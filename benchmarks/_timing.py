"""Shared benchmark timing — one best-of-N implementation for every
benchmark module, built on the obs tracer.

Every bench used to carry its own copy of the ``perf_counter`` best-of-N
loop (and ``fuzzy_bench`` timed its wall clock with non-monotonic
``time.time()``).  This module is the single source of truth:

  timed(fn)      — best-of-N wall time for a callable; each repetition
                   runs under a ``bench.rep`` obs span so enabling the
                   tracer yields a Chrome-trace of the bench itself.
  stopwatch()    — context manager for one-shot sections (ingest loops,
                   end-to-end pipelines); monotonic by construction.

All times are ``time.perf_counter()`` seconds.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Optional, Tuple

from repro import obs

__all__ = ["timed", "stopwatch", "Stopwatch"]


def timed(fn: Callable[[], Any], repeat: int = 3, warmup: int = 0,
          block: Optional[Callable[[Any], Any]] = None,
          ) -> Tuple[Any, float]:
    """Run ``fn`` ``warmup + repeat`` times; return (last output,
    best seconds over the timed repetitions).

    ``block`` (e.g. ``jax.block_until_ready``) is applied to the output
    inside the timed region so async dispatch is charged to the bench.
    """
    out = None
    for _ in range(warmup):
        out = fn()
        if block is not None:
            block(out)
    best = float("inf")
    for _ in range(max(1, repeat)):
        with obs.span("bench.rep") as sp:
            t0 = time.perf_counter()
            out = fn()
            if block is not None:
                block(out)
            dt = time.perf_counter() - t0
            sp.set("seconds", dt)
        best = min(best, dt)
    return out, best


class Stopwatch:
    """``with stopwatch() as sw: ...`` then read ``sw.seconds``."""

    __slots__ = ("_t0", "seconds")

    def __enter__(self) -> "Stopwatch":
        self.seconds = 0.0
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        self.seconds = time.perf_counter() - self._t0
        return False


def stopwatch() -> Stopwatch:
    return Stopwatch()
