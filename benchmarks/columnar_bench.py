"""Columnar engine vs row engine on the Table-3-style workloads.

Same optimizer, same physical plans, same partitioned executor — only the
operator implementation changes (``Executor(vectorize=True)`` lowers
supported subplans to ColumnBatch pipelines with the fused
filter+aggregate kernel of kernels/columnar_ops).  Run on >=10k-row
scans so the per-query fixed costs (shred-cache assembly, kernel
dispatch) amortize; the first vectorized run of each query warms the
per-component column caches and is excluded by best-of-N timing.
"""

from __future__ import annotations

import datetime as dt
import functools

from repro.configs.tinysocial import build_dataverse
from repro.core import algebra as A
from repro.storage.query import run_query

from ._timing import timed

N_USERS, N_MSGS = 4000, 20000
SMOKE_USERS, SMOKE_MSGS = 800, 4000

_timed = functools.partial(timed, repeat=5)


def approx_equal(a, b, rel=1e-5):
    """Structural equality with numeric tolerance: on TPU the fused
    Pallas kernel accumulates in f32, so sums/avgs over >=2^24-scale
    values differ from the row engine in the last bits (exact on the
    CPU jnp-x64 fallback)."""
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() \
            and all(approx_equal(a[k], b[k], rel) for k in a)
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) \
            and all(approx_equal(x, y, rel) for x, y in zip(a, b))
    if isinstance(a, bool) or isinstance(b, bool) or a is None or b is None:
        return a == b
    if isinstance(a, (int, float)) and isinstance(b, (int, float)):
        return abs(a - b) <= rel * max(1.0, abs(a), abs(b))
    return a == b


def _compare(name, plan, ds, rows, check=None):
    (res_r, t_r) = _timed(lambda: run_query(plan, ds))
    (res_c, t_c) = _timed(lambda: run_query(plan, ds, vectorize=True))
    if check is not None:
        assert approx_equal(check(res_r[0]), check(res_c[0])), name
    stats = res_c[1].stats
    rows.append({
        "bench": f"columnar_{name}",
        "us_per_call": t_r * 1e6,
        "us_columnar": t_c * 1e6,
        "derived": f"speedup {t_r / t_c:.1f}x; "
                   f"vectorized={stats.rows_vectorized} "
                   f"fallback={stats.rows_fallback}",
    })
    return t_r, t_c


def run(smoke: bool = False) -> list:
    nu, nm = (SMOKE_USERS, SMOKE_MSGS) if smoke else (N_USERS, N_MSGS)
    _, ds = build_dataverse(nu, nm, num_partitions=4,
                            flush_threshold=256)
    rows: list = []
    mlo = dt.datetime(2014, 2, 1)
    far = dt.datetime(2030, 1, 1)

    # -- filter + aggregate over the full 20k-row scan (the hot path:
    #    exact ranges fuse predicate and reductions into one kernel pass)
    agg = A.aggregate(
        A.select(A.scan("MugshotMessages"),
                 pred=lambda r: r["timestamp"] >= mlo,
                 fields=["timestamp"], ranges={"timestamp": (mlo, far)},
                 ranges_exact=True, hints=["skip-index"]),
        {"cnt": ("count", "*"), "avg_author": ("avg", "author-id"),
         "mx": ("max", "author-id")})
    t_r, t_c = _compare(f"filter_agg_{nm // 1000}k", agg, ds,
                        rows, check=lambda r: r[0])
    assert t_c < t_r, f"columnar must beat the row engine on {nm}-row " \
                      "filter+aggregate"

    # -- columnar-native storage: components carry their ColumnBatch as
    #    primary data (shredded once at flush), so projected scans are
    #    zero-copy dict subsets and no row view was ever forced
    msgs = ds["MugshotMessages"]
    comp = next(c for c in msgs.partitions[0].primary.components if c.valid)
    stored = sorted(comp.batch.columns)
    rows.append({
        "bench": "columnar_storage",
        "us_per_call": "",
        "derived": f"columns stored on component at flush: {stored} "
                   f"(of {len(msgs.columnar_schema().kinds)} in schema; "
                   f"row dicts exist only as the lazy view the row-engine "
                   f"comparison runs above forced)",
    })

    # -- same query, inexact ranges: the row-predicate residual re-check
    #    decodes survivors, showing the cost of opaque predicates
    agg_resid = A.aggregate(
        A.select(A.scan("MugshotMessages"),
                 pred=lambda r: mlo <= r["timestamp"] <= far,
                 fields=["timestamp"], ranges={"timestamp": (mlo, far)},
                 hints=["skip-index"]),
        {"cnt": ("count", "*")})
    _compare("filter_agg_residual", agg_resid, ds, rows,
             check=lambda r: r[0])

    # -- grouped aggregation + top-k (vectorized hash group + sort)
    grp = A.limit(A.order_by(
        A.group_by(A.scan("MugshotMessages"), ["author-id"],
                   {"cnt": ("count", "*"), "am": ("avg", "message-id")}),
        ["cnt", "author-id"], desc=True), 10)
    _compare("group_topk", grp, ds, rows,
             check=lambda r: [x["cnt"] for x in r])

    # -- equijoin under a grouped aggregate (join stays columnar because a
    #    reducer sits above it; a bare join would fall back)
    join_grp = A.group_by(
        A.join(A.select(A.scan("MugshotMessages"),
                        pred=lambda r: r["timestamp"] >= mlo,
                        fields=["timestamp"],
                        ranges={"timestamp": (mlo, far)},
                        ranges_exact=True, hints=["skip-index"]),
               A.scan("MugshotUsers"), ["author-id"], ["id"]),
        ["author-id"], {"cnt": ("count", "*")})
    _compare("join_group", join_grp, ds, rows,
             check=lambda r: sorted((x["author-id"], x["cnt"]) for x in r))
    return rows
