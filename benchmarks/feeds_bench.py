"""Feed ingestion throughput + joint fan-out + fuzzy-join dedup benches
(paper §2.4/§4.5 + Q13)."""

from __future__ import annotations

import numpy as np

from repro.configs.tinysocial import build_dataverse, gen_messages
from repro.data.dedup import FuzzyJoin
from repro.data.feeds import BatchAssembler, Feed, SyntheticTokenAdaptor

from ._timing import stopwatch


def run(smoke: bool = False) -> list:
    rows = []
    n_ingest = 600 if smoke else 3000
    n_docs = 80 if smoke else 300

    # -- feed -> dataset ingestion pipeline ----------------------------------
    _, ds = build_dataverse(50, 0, num_partitions=4, flush_threshold=512)
    msgs_ds = ds["MugshotMessages"]
    recs = gen_messages(n_ingest, 50, seed=3)
    src = iter(recs)

    class ListAdaptor:
        cursor = 0

        def next_batch(self, n):
            out = recs[self.cursor:self.cursor + n]
            self.cursor += len(out)
            return out

        def seek(self, c):
            self.cursor = c

    feed = Feed("ingest", adaptor=ListAdaptor(),
                udfs=[lambda r: r if r["author-id"] != 13 else None],
                store=lambda rs: [msgs_ds.insert(r) for r in rs])
    with stopwatch() as sw:
        while feed.pump(256):
            pass
    dt = sw.seconds
    rows.append({"bench": "feed_ingest", "us_per_call": dt / n_ingest * 1e6,
                 "derived": f"{len(msgs_ds)} stored (author 13 filtered), "
                            f"{n_ingest / dt:.0f} rec/s"})

    # -- joint fan-out: train + eval subscribe to one intake ------------------
    primary = Feed("intake", adaptor=SyntheticTokenAdaptor(512, 50304))
    train_sink = BatchAssembler(32)
    eval_sink = BatchAssembler(8)
    train = Feed("train", source_joint=primary.joint, store=train_sink)
    evalf = Feed("eval", source_joint=primary.joint, store=eval_sink)
    with stopwatch() as sw:
        for _ in range(8):
            primary.pump(64)
            train.pump(64)
            evalf.pump(64)
    dt = sw.seconds
    nb = 0
    while train_sink.take() is not None:
        nb += 1
    rows.append({"bench": "feed_joint_fanout",
                 "us_per_call": dt / 512 * 1e6,
                 "derived": f"{nb} train batches; 2 subscribers, "
                            f"1 intake (cascading feeds)"})

    # -- fuzzy-join dedup (Q13) ----------------------------------------------
    rng = np.random.default_rng(0)
    vocab = [f"tok{i}" for i in range(200)]
    docs = []
    for i in range(n_docs):
        base = set(rng.choice(vocab, size=12, replace=False))
        docs.append((i, base))
        if i % 5 == 0:
            near = set(base)
            near.discard(next(iter(near)))
            docs.append((1000 + i, near))
    fj = FuzzyJoin(threshold=0.5, num_hashes=64, bands=16)
    with stopwatch() as sw:
        pairs, stats = fj.run(docs)
    dt = sw.seconds
    n = len(docs)
    rows.append({"bench": "fuzzy_join_dedup", "us_per_call": dt * 1e6,
                 "derived": f"{stats['pairs']} dup pairs; candidates "
                            f"{stats['candidates']} vs brute "
                            f"{n * (n - 1) // 2} "
                            f"({n * (n - 1) // 2 / max(stats['candidates'], 1):.0f}x pruned)"})
    return rows
