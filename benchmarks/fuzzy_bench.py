"""Fuzzy query paths: ngram index + vectorized kernels vs the scalar
python paths.

Two workloads:

  * fuzzy selects (edit-distance and gram-Jaccard) on an ngram(3)-indexed
    string field: the columnar NGRAM_INDEX_SEARCH -> T_OCCURRENCE ->
    batched-verify chain vs the row engine's full dictionary scan with a
    per-row python predicate (``RewriteConfig(use_indexes=False)``, the
    pre-ngram fuzzy path).  Zero result diffs, ``rows_fuzzy_vectorized >
    0`` with ``rows_fallback == 0``, and zero kernel retraces on the
    repeated (timed) queries are asserted; at full size the edit-distance
    select must beat the scan by >= 5x.
  * FuzzyJoin verification: the batched dictionary-coded Jaccard pass vs
    the per-pair python loop on the same LSH candidate set — identical
    pairs, >= 5x at full size.

Usage: PYTHONPATH=src python -m benchmarks.fuzzy_bench [--smoke]
"""

from __future__ import annotations

import argparse
import random
import sys

from repro.core import adm
from repro.core import algebra as A
from repro.core.rewriter import RewriteConfig
from repro.data.dedup import FuzzyJoin, minhash_signature
from repro.fuzzy import fuzzy_predicate
from repro.storage.dataset import PartitionedDataset
from repro.storage.query import run_query

N_ROWS, N_JOIN = 20000, 3500
SMOKE_ROWS, SMOKE_JOIN = 2000, 400


from ._timing import stopwatch, timed as _timed


def _canon(rows):
    return sorted(repr(sorted(r.items(), key=lambda kv: kv[0]))
                  for r in rows)


def _word(rng):
    return "".join(rng.choice("abcdefghij") for _ in range(rng.randrange(4, 12)))


def _build_dataset(n_rows: int):
    rng = random.Random(42)
    vocab = [_word(rng) for _ in range(600)]
    target = vocab[0]
    # plant near-duplicates of the target so fuzzy selects hit
    for _ in range(30):
        j = rng.randrange(len(target))
        vocab.append(target[:j] + rng.choice("xyz") + target[j:])
    rt = adm.RecordType("FuzzyT", (
        adm.Field("id", adm.INT64),
        adm.Field("w", adm.STRING),
    ), open=True)
    ds = PartitionedDataset("F", rt, "id", num_partitions=4,
                            flush_threshold=1024)
    ds.create_index("w", kind="ngram")
    ds.insert_batch([{"id": i, "w": rng.choice(vocab)}
                     for i in range(n_rows)])
    return ds, target


def _select_rows(ds, target, repeat):
    out = []
    specs = {
        "ed_select": ("w", "ed", target, 2),
        "jaccard_select": ("w", "jaccard", target, 0.6),
    }
    for name, spec in specs.items():
        # pred IS the spec's predicate, so the plan declares exactness
        # and the columnar chain never re-runs it row-at-a-time
        plan = A.select(A.scan("F"), pred=fuzzy_predicate(spec),
                        fields=["w"], fuzzy=spec, ranges_exact=True)
        # baseline: the python dictionary-scan path (no index rule)
        (res_s, t_s) = _timed(lambda p=plan: run_query(
            p, {"F": ds}, config=RewriteConfig(use_indexes=False)), repeat)
        run_query(plan, {"F": ds}, vectorize=True)   # warm jit caches
        (res_c, t_c) = _timed(lambda p=plan: run_query(
            p, {"F": ds}, vectorize=True), repeat)
        assert _canon(res_s[0]) == _canon(res_c[0]), \
            f"{name}: fuzzy chain diverges from the scalar scan"
        ex = res_c[1]
        assert ex.stats.rows_fuzzy_vectorized > 0, \
            f"{name}: fuzzy chain silently fell back to the row engine"
        assert ex.stats.rows_fallback == 0, \
            f"{name}: {ex.stats.rows_fallback} rows fell back"
        assert ex.stats.kernel_retraces == 0, \
            f"{name}: repeated fuzzy query retraced the kernels"
        out.append({
            "bench": f"fuzzy_{name}",
            "us_per_call": t_s * 1e6,
            "us_columnar": t_c * 1e6,
            "derived": f"ngram chain {t_s / t_c:.1f}x vs python scan "
                       f"({len(res_c[0])} rows out, "
                       f"{ex.stats.rows_fuzzy_vectorized} fuzzy-vec rows)",
            "speedup": t_s / t_c,
        })
    return out


def _join_rows(n_records: int, repeat: int):
    """Near-duplicate clusters (the dedup workload the pipeline exists
    for): LSH banding turns every within-cluster pair into a candidate,
    so verification dominates the join — exactly the stage the batched
    kernel replaces."""
    rng = random.Random(7)
    vocab = [f"tok{i}" for i in range(800)]
    cluster = 100
    recs = []
    rid = 0
    for _c in range(max(n_records // cluster, 1)):
        base = rng.sample(vocab, 60)
        for _ in range(cluster):
            s = set(base)
            for t in rng.sample(base, 5):
                s.discard(t)
            s.update(rng.sample(vocab, 3))
            recs.append((rid, s))
            rid += 1
    fj = FuzzyJoin(threshold=0.5)
    # candidate generation once; time the verify stage both ways
    sigs = {rid: minhash_signature(t, fj.num_hashes, fj.seed)
            for rid, t in recs}
    toks = dict(recs)
    buckets = {}
    for rid, sig in sigs.items():
        for key in fj.band_keys(sig):
            buckets.setdefault(key, []).append(rid)
    import itertools
    candidates = set()
    for rids in buckets.values():
        for a, b in itertools.combinations(sorted(rids, key=str), 2):
            candidates.add((a, b))
    cands = sorted(candidates, key=str)
    # timing spans sub-100ms calls: park the cyclic GC so a collection
    # pause does not land inside one repeat and skew the min
    import gc
    gc.collect()
    gc.disable()
    try:
        fj.batch_verify = False
        (pairs_p, t_p) = _timed(lambda: fj.verify(cands, toks),
                                max(repeat, 4))
        fj.batch_verify = True
        fj.verify(cands, toks)                   # warm jit caches
        (pairs_b, t_b) = _timed(lambda: fj.verify(cands, toks),
                                max(repeat, 4))
    finally:
        gc.enable()
    assert sorted(pairs_b) == sorted(pairs_p), \
        "batched FuzzyJoin verify diverges from the per-pair loop"
    return [{
        "bench": "fuzzy_join_verify",
        "us_per_call": t_p * 1e6,
        "us_columnar": t_b * 1e6,
        "derived": f"batched verify {t_p / t_b:.1f}x vs per-pair python "
                   f"({len(cands)} candidates -> {len(pairs_b)} pairs)",
        "speedup": t_p / t_b,
    }]


def run(smoke: bool = False) -> list:
    n_rows, n_join = (SMOKE_ROWS, SMOKE_JOIN) if smoke \
        else (N_ROWS, N_JOIN)
    repeat = 2 if smoke else 3
    ds, target = _build_dataset(n_rows)
    rows = _select_rows(ds, target, repeat)
    del ds              # the join timings need the memory, not the caches
    import gc
    gc.collect()
    rows += _join_rows(n_join, repeat)
    if not smoke:       # acceptance targets hold at full size only
        ed = next(r for r in rows if r["bench"] == "fuzzy_ed_select")
        jv = next(r for r in rows if r["bench"] == "fuzzy_join_verify")
        assert ed["speedup"] >= 5.0, \
            f"ed select {ed['speedup']:.1f}x < 5x target"
        assert jv["speedup"] >= 5.0, \
            f"join verify {jv['speedup']:.1f}x < 5x target"
    for r in rows:
        r.pop("speedup", None)
    return rows


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small dataset, fewer repeats (CI gate)")
    args = p.parse_args()
    with stopwatch() as sw:
        out = run(smoke=args.smoke)
    print("name,us_per_call,us_columnar,derived")
    for r in out:
        print(f"{r['bench']},{r['us_per_call']:.1f},"
              f"{r['us_columnar']:.1f},{r['derived']}")
    print(f"# fuzzy_bench done in {sw.seconds:.1f}s "
          f"({'smoke' if args.smoke else 'full'})", file=sys.stderr)


if __name__ == "__main__":
    main()
