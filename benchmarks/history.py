"""Bench-history regression gate: compare a fresh ``benchmarks/run.py
--json`` report against the committed ``benchmarks/baseline.json``.

Until now the bench trajectory was empty — smoke benches carried
hard-coded asserts (speedup >= 3x, zero torn reads, ...) but nothing
compared run N against run N-1, so a 2x slowdown that still cleared the
absolute floors was invisible.  This module is the gate:

* ``--update`` seeds/refreshes the baseline from a fresh report: per
  bench row it records ``us_per_call``, the owning module, a *tolerance
  band* (``max_ratio``: how much slower the row may get before the gate
  trips — per-module defaults cover the noisier thread-scheduling
  benches), and the exact-invariant fields (``torn_reads``,
  ``h2d_warm``, ...) that must never drift at all.

* ``--check`` compares a fresh report row-by-row: prints a delta table,
  writes a machine-readable delta report (``--report``, uploaded as a
  CI artifact next to ``bench_smoke.json``), and exits nonzero when

    - the baseline or report schema_version is unknown,
    - a baselined bench is missing from the fresh report,
    - an exact-invariant field changed, or
    - a row regressed beyond its band: ``fresh > base * max_ratio``
      *and* ``fresh - base > min_delta_us`` (the absolute slack keeps
      near-zero rows from tripping on timer noise).

  Improvements and new benches never fail the gate (new rows are listed
  so the next ``--update`` picks them up).

Usage:
    PYTHONPATH=src python -m benchmarks.history --check \
        [--baseline benchmarks/baseline.json] [--fresh bench_smoke.json] \
        [--report bench_delta.json]
    PYTHONPATH=src python -m benchmarks.history --update \
        [--fresh bench_smoke.json]

``scripts/verify.sh`` and CI run ``--check`` right after the smoke
benches; regenerate the baseline with ``--update`` whenever a PR
legitimately moves the numbers, and commit the diff.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Tuple

BASELINE_SCHEMA_VERSION = 1
REPORT_SCHEMA_VERSIONS = (1,)          # accepted run.py --json schemas
DEFAULT_BASELINE = "benchmarks/baseline.json"
DEFAULT_FRESH = "bench_smoke.json"

# How much slower (ratio) a row may get before the gate trips.  The
# thread-scheduling benches (serve/feeds) and the microsecond-scale
# index candidate reads are the noisiest (observed 3-5x run-to-run
# swings on a loaded host); pure-kernel rows are the steadiest.
# Written into the baseline per row so a future tightening only needs
# --update.
DEFAULT_MAX_RATIO = 3.0
MODULE_MAX_RATIO = {"serve": 5.0, "feeds": 4.0, "ingest": 4.0,
                    "index": 5.0, "mesh": 4.0}
# Absolute slack: a row under the band never fails on fewer extra
# microseconds than this (near-zero rows divide noisily — a 20us row
# tripling is timer noise, not a regression).
DEFAULT_MIN_DELTA_US = 1000.0

# Fields that must match the baseline exactly — correctness/residency
# invariants a timing band must never paper over.
EXACT_FIELDS = ("torn_reads", "lost_acked", "recoveries",
                "h2d_warm", "retraces_warm")


def build_baseline(report: Dict[str, Any],
                   default_max_ratio: float = DEFAULT_MAX_RATIO,
                   min_delta_us: float = DEFAULT_MIN_DELTA_US
                   ) -> Dict[str, Any]:
    """Distill a ``run.py --json`` report into a committed baseline."""
    sv = report.get("schema_version")
    if sv not in REPORT_SCHEMA_VERSIONS:
        raise ValueError(f"unsupported report schema_version: {sv!r}")
    benches: Dict[str, Any] = {}
    for name, row in sorted(report.get("benches", {}).items()):
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)):
            continue                       # non-timing row: nothing to band
        module = row.get("module", "")
        entry: Dict[str, Any] = {
            "us_per_call": float(us),
            "module": module,
            "max_ratio": MODULE_MAX_RATIO.get(module, default_max_ratio),
        }
        exact = {f: row[f] for f in EXACT_FIELDS if f in row}
        if exact:
            entry["exact"] = exact
        benches[name] = entry
    return {
        "schema_version": BASELINE_SCHEMA_VERSION,
        "source_schema_version": sv,
        "smoke": bool(report.get("smoke")),
        "min_delta_us": float(min_delta_us),
        "benches": benches,
    }


def compare(baseline: Dict[str, Any], report: Dict[str, Any]
            ) -> Tuple[List[Dict[str, Any]], List[str]]:
    """Row-by-row delta of a fresh report against the baseline.

    Returns (rows, failures): one delta row per bench with a ``status``
    of ``ok`` / ``improved`` / ``regression`` / ``exact_mismatch`` /
    ``missing`` / ``new``; ``failures`` holds one human-readable line
    per gate violation (empty == gate passes)."""
    failures: List[str] = []
    bsv = baseline.get("schema_version")
    if bsv != BASELINE_SCHEMA_VERSION:
        return [], [f"baseline schema_version {bsv!r} != "
                    f"{BASELINE_SCHEMA_VERSION} (regenerate with --update)"]
    rsv = report.get("schema_version")
    if rsv not in REPORT_SCHEMA_VERSIONS:
        return [], [f"report schema_version {rsv!r} not in "
                    f"{REPORT_SCHEMA_VERSIONS}"]
    if report.get("failures"):
        failures.append(f"fresh report carries bench failures: "
                        f"{report['failures']}")
    min_delta = float(baseline.get("min_delta_us", DEFAULT_MIN_DELTA_US))
    fresh_rows = report.get("benches", {})
    rows: List[Dict[str, Any]] = []
    for name, base in sorted(baseline.get("benches", {}).items()):
        row: Dict[str, Any] = {"bench": name, "module": base.get("module"),
                               "base_us": base["us_per_call"],
                               "max_ratio": base["max_ratio"]}
        fresh = fresh_rows.get(name)
        if fresh is None:
            row.update(status="missing", fresh_us=None, ratio=None)
            rows.append(row)
            failures.append(f"{name}: baselined bench missing from report")
            continue
        us = fresh.get("us_per_call")
        if not isinstance(us, (int, float)):
            row.update(status="missing", fresh_us=None, ratio=None)
            rows.append(row)
            failures.append(f"{name}: fresh row has no numeric us_per_call")
            continue
        base_us = float(base["us_per_call"])
        ratio = float(us) / base_us if base_us > 0 else float("inf")
        row.update(fresh_us=float(us), ratio=ratio)
        status = "ok"
        for fld, want in base.get("exact", {}).items():
            got = fresh.get(fld)
            if got != want:
                status = "exact_mismatch"
                failures.append(f"{name}: invariant {fld} changed "
                                f"{want!r} -> {got!r}")
        if status == "ok":
            if (ratio > base["max_ratio"]
                    and (us - base_us) > min_delta):
                status = "regression"
                failures.append(
                    f"{name}: {us:.1f}us vs baseline {base_us:.1f}us "
                    f"({ratio:.2f}x > {base['max_ratio']:.2f}x band)")
            elif ratio < 1.0:
                status = "improved"
        row["status"] = status
        rows.append(row)
    for name, fresh in sorted(fresh_rows.items()):
        if name not in baseline.get("benches", {}) \
                and isinstance(fresh.get("us_per_call"), (int, float)):
            rows.append({"bench": name, "module": fresh.get("module"),
                         "base_us": None, "max_ratio": None,
                         "fresh_us": float(fresh["us_per_call"]),
                         "ratio": None, "status": "new"})
    return rows, failures


def format_table(rows: List[Dict[str, Any]]) -> str:
    """The human-readable delta table --check prints."""
    header = (f"{'bench':<34} {'base_us':>12} {'fresh_us':>12} "
              f"{'ratio':>7} {'band':>6}  status")
    out = [header, "-" * len(header)]
    for r in rows:
        base = "-" if r["base_us"] is None else f"{r['base_us']:.1f}"
        fresh = "-" if r["fresh_us"] is None else f"{r['fresh_us']:.1f}"
        ratio = "-" if r["ratio"] is None else f"{r['ratio']:.2f}x"
        band = "-" if r["max_ratio"] is None else f"{r['max_ratio']:.1f}x"
        out.append(f"{r['bench']:<34} {base:>12} {fresh:>12} "
                   f"{ratio:>7} {band:>6}  {r['status']}")
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = p.add_mutually_exclusive_group(required=True)
    mode.add_argument("--check", action="store_true",
                      help="gate: compare fresh report vs baseline, "
                           "exit nonzero on regression")
    mode.add_argument("--update", action="store_true",
                      help="seed/refresh the committed baseline from the "
                           "fresh report")
    p.add_argument("--baseline", default=DEFAULT_BASELINE, metavar="PATH")
    p.add_argument("--fresh", default=DEFAULT_FRESH, metavar="PATH",
                   help="fresh run.py --json output (default "
                        f"{DEFAULT_FRESH})")
    p.add_argument("--report", default="", metavar="PATH",
                   help="also write the delta rows as JSON (CI artifact)")
    args = p.parse_args(argv)

    try:
        with open(args.fresh) as f:
            fresh = json.load(f)
    except (OSError, ValueError) as e:
        print(f"history: cannot read fresh report {args.fresh}: {e}",
              file=sys.stderr)
        return 2

    if args.update:
        baseline = build_baseline(fresh)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"history: baseline -> {args.baseline} "
              f"({len(baseline['benches'])} benches)")
        return 0

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as e:
        print(f"history: cannot read baseline {args.baseline}: {e} "
              f"(seed one with --update)", file=sys.stderr)
        return 2
    rows, failures = compare(baseline, fresh)
    print(format_table(rows))
    if args.report:
        with open(args.report, "w") as f:
            json.dump({"schema_version": BASELINE_SCHEMA_VERSION,
                       "baseline": args.baseline, "fresh": args.fresh,
                       "rows": rows, "failures": failures}, f,
                      indent=1, sort_keys=True)
            f.write("\n")
        print(f"# delta report -> {args.report}", file=sys.stderr)
    if failures:
        print("\nhistory: REGRESSION GATE FAILED", file=sys.stderr)
        for line in failures:
            print(f"  - {line}", file=sys.stderr)
        return 1
    n_ok = sum(r["status"] in ("ok", "improved") for r in rows)
    print(f"\nhistory: gate passed ({n_ok} rows within band)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
