"""Index access paths: row engine vs columnar candidate intersection.

Benchmarks the vectorized Figure-6 chains (secondary btree / rtree /
keyword search -> candidate bitmap -> gather -> post-validate) against
the row engine on the same plans, asserting zero result diffs.  Every
index plan must report ``rows_index_vectorized > 0`` with
``rows_fallback == 0`` and ``kernel_retraces == 0`` on repeated queries
— a silent fallback to the row engine (or a per-query kernel retrace)
fails the bench (scripts/verify.sh runs ``--smoke``).

The *candidate-read stage* is additionally benchmarked in isolation
against a bench-local reconstruction of the replaced path (a secondary
LSMIndex of (key, pk) rows probed via the dict-union ``range_values``
walk + per-query sort): the per-component CSR postings probe must beat
it >= 2x at full size.

Expected shape of the plan-level numbers: index -> aggregate/group
pipelines win big (no row materialization at all); selective full-record
selects sit near the row engine's latency, paying only the row boundary
decode.

Usage: PYTHONPATH=src python -m benchmarks.index_bench [--smoke]
"""

from __future__ import annotations

import argparse
import datetime as dt
import sys

import numpy as np

from repro.configs.tinysocial import build_dataverse
from repro.core import algebra as A
from repro.core.functions import cells_covering_circle, spatial_cell
from repro.core.lsm import LSMIndex
from repro.storage.query import run_query

N_USERS, N_MSGS = 4000, 12000
SMOKE_USERS, SMOKE_MSGS = 400, 1200


from ._timing import stopwatch, timed as _timed


def _canon(rows):
    return sorted(repr(sorted(r.items(), key=lambda kv: kv[0]))
                  for r in rows)


def _plans(n_users):
    from repro.core.functions import spatial_distance, word_tokens
    lo, hi = dt.datetime(2010, 1, 1), dt.datetime(2010, 3, 1)
    mlo = dt.datetime(2014, 1, 15)
    center, radius = (33.5, -117.5), 0.12
    return {
        # selective point-ish range, full records out (boundary-bound)
        "btree_select": A.select(
            A.scan("MugshotUsers"),
            pred=lambda r: lo <= r["user-since"] <= hi,
            fields=["user-since"], ranges={"user-since": (lo, hi)},
            ranges_exact=True),
        # wide range feeding a fused aggregate: no row ever materializes
        "btree_agg": A.aggregate(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r: r["timestamp"] >= mlo,
                     fields=["timestamp"],
                     ranges={"timestamp": (mlo, None)}, ranges_exact=True),
            {"c": ("count", "*"), "av": ("avg", "author-id"),
             "mx": ("max", "timestamp")}),
        # two btree indexes: candidate bitmaps intersect before decode
        "multi_index_group": A.group_by(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r, k=n_users // 2:
                     r["timestamp"] >= mlo and r["author-id"] <= k,
                     fields=["timestamp", "author-id"],
                     ranges={"timestamp": (mlo, None),
                             "author-id": (None, n_users // 2)},
                     ranges_exact=True),
            ["author-id"], {"c": ("count", "*")}),
        "rtree_select": A.select(
            A.scan("MugshotMessages"),
            pred=lambda r: spatial_distance(r["sender-location"],
                                            center) <= radius,
            fields=["sender-location"],
            spatial=("sender-location", center, radius)),
        "keyword_agg": A.aggregate(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r: "tonight" in word_tokens(r["message"]),
                     fields=["message"],
                     keyword=("message", "tonight", 0)),
            {"c": ("count", "*"), "mn": ("min", "message-id")}),
    }


# ---------------------------------------------------------------------------
# candidate-read stage: legacy secondary-LSM walk vs CSR postings probe
# ---------------------------------------------------------------------------

class _Extreme:
    """Comparable +/- infinity for composite (key, pk) range probes (the
    replaced path's unbounded-side sentinels)."""

    def __init__(self, sign): self.sign = sign
    def __lt__(self, other): return self.sign < 0
    def __gt__(self, other): return self.sign > 0
    def __le__(self, other): return self.sign < 0
    def __ge__(self, other): return self.sign > 0


_MIN, _MAX = _Extreme(-1), _Extreme(+1)


def _legacy_secondaries(ds, fld, kind="btree"):
    """Reconstruct the pre-postings secondary index: one row-mode
    LSMIndex of ((key, pk) -> pk) per partition, flushed so candidate
    reads walk real components (the path this PR replaced)."""
    out = []
    for i in range(ds.num_partitions):
        ix = LSMIndex(flush_threshold=1 << 30, columnar=False)
        for pk, row in ds.partitions[i].primary.items():
            if fld in row:
                key = row[fld] if kind == "btree" else \
                    spatial_cell(row[fld], ds.spatial_cell_size)
                ix.insert((key, pk), pk)
        ix.flush()     # one disk component: the legacy walk's best case
        out.append(ix)
    return out


def _legacy_pk_array(pks):
    if not pks:
        return np.zeros(0, dtype=np.int64)
    return np.unique(np.asarray(pks))


def _bench_candidate_stage(ds, nm, rows, repeat):
    """Time ONLY the candidate read (index probe -> sorted PK candidate
    array) for a wide btree range and an rtree circle, legacy vs
    postings, asserting identical candidates and the >= 2x win at full
    size."""
    msgs = ds["MugshotMessages"]
    mlo = dt.datetime(2014, 1, 15)
    center, radius = (33.5, -117.5), 0.12
    legacy_b = _legacy_secondaries(msgs, "timestamp", "btree")
    legacy_r = _legacy_secondaries(msgs, "sender-location", "rtree")

    def legacy_btree():
        return [_legacy_pk_array(ix.range_values((mlo, _MIN), (_MAX, _MAX)))
                for ix in legacy_b]

    def legacy_rtree():
        out = []
        for ix in legacy_r:
            pks = []
            for cell in cells_covering_circle(center, radius,
                                              msgs.spatial_cell_size):
                pks.extend(ix.range_values(((cell, _MIN)), ((cell, _MAX))))
            out.append(_legacy_pk_array(pks))
        return out

    def csr_btree():
        return [msgs.secondary_candidate_pks(i, "timestamp", mlo, None)
                for i in range(msgs.num_partitions)]

    def csr_rtree():
        return [msgs.spatial_candidate_pks(i, "sender-location", center,
                                           radius)
                for i in range(msgs.num_partitions)]

    for name, legacy, csr in (("btree_range", legacy_btree, csr_btree),
                              ("rtree_circle", legacy_rtree, csr_rtree)):
        (res_l, t_l) = _timed(legacy, repeat)
        (res_c, t_c) = _timed(csr, repeat)
        # legacy candidates over-approximate: entries for rows whose
        # newer version left the key range are tombstone-maintained
        # there, but this bench builds from a clean scan, so sets match
        assert [a.tolist() for a in res_l] == [a.tolist() for a in res_c], \
            f"candidate_{name}: postings diverge from the legacy walk"
        speedup = t_l / t_c
        if nm >= N_MSGS:     # full size: the tentpole's asserted win
            assert speedup >= 2.0, \
                f"candidate_{name}: CSR postings only {speedup:.2f}x " \
                f"vs the legacy dict-union walk (need >= 2x)"
        rows.append({
            "bench": f"index_candidates_{name}",
            "us_per_call": t_l * 1e6,
            "us_columnar": t_c * 1e6,
            "derived": f"CSR candidate read {speedup:.1f}x vs legacy "
                       f"secondary-LSM walk "
                       f"({sum(len(a) for a in res_c)} candidate pks)",
        })


def run(smoke: bool = False) -> list:
    nu, nm = (SMOKE_USERS, SMOKE_MSGS) if smoke else (N_USERS, N_MSGS)
    _, ds = build_dataverse(nu, nm, num_partitions=4, flush_threshold=256)
    msgs = ds["MugshotMessages"]
    msgs.create_index("sender-location", kind="rtree")
    msgs.create_index("message", kind="keyword")
    rows = []
    repeat = 2 if smoke else 4
    for name, plan in _plans(nu).items():
        (res_r, t_r) = _timed(lambda p=plan: run_query(p, ds), repeat)
        # warm the jit caches outside the timed region
        run_query(plan, ds, vectorize=True)
        (res_c, t_c) = _timed(lambda p=plan: run_query(p, ds,
                                                       vectorize=True),
                              repeat)
        assert _canon(res_r[0]) == _canon(res_c[0]), \
            f"{name}: columnar results diverge from the row engine"
        ex = res_c[1]
        assert ex.stats.rows_index_vectorized > 0, \
            f"{name}: index access path silently fell back to the row engine"
        assert ex.stats.rows_fallback == 0, \
            f"{name}: {ex.stats.rows_fallback} rows fell back"
        assert ex.stats.kernel_retraces == 0, \
            f"{name}: repeated index query retraced " \
            f"{ex.stats.kernel_retraces} kernel cores"
        rows.append({
            "bench": f"index_{name}",
            "us_per_call": t_r * 1e6,
            "us_columnar": t_c * 1e6,
            "derived": f"columnar {t_r / t_c:.1f}x vs row engine "
                       f"({len(res_c[0])} rows out, "
                       f"{ex.stats.rows_index_vectorized} idx-vec rows)",
        })
    _bench_candidate_stage(ds, nm, rows, repeat)
    return rows


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small dataset, fewer repeats (CI gate)")
    args = p.parse_args()
    with stopwatch() as sw:
        out = run(smoke=args.smoke)
    print("name,us_per_call,us_columnar,derived")
    for r in out:
        print(f"{r['bench']},{r['us_per_call']:.1f},"
              f"{r['us_columnar']:.1f},{r['derived']}")
    print(f"# index_bench done in {sw.seconds:.1f}s "
          f"({'smoke' if args.smoke else 'full'})", file=sys.stderr)


if __name__ == "__main__":
    main()
