"""Index access paths: row engine vs columnar candidate intersection.

Benchmarks the vectorized Figure-6 chains (secondary btree / rtree /
keyword search -> PK bitmap intersect -> gather -> post-validate) against
the row engine on the same plans, asserting zero result diffs.  Every
index plan must report ``rows_index_vectorized > 0`` with
``rows_fallback == 0`` — a silent fallback to the row engine fails the
bench (scripts/verify.sh runs ``--smoke``).

Expected shape of the numbers: index -> aggregate/group pipelines win big
(no row materialization at all); selective full-record selects sit near
the row engine's latency, paying only the row boundary decode.

Usage: PYTHONPATH=src python -m benchmarks.index_bench [--smoke]
"""

from __future__ import annotations

import argparse
import datetime as dt
import sys
import time

from repro.configs.tinysocial import build_dataverse
from repro.core import algebra as A
from repro.storage.query import run_query

N_USERS, N_MSGS = 4000, 12000
SMOKE_USERS, SMOKE_MSGS = 400, 1200


def _timed(fn, repeat=3):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def _canon(rows):
    return sorted(repr(sorted(r.items(), key=lambda kv: kv[0]))
                  for r in rows)


def _plans(n_users):
    from repro.core.functions import spatial_distance, word_tokens
    lo, hi = dt.datetime(2010, 1, 1), dt.datetime(2010, 3, 1)
    mlo = dt.datetime(2014, 1, 15)
    center, radius = (33.5, -117.5), 0.12
    return {
        # selective point-ish range, full records out (boundary-bound)
        "btree_select": A.select(
            A.scan("MugshotUsers"),
            pred=lambda r: lo <= r["user-since"] <= hi,
            fields=["user-since"], ranges={"user-since": (lo, hi)},
            ranges_exact=True),
        # wide range feeding a fused aggregate: no row ever materializes
        "btree_agg": A.aggregate(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r: r["timestamp"] >= mlo,
                     fields=["timestamp"],
                     ranges={"timestamp": (mlo, None)}, ranges_exact=True),
            {"c": ("count", "*"), "av": ("avg", "author-id"),
             "mx": ("max", "timestamp")}),
        # two btree indexes: candidate bitmaps intersect before decode
        "multi_index_group": A.group_by(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r, k=n_users // 2:
                     r["timestamp"] >= mlo and r["author-id"] <= k,
                     fields=["timestamp", "author-id"],
                     ranges={"timestamp": (mlo, None),
                             "author-id": (None, n_users // 2)},
                     ranges_exact=True),
            ["author-id"], {"c": ("count", "*")}),
        "rtree_select": A.select(
            A.scan("MugshotMessages"),
            pred=lambda r: spatial_distance(r["sender-location"],
                                            center) <= radius,
            fields=["sender-location"],
            spatial=("sender-location", center, radius)),
        "keyword_agg": A.aggregate(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r: "tonight" in word_tokens(r["message"]),
                     fields=["message"],
                     keyword=("message", "tonight", 0)),
            {"c": ("count", "*"), "mn": ("min", "message-id")}),
    }


def run(smoke: bool = False) -> list:
    nu, nm = (SMOKE_USERS, SMOKE_MSGS) if smoke else (N_USERS, N_MSGS)
    _, ds = build_dataverse(nu, nm, num_partitions=4, flush_threshold=256)
    msgs = ds["MugshotMessages"]
    msgs.create_index("sender-location", kind="rtree")
    msgs.create_index("message", kind="keyword")
    rows = []
    repeat = 2 if smoke else 4
    for name, plan in _plans(nu).items():
        (res_r, t_r) = _timed(lambda p=plan: run_query(p, ds), repeat)
        # warm the jit caches outside the timed region
        run_query(plan, ds, vectorize=True)
        (res_c, t_c) = _timed(lambda p=plan: run_query(p, ds,
                                                       vectorize=True),
                              repeat)
        assert _canon(res_r[0]) == _canon(res_c[0]), \
            f"{name}: columnar results diverge from the row engine"
        ex = res_c[1]
        assert ex.stats.rows_index_vectorized > 0, \
            f"{name}: index access path silently fell back to the row engine"
        assert ex.stats.rows_fallback == 0, \
            f"{name}: {ex.stats.rows_fallback} rows fell back"
        rows.append({
            "bench": f"index_{name}",
            "us_per_call": t_r * 1e6,
            "us_columnar": t_c * 1e6,
            "derived": f"columnar {t_r / t_c:.1f}x vs row engine "
                       f"({len(res_c[0])} rows out, "
                       f"{ex.stats.rows_index_vectorized} idx-vec rows)",
        })
    return rows


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small dataset, fewer repeats (CI gate)")
    args = p.parse_args()
    t0 = time.time()
    out = run(smoke=args.smoke)
    print("name,us_per_call,us_columnar,derived")
    for r in out:
        print(f"{r['bench']},{r['us_per_call']:.1f},"
              f"{r['us_columnar']:.1f},{r['derived']}")
    print(f"# index_bench done in {time.time() - t0:.1f}s "
          f"({'smoke' if args.smoke else 'full'})", file=sys.stderr)


if __name__ == "__main__":
    main()
