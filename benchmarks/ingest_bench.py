"""Ingest pipeline: feed -> flush -> tiered merge -> filter+aggregate scan,
legacy row path vs columnar-native storage.

The row path is the pre-refactor architecture, kept addressable as
``PartitionedDataset(columnar=False)``: a feed stores one record at a
time, flushes build object-array row components, merges run the dict
k-way pass, and the scan runs the row engine.  The columnar-native path
is the refactored spine: the feed accumulates micro-batches into a
``DatasetSink`` delivered via ``insert_batch``, flushes shred straight
into component ColumnBatches, merges gather columns through the
``sorted_merge_take`` kernel, and the scan runs vectorized.

Reported: rows/sec ingested (intake -> store, flushes + policy merges
included), wall-time of a final merge collapsing each partition's
components, the scan stage (SCAN_ROUNDS rounds of the filter+aggregate
plans — the standing analytics a feed-fed dataset exists to serve), and
the end-to-end ratio.  Results are asserted identical between paths;
``--smoke`` (run by scripts/verify.sh) shrinks sizes and skips the
speedup assertion (timings are noisy at CI scale — the full run must
show >= 2x end to end).

Usage: PYTHONPATH=src python -m benchmarks.ingest_bench [--smoke]
"""

from __future__ import annotations

import argparse
import datetime as dt
import sys

from repro.configs.tinysocial import gen_messages, message_type
from repro.core import algebra as A
from repro.core.lsm import TieredMergePolicy
from repro.data.feeds import DatasetSink, Feed, SocketAdaptor
from repro.storage.dataset import PartitionedDataset
from repro.storage.query import run_query

from ._timing import stopwatch

N_MSGS, N_USERS = 40000, 4000
SMOKE_MSGS, SMOKE_USERS = 3000, 300
PUMP, MICRO_BATCH = 1024, 512


def _canon(rows):
    return sorted(repr(sorted(r.items(), key=lambda kv: kv[0]))
                  for r in rows)


SCAN_ROUNDS = 25        # post-ingest analytics: each round re-runs the
#                         filter+aggregate plans over the merged dataset


def _scan_plans():
    mlo = dt.datetime(2014, 2, 1)
    span = (dt.datetime(2014, 1, 10), dt.datetime(2014, 3, 20))
    return [
        A.aggregate(
            A.select(A.scan("M"),
                     pred=lambda r: r["timestamp"] >= mlo,
                     fields=["timestamp"], ranges={"timestamp": (mlo, None)},
                     ranges_exact=True),
            {"c": ("count", "*"), "av": ("avg", "author-id"),
             "mx": ("max", "message-id")}),
        A.aggregate(
            A.select(A.scan("M"),
                     pred=lambda r: span[0] <= r["timestamp"] <= span[1],
                     fields=["timestamp"], ranges={"timestamp": span},
                     ranges_exact=True),
            {"c": ("count", "*"), "mn": ("min", "author-id")}),
    ]


def run_pipeline(columnar: bool, msgs, parts: int = 4,
                 threshold: int = 1024, scan_rounds: int = SCAN_ROUNDS):
    ds = PartitionedDataset("M", message_type(), "message-id",
                            num_partitions=parts, flush_threshold=threshold,
                            merge_policy=TieredMergePolicy(k=4),
                            columnar=columnar)
    sock = SocketAdaptor()
    sock.push(msgs)
    if columnar:
        store = DatasetSink(ds, batch_size=MICRO_BATCH)
    else:                       # legacy: one record at a time into the store
        def store(recs):
            for r in recs:
                ds.insert(r)
    feed = Feed("ingest", adaptor=sock, store=store)

    with stopwatch() as sw_ingest:
        while feed.pump(PUMP):
            pass
        if columnar:
            store.flush()       # tail micro-batch
        for part in ds.partitions:  # end-of-stream: flush memtables
            part.primary.flush()
    t_ingest = sw_ingest.seconds

    with stopwatch() as sw_merge:  # tiered backstop: collapse partitions
        for part in ds.partitions:
            valid = [c for c in part.primary.components if c.valid]
            if len(valid) >= 2:
                part.primary.merge(valid)
    t_merge = sw_merge.seconds

    plans = _scan_plans()
    with stopwatch() as sw_scan:
        rows = []
        for _ in range(scan_rounds):
            rows = [run_query(p, {"M": ds}, vectorize=columnar)[0][0]
                    for p in plans]
    t_scan = sw_scan.seconds
    return ds, rows, {"ingest": t_ingest, "merge": t_merge, "scan": t_scan,
                      "total": t_ingest + t_merge + t_scan}


def run(smoke: bool = False) -> list:
    nm, nu = (SMOKE_MSGS, SMOKE_USERS) if smoke else (N_MSGS, N_USERS)
    msgs = gen_messages(nm, nu)
    threshold = 256 if smoke else 1024
    speedup = 0.0
    # best of two attempts: wall-clock pipelines are sensitive to noisy
    # neighbors, and one clean execution is what the 2x claim is about
    for attempt in range(1 if smoke else 2):
        ds_r, rows_r, t_r = run_pipeline(False, msgs, threshold=threshold)
        ds_c, rows_c, t_c = run_pipeline(True, msgs, threshold=threshold)
        assert _canon(rows_r) == _canon(rows_c), \
            "columnar-native pipeline diverges from the row path"
        # the columnar path's components are batch-primary and nothing on
        # the ingest/merge/scan pipeline ever forced a row view
        for part in ds_c.partitions:
            for comp in part.primary.components:
                if comp.valid:
                    assert comp.batch is not None and comp._rows is None, \
                        "columnar pipeline forced a row view"
        speedup = max(speedup, t_r["total"] / t_c["total"])
        if speedup >= 2.0:
            break
    merges_c = sum(p.primary.stats["merges"] for p in ds_c.partitions)
    if not smoke:
        assert speedup >= 2.0, \
            f"end-to-end speedup {speedup:.2f}x < 2x (row {t_r['total']:.2f}s" \
            f" vs columnar {t_c['total']:.2f}s)"
    out = []
    for name, tr in (("row_path", t_r), ("columnar_native", t_c)):
        out.append({
            "bench": f"ingest_{name}",
            "rows_per_sec": nm / tr["ingest"],
            "merge_ms": tr["merge"] * 1e3,
            "scan_stage_ms": tr["scan"] * 1e3,
            "total_s": tr["total"],
            "derived": "",
        })
    out[-1]["derived"] = (
        f"columnar-native {speedup:.1f}x end-to-end vs row path "
        f"(ingest {t_r['ingest'] / t_c['ingest']:.1f}x, merge "
        f"{t_r['merge'] / max(t_c['merge'], 1e-9):.1f}x, scan "
        f"{t_r['scan'] / max(t_c['scan'], 1e-9):.1f}x; "
        f"{merges_c} policy merges during ingest)")
    return out


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small dataset, no speedup assertion (CI gate)")
    args = p.parse_args()
    with stopwatch() as sw:
        out = run(smoke=args.smoke)
    print("name,rows_per_sec,merge_ms,scan_stage_ms,total_s,derived")
    for r in out:
        print(f"{r['bench']},{r['rows_per_sec']:.0f},{r['merge_ms']:.1f},"
              f"{r['scan_stage_ms']:.1f},{r['total_s']:.2f},{r['derived']}")
    print(f"# ingest_bench done in {sw.seconds:.1f}s "
          f"({'smoke' if args.smoke else 'full'})", file=sys.stderr)


if __name__ == "__main__":
    main()
