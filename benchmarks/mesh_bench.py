"""Mesh-parallel SPMD runtime: the partition loop vs one shard_map dispatch.

Warm-path comparison of the two executor modes over the same dataset:
the per-partition Python loop (P fused dispatches + P device_gets per
query) against the SPMD partition mesh (operands stacked along a leading
partition axis, ONE ``shard_map`` dispatch for all partitions —
``runtime/spmd.py``).  Three benches cover the three lowered paths:

  mesh_index_chain   Figure-6 btree chain select (``plancache.run_all``)
  mesh_select        non-indexed range scan (``spmd.batched_range_masks``)
  mesh_agg_merge     fused filter+aggregate chain with local aggregation
                     (``spmd.batched_select_aggregate`` / chain agg mode)

Hard assertions: mesh rows must equal loop rows exactly, a warm mesh
query must ship zero host->device bytes and retrace nothing, and the
mesh mode must beat the warm loop.  The gain is dispatch amortization
(P per-partition dispatches + device_gets collapse into one), so the
threshold scales with how much of the query IS dispatch: the chain and
aggregate benches run device-side end to end and must hit >= 2x at full
size (32 partitions, 4-device mesh); the scan-path select still filters
row output per partition on the host, so its bar is >= 1.2x.  Smoke
sizes leave almost no dispatch cost to amortize (250-row partitions) —
there the bars are 1.05x / 0.7x, a regression tripwire rather than a
performance claim; scripts/verify.sh runs ``--smoke``.

The mesh needs >= 4 devices, and ``XLA_FLAGS=--xla_force_host_platform_
device_count=4`` only takes effect before jax is first imported — so
when the current process has fewer devices, ``run()`` re-execs itself as
a subprocess with the flag set and ``--emit-json``, then relays the
rows.  CI's forced-multi-device leg runs the bench in-process.

Usage: PYTHONPATH=src python -m benchmarks.mesh_bench [--smoke]
                                                      [--emit-json PATH]
"""

from __future__ import annotations

import argparse
import datetime as dt
import json
import os
import subprocess
import sys
import tempfile

from ._timing import stopwatch, timed as _timed

FULL_USERS, FULL_MSGS, FULL_PARTS = 2000, 16000, 32
SMOKE_USERS, SMOKE_MSGS, SMOKE_PARTS = 600, 2000, 8
MESH_DEVICES = 4
_FORCE_FLAG = f"--xla_force_host_platform_device_count={MESH_DEVICES}"


def _canon(rows):
    return sorted(repr(sorted(r.items(), key=lambda kv: kv[0]))
                  for r in rows)


def _plans():
    from repro.core import algebra as A
    # selective range: the warm cost is the chain dispatch itself, not
    # host row decode, so dispatch amortization is what gets measured
    lo, hi = dt.datetime(2010, 1, 1), dt.datetime(2010, 3, 1)
    mlo = dt.datetime(2014, 1, 15)
    return {
        "mesh_index_chain": A.select(
            A.scan("MugshotUsers"),
            pred=lambda r: lo <= r["user-since"] <= hi,
            fields=["user-since"], ranges={"user-since": (lo, hi)},
            ranges_exact=True),
        # message-id has no index: lowers to scan + range mask, which
        # the mesh runs as one stacked spmd_range_mask dispatch
        "mesh_select": A.select(
            A.scan("MugshotMessages"),
            pred=lambda r: 100 <= r["message-id"] <= 900,
            fields=["message-id"], ranges={"message-id": (100, 900)},
            ranges_exact=True),
        "mesh_agg_merge": A.aggregate(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r: r["timestamp"] >= mlo,
                     fields=["timestamp"],
                     ranges={"timestamp": (mlo, None)},
                     ranges_exact=True),
            {"c": ("count", "*"), "av": ("avg", "author-id"),
             "mx": ("max", "timestamp")}),
    }


def _run_local(smoke: bool = False) -> list:
    """The actual bench; requires >= MESH_DEVICES jax devices."""
    import jax

    from repro.configs.tinysocial import build_dataverse
    from repro.storage.query import run_query

    n_dev = len(jax.devices())
    assert n_dev >= MESH_DEVICES, \
        f"mesh bench needs {MESH_DEVICES} devices, have {n_dev} " \
        f"(set XLA_FLAGS={_FORCE_FLAG} before jax imports)"
    nu, nm, parts = (SMOKE_USERS, SMOKE_MSGS, SMOKE_PARTS) if smoke \
        else (FULL_USERS, FULL_MSGS, FULL_PARTS)
    _, ds = build_dataverse(nu, nm, num_partitions=parts,
                            flush_threshold=512)
    repeat = 5 if smoke else 20
    bars = {"mesh_index_chain": 1.05, "mesh_select": 0.7,
            "mesh_agg_merge": 1.05} if smoke else \
        {"mesh_index_chain": 2.0, "mesh_select": 1.2,
         "mesh_agg_merge": 2.0}
    rows = []
    for name, plan in _plans().items():
        # warm both modes fully (trace + upload), then time steady state
        res_l, _ = run_query(plan, ds, vectorize=True)
        run_query(plan, ds, vectorize=True)
        ((_, ex_l), t_loop) = _timed(
            lambda p=plan: run_query(p, ds, vectorize=True), repeat)
        res_m, _ = run_query(plan, ds, vectorize=True, mesh=MESH_DEVICES)
        run_query(plan, ds, vectorize=True, mesh=MESH_DEVICES)
        ((_, ex_m), t_mesh) = _timed(
            lambda p=plan: run_query(p, ds, vectorize=True,
                                     mesh=MESH_DEVICES), repeat)
        assert _canon(res_l) == _canon(res_m), \
            f"{name}: mesh rows diverge from the loop " \
            f"({len(res_l)} vs {len(res_m)})"
        assert ex_m.stats.spmd_dispatches >= 1, \
            f"{name}: mesh mode never dispatched SPMD"
        assert ex_m.stats.h2d_bytes == 0, \
            f"{name}: warm mesh query shipped {ex_m.stats.h2d_bytes} B " \
            f"host->device"
        assert ex_m.stats.kernel_retraces == 0, \
            f"{name}: warm mesh query retraced " \
            f"{ex_m.stats.kernel_retraces} cores"
        assert ex_l.stats.h2d_bytes == 0 \
            and ex_l.stats.kernel_retraces == 0, \
            f"{name}: loop baseline was not warm"
        speedup = t_loop / t_mesh
        assert speedup >= bars[name], \
            f"{name}: mesh only {speedup:.2f}x vs the partition loop " \
            f"(need >= {bars[name]}x at {parts} partitions)"
        rows.append({
            "bench": name,
            "us_per_call": t_mesh * 1e6,
            "us_loop": t_loop * 1e6,
            "speedup": round(speedup, 2),
            "partitions": parts,
            "spmd_dispatches": ex_m.stats.spmd_dispatches,
            "h2d_warm": ex_m.stats.h2d_bytes,
            "retraces_warm": ex_m.stats.kernel_retraces,
            "derived": f"{speedup:.1f}x vs {parts}-partition loop, "
                       f"{ex_m.stats.spmd_dispatches} SPMD dispatch(es), "
                       f"warm ships 0 B",
        })
    return rows


def run(smoke: bool = False) -> list:
    """Bench entry point for ``benchmarks.run``.  Re-execs with forced
    host devices when this process can't host the mesh (XLA only honors
    the flag before jax's first import)."""
    import jax
    if len(jax.devices()) >= MESH_DEVICES:
        return _run_local(smoke)
    with tempfile.NamedTemporaryFile("r", suffix=".json") as f:
        cmd = [sys.executable, "-m", "benchmarks.mesh_bench",
               "--emit-json", f.name] + (["--smoke"] if smoke else [])
        env = dict(os.environ, XLA_FLAGS=_FORCE_FLAG)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in ("src", env.get("PYTHONPATH", "")) if p)
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"forced-multi-device subprocess failed:\n{proc.stdout}"
                f"\n{proc.stderr}")
        return json.load(f)["rows"]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small dataset, fewer repeats (CI gate)")
    p.add_argument("--emit-json", default="", metavar="PATH",
                   help="write {'rows': [...]} to PATH (subprocess "
                        "handshake; implies in-process execution)")
    args = p.parse_args()
    with stopwatch() as sw:
        out = _run_local(smoke=args.smoke) if args.emit_json \
            else run(smoke=args.smoke)
    if args.emit_json:
        with open(args.emit_json, "w") as f:
            json.dump({"rows": out}, f, default=str)
            f.write("\n")
    print("name,us_mesh,us_loop,speedup,partitions,h2d_warm,retraces_warm")
    for r in out:
        print(f"{r['bench']},{r['us_per_call']:.1f},{r['us_loop']:.1f},"
              f"{r['speedup']},{r['partitions']},{r['h2d_warm']},"
              f"{r['retraces_warm']}")
    print(f"# mesh_bench done in {sw.seconds:.1f}s "
          f"({'smoke' if args.smoke else 'full'})", file=sys.stderr)


if __name__ == "__main__":
    main()
