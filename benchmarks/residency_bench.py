"""Device residency: cold vs warm Figure-6 chains under the buffer pool
and fused plan cache.

The first execution of an index chain pays everything once: the fused
core traces, the pow2-padded columns and postings upload, the plan shape
enters the cache.  Every later execution of the same plan shape must be
one cached fused dispatch per partition over already-resident buffers —
``h2d_bytes == 0``, ``kernel_retraces == 0``, ``plan_cache_misses == 0``
— and at least 3x faster than the cold run.  A warm query that ships
bytes host->device, retraces, or misses the plan cache fails the bench
(scripts/verify.sh runs ``--smoke``).

Dataset sizes are deliberately offset from index_bench's so the pow2
buckets differ: when both smoke benches run in one process the fused
core must trace fresh here, keeping the cold measurement honest.

Usage: PYTHONPATH=src python -m benchmarks.residency_bench [--smoke]
"""

from __future__ import annotations

import argparse
import datetime as dt
import sys

from repro.configs.tinysocial import build_dataverse
from repro.core import algebra as A
from repro.kernels import device_pool as DP
from repro.storage.query import run_query

from ._timing import stopwatch, timed as _timed

N_USERS, N_MSGS = 6000, 18000
SMOKE_USERS, SMOKE_MSGS = 1000, 3000


def _canon(rows):
    return sorted(repr(sorted(r.items(), key=lambda kv: kv[0]))
                  for r in rows)


def _plans():
    lo, hi = dt.datetime(2010, 1, 1), dt.datetime(2010, 3, 1)
    mlo = dt.datetime(2014, 1, 15)
    return {
        # selective range, full records out: warm cost is the boundary
        # decode, the candidate chain itself is one resident dispatch
        "btree_select": A.select(
            A.scan("MugshotUsers"),
            pred=lambda r: lo <= r["user-since"] <= hi,
            fields=["user-since"], ranges={"user-since": (lo, hi)},
            ranges_exact=True),
        # wide range into a fused aggregate: no rows materialize, the
        # warm query is pure device work + one scalar row back
        "btree_agg": A.aggregate(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r: r["timestamp"] >= mlo,
                     fields=["timestamp"],
                     ranges={"timestamp": (mlo, None)}, ranges_exact=True),
            {"c": ("count", "*"), "av": ("avg", "author-id"),
             "mx": ("max", "timestamp")}),
    }


def run(smoke: bool = False) -> list:
    nu, nm = (SMOKE_USERS, SMOKE_MSGS) if smoke else (N_USERS, N_MSGS)
    _, ds = build_dataverse(nu, nm, num_partitions=4, flush_threshold=256)
    rows = []
    repeat = 3 if smoke else 5
    for name, plan in _plans().items():
        with stopwatch() as cold:
            res0, ex0 = run_query(plan, ds, vectorize=True)
        h2d_cold = ex0.stats.h2d_bytes
        assert ex0.stats.rows_fallback == 0, \
            f"{name}: cold run fell back to the row engine"
        assert ex0.stats.plan_cache_misses >= 1, \
            f"{name}: cold run never reached the fused plan cache"
        assert h2d_cold > 0, f"{name}: cold run uploaded nothing"
        ((res_w, ex_w), t_warm) = _timed(
            lambda p=plan: run_query(p, ds, vectorize=True), repeat)
        assert _canon(res_w) == _canon(res0), \
            f"{name}: warm results diverge from the cold run"
        assert ex_w.stats.h2d_bytes == 0, \
            f"{name}: warm query shipped {ex_w.stats.h2d_bytes} bytes " \
            f"host->device (buffer pool miss)"
        assert ex_w.stats.kernel_retraces == 0, \
            f"{name}: warm query retraced {ex_w.stats.kernel_retraces} cores"
        assert ex_w.stats.plan_cache_hits >= 1 \
            and ex_w.stats.plan_cache_misses == 0, \
            f"{name}: warm query missed the plan cache " \
            f"({ex_w.stats.plan_cache_hits} hits, " \
            f"{ex_w.stats.plan_cache_misses} misses)"
        speedup = cold.seconds / t_warm
        assert speedup >= 3.0, \
            f"{name}: warm only {speedup:.2f}x vs cold (need >= 3x)"
        pool = DP.pool.stats()
        rows.append({
            "bench": f"residency_{name}",
            "us_per_call": cold.seconds * 1e6,
            "us_warm": t_warm * 1e6,
            "speedup": round(speedup, 2),
            "h2d_cold": h2d_cold,
            "h2d_warm": ex_w.stats.h2d_bytes,
            "retraces_warm": ex_w.stats.kernel_retraces,
            "derived": f"warm {speedup:.1f}x vs cold, "
                       f"{h2d_cold} B uploaded once, "
                       f"{pool['resident_bytes']} B resident "
                       f"({len(res_w)} rows out)",
        })
    return rows


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small dataset, fewer repeats (CI gate)")
    args = p.parse_args()
    with stopwatch() as sw:
        out = run(smoke=args.smoke)
    print("name,us_cold,us_warm,speedup,h2d_cold,h2d_warm,retraces_warm")
    for r in out:
        print(f"{r['bench']},{r['us_per_call']:.1f},{r['us_warm']:.1f},"
              f"{r['speedup']},{r['h2d_cold']},{r['h2d_warm']},"
              f"{r['retraces_warm']}")
    print(f"# residency_bench done in {sw.seconds:.1f}s "
          f"({'smoke' if args.smoke else 'full'})", file=sys.stderr)


if __name__ == "__main__":
    main()
