"""Benchmark aggregator: one module per paper table + substrate benches.

Usage: PYTHONPATH=src python -m benchmarks.run [--only table3]
Prints ``name,us_per_call,derived`` CSV (plus table-specific columns).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="")
    args = p.parse_args()

    from . import (columnar_bench, feeds_bench, fuzzy_bench, index_bench,
                   ingest_bench, step_bench, table2_storage,
                   table3_queries, table4_inserts)
    modules = {
        "table2": table2_storage,
        "table3": table3_queries,
        "table4": table4_inserts,
        "columnar": columnar_bench,
        "index": index_bench,
        "fuzzy": fuzzy_bench,
        "ingest": ingest_bench,
        "feeds": feeds_bench,
        "steps": step_bench,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name},FAILED,{type(e).__name__}: {e}")
            failures += 1
            continue
        for r in rows:
            main_t = r.get("us_per_call", "")
            extra = r.get("derived", "")
            for k, v in r.items():
                if k not in ("bench", "us_per_call", "derived"):
                    extra += f" | {k}={v}"
            t_str = f"{main_t:.1f}" if isinstance(main_t, float) else main_t
            print(f"{r['bench']},{t_str},{extra}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
