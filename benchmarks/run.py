"""Benchmark aggregator: one module per paper table + substrate benches.

Usage: PYTHONPATH=src python -m benchmarks.run [--only table3] [--smoke]
                                               [--json out.json]

``--smoke`` drives the eight CI smoke benches (columnar / index /
residency / ingest / fuzzy / feeds / serve / mesh) at reduced sizes
with one combined exit code —
this is what ``scripts/verify.sh`` and the CI workflow invoke, replacing
the old per-bench invocations.  Each smoke bench carries its own hard
assertions (engine equivalence, no silent index/fuzzy fallback, zero
kernel retraces on repeated queries, zero host->device bytes on warm
chains, zero torn reads / lost acks under concurrent serving), so a
nonzero exit means a real regression, not a slow machine.

``--json out.json`` additionally writes a machine-readable report:

    {"schema_version": 1,
     "smoke": true,
     "benches": {"<bench>": {"us_per_call": ..., "module": "columnar",
                             ...bench-specific fields...}, ...},
    "modules": {"<module>": {"seconds": ...}},
     "metrics": {<obs metric snapshot taken after all benches ran>},
     "failures": ["<module>: <error>", ...]}

CI archives this file per run; ``scripts/verify.sh`` asserts it parses
and contains rows from every smoke module.

Prints ``name,us_per_call,derived`` CSV (plus table-specific columns).
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys

from repro import obs

from ._timing import stopwatch

SMOKE_MODULES = ("columnar", "index", "residency", "ingest", "fuzzy",
                 "feeds", "serve", "mesh")
JSON_SCHEMA_VERSION = 1


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="")
    p.add_argument("--smoke", action="store_true",
                   help="run the CI smoke benches (reduced sizes, "
                        "one exit code)")
    p.add_argument("--json", default="", metavar="PATH",
                   help="write a structured JSON report (bench rows + "
                        "obs metric snapshot) to PATH")
    args = p.parse_args()

    from . import (columnar_bench, feeds_bench, fuzzy_bench, index_bench,
                   ingest_bench, mesh_bench, residency_bench, serve_bench,
                   step_bench, table2_storage, table3_queries,
                   table4_inserts)
    modules = {
        "table2": table2_storage,
        "table3": table3_queries,
        "table4": table4_inserts,
        "columnar": columnar_bench,
        "index": index_bench,
        "residency": residency_bench,
        "fuzzy": fuzzy_bench,
        "ingest": ingest_bench,
        "feeds": feeds_bench,
        "serve": serve_bench,
        "mesh": mesh_bench,
        "steps": step_bench,
    }
    if args.smoke:
        modules = {k: modules[k] for k in SMOKE_MODULES}
    print("name,us_per_call,derived")
    report = {"schema_version": JSON_SCHEMA_VERSION, "smoke": args.smoke,
              "benches": {}, "modules": {}, "metrics": {}, "failures": []}
    for name, mod in modules.items():
        if args.only and args.only not in name:
            continue
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            with stopwatch() as sw:
                rows = mod.run(**kwargs)
        except Exception as e:  # noqa: BLE001
            print(f"{name},FAILED,{type(e).__name__}: {e}")
            report["failures"].append(f"{name}: {type(e).__name__}: {e}")
            continue
        report["modules"][name] = {"seconds": sw.seconds}
        for r in rows:
            main_t = r.get("us_per_call", "")
            extra = r.get("derived", "")
            for k, v in r.items():
                if k not in ("bench", "us_per_call", "derived"):
                    extra += f" | {k}={v}"
            t_str = f"{main_t:.1f}" if isinstance(main_t, float) else main_t
            print(f"{r['bench']},{t_str},{extra}")
            report["benches"][r["bench"]] = dict(
                {k: v for k, v in r.items() if k != "bench"}, module=name)
        print(f"# {name} done in {sw.seconds:.1f}s"
              f"{' (smoke)' if args.smoke else ''}", file=sys.stderr)
    if args.json:
        report["metrics"] = obs.snapshot()
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True, default=str)
            f.write("\n")
        print(f"# json report -> {args.json}", file=sys.stderr)
    sys.exit(1 if report["failures"] else 0)


if __name__ == "__main__":
    main()
