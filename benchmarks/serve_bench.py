"""Mixed ingest+query serving benchmark (paper §2.4/§4.4): N feed pump
threads and M snapshot-isolated query workers drive one
``PartitionedDataset`` through the admission-controlled
``repro.serve.ServeHarness``, with a mid-run checkpoint +
crash-and-recover to exercise at-least-once feed replay.

Hard assertions (smoke and full): zero torn reads, zero lost
acknowledged records (both live floor checks and the final scan), no
query-worker exceptions, nonzero sustained ingest, and — now that every
request carries a deadline — a *zero deadline-miss ledger* at smoke
load (``serve.slo.missed == 0`` and ``serve.slo.rejected_deadline ==
0`` under the generous smoke deadline): the numbers are only reported
if the concurrent run was correct *and* met its SLO.

Reported per row: sustained ingest rate (acked records/s), p50/p99
query latency from the ``serve.query.latency_s`` obs histogram, SLO
attainment, queue-wait p50/p99, and the phase that dominates tail
latency.  The ``serve_mixed_2x2_exported`` smoke row repeats the steady
-state row with the ``obs.serve_http()`` Prometheus exporter + rate
sampler live, scrapes ``/metrics`` after the run, and reports the
ingest-rate parity vs the exporter-off row (the exporter must be
near-free; the hard bound is intentionally loose because two
thread-scheduled runs already differ run-to-run).

Usage: PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""

from __future__ import annotations

import urllib.request

from repro import obs
from repro.core import adm
from repro.core.lsm import TieredMergePolicy
from repro.serve import ServeHarness
from repro.storage.dataset import PartitionedDataset

# exporter-on vs exporter-off sustained-ingest ratio floor: measured
# parity is ~1.0 (±10%); the assert is looser only because two
# concurrent runs differ by thread-scheduling noise alone
EXPORT_PARITY_FLOOR = 0.5


def _dataset(flush_threshold: int) -> PartitionedDataset:
    rt = adm.RecordType("ServedType",
                        (adm.Field("pk", adm.INT64),
                         adm.Field("val", adm.INT64),
                         adm.Field("text", adm.STRING)),
                        open=True)
    return PartitionedDataset("served", rt, "pk", num_partitions=4,
                              flush_threshold=flush_threshold,
                              merge_policy=TieredMergePolicy(k=3))


def _drive(name: str, *, n_ingest: int, n_query: int, per_lane: int,
           duration_s: float, deadline_s: float, crash: bool = False,
           smoke: bool = False, exporter: bool = False) -> dict:
    ds = _dataset(flush_threshold=256)
    h = ServeHarness(ds, n_ingest=n_ingest, n_query=n_query,
                     pump_batch=64, records_per_lane=per_lane,
                     deadline_s=deadline_s)
    total = n_ingest * per_lane
    server = None
    metrics_text = ""
    if exporter:
        server = obs.serve_http(port=0, sample_interval_s=0.25,
                                trace_source=h.tracker.profile_spans)
    try:
        rep = h.run(duration_s=duration_s,
                    checkpoint_after=total // 4 if crash else None,
                    crash_after=total // 2 if crash else None)
        if server is not None:
            metrics_text = urllib.request.urlopen(
                server.url + "/metrics", timeout=10).read().decode()
    finally:
        if server is not None:
            server.stop()
    d = rep.as_dict()
    assert d["torn_reads"] == 0, f"{name}: torn reads {d['torn_reads']}"
    assert d["lost_acks"] == 0, f"{name}: lost-ack reads {d['lost_acks']}"
    assert d["lost_acked_final"] == 0, \
        f"{name}: acked records missing from final scan"
    assert not d["query_errors"], f"{name}: {d['query_errors'][:3]}"
    assert d["ingest_acked"] >= n_ingest * per_lane, \
        f"{name}: only {d['ingest_acked']} acked"
    assert d["ingest_rate"] > 0, f"{name}: zero sustained ingest"
    assert d["queries"] > 0 and d["query_p99_ms"] is not None, \
        f"{name}: no query latency measured"
    assert d["queue_wait_p99_ms"] is not None, \
        f"{name}: no queue wait measured"
    assert d["slo"]["attained"] > 0, f"{name}: no request met its SLO"
    if smoke:
        # zero deadline-miss ledger at smoke load: the generous smoke
        # deadline must never be blown, by completion or by admission
        assert d["slo"]["missed"] == 0, \
            f"{name}: {d['slo']['missed']} deadline misses at smoke load"
        assert d["slo"]["rejected_deadline"] == 0, \
            f"{name}: deadline-rejected requests at smoke load"
    if exporter:
        # the scrape must be real Prometheus text covering the serve tier
        assert "# TYPE serve_ingest_acked counter" in metrics_text, \
            f"{name}: /metrics missing serve counters"
        assert "serve_queue_wait_s" in metrics_text, \
            f"{name}: /metrics missing queue-wait summary"
    return {"bench": name,
            "us_per_call": 1e6 / d["ingest_rate"],
            "ingest_rate": round(d["ingest_rate"], 1),
            "ingest_acked": d["ingest_acked"],
            "queries": d["queries"],
            "admission_rejected": d["admission_rejected"],
            "query_p50_ms": round(d["query_p50_ms"], 3),
            "query_p99_ms": round(d["query_p99_ms"], 3),
            "queue_wait_p50_ms": round(d["queue_wait_p50_ms"], 3),
            "queue_wait_p99_ms": round(d["queue_wait_p99_ms"], 3),
            "slo_attained": d["slo"]["attained"],
            "slo_missed": d["slo"]["missed"],
            "slo_rejected_deadline": d["slo"]["rejected_deadline"],
            "slo_attainment": d["slo"]["attainment"],
            "deadline_miss_rate": d["deadline_miss_rate"],
            "slowest_phase_p99": d["slowest_phase_p99"],
            "torn_reads": d["torn_reads"],
            "lost_acked": d["lost_acked_final"] + d["lost_acks"],
            "recoveries": d["recoveries"],
            "derived": f"{d['ingest_rate']:.0f} rec/s, "
                       f"p99 {d['query_p99_ms']:.1f}ms, "
                       f"{d['queries']} queries, "
                       f"slo {d['slo']['attained']}/{d['slo']['attained'] + d['slo']['missed']}"}


def run(smoke: bool = False) -> list:
    per_lane = 1500 if smoke else 8000
    budget = 20.0 if smoke else 90.0
    deadline = 5.0 if smoke else 15.0
    rows = [
        # steady state: 2 ingest lanes + 2 query workers
        _drive("serve_mixed_2x2", n_ingest=2, n_query=2,
               per_lane=per_lane, duration_s=budget, deadline_s=deadline,
               smoke=smoke),
        # fault injection: checkpoint, crash, WAL recovery + feed replay
        _drive("serve_crash_replay", n_ingest=2, n_query=2,
               per_lane=per_lane, duration_s=budget, deadline_s=deadline,
               smoke=smoke, crash=True),
    ]
    if smoke:
        # steady state again, exporter + rate sampler live: the serving
        # numbers must stay at parity with the exporter stopped
        exported = _drive("serve_mixed_2x2_exported", n_ingest=2, n_query=2,
                          per_lane=per_lane, duration_s=budget,
                          deadline_s=deadline, smoke=True, exporter=True)
        parity = exported["ingest_rate"] / rows[0]["ingest_rate"]
        exported["export_parity"] = round(parity, 3)
        exported["derived"] += f", parity {parity:.2f}x"
        assert parity >= EXPORT_PARITY_FLOOR, \
            f"exporter cost: ingest parity {parity:.2f}x < " \
            f"{EXPORT_PARITY_FLOOR}x of exporter-off row"
        rows.append(exported)
    else:
        rows.append(_drive("serve_mixed_4x4", n_ingest=4, n_query=4,
                           per_lane=per_lane, duration_s=budget,
                           deadline_s=deadline))
    return rows


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    args = p.parse_args()
    for r in run(smoke=args.smoke):
        print(r)
