"""Mixed ingest+query serving benchmark (paper §2.4/§4.4): N feed pump
threads and M snapshot-isolated query workers drive one
``PartitionedDataset`` through the admission-controlled
``repro.serve.ServeHarness``, with a mid-run checkpoint +
crash-and-recover to exercise at-least-once feed replay.

Hard assertions (smoke and full): zero torn reads, zero lost
acknowledged records (both live floor checks and the final scan), no
query-worker exceptions, and nonzero sustained ingest — the numbers are
only reported if the concurrent run was *correct*.

Reported per row: sustained ingest rate (acked records/s) and p50/p99
query latency from the ``serve.query.latency_s`` obs histogram.

Usage: PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""

from __future__ import annotations

from repro.core import adm
from repro.core.lsm import TieredMergePolicy
from repro.serve import ServeHarness
from repro.storage.dataset import PartitionedDataset


def _dataset(flush_threshold: int) -> PartitionedDataset:
    rt = adm.RecordType("ServedType",
                        (adm.Field("pk", adm.INT64),
                         adm.Field("val", adm.INT64),
                         adm.Field("text", adm.STRING)),
                        open=True)
    return PartitionedDataset("served", rt, "pk", num_partitions=4,
                              flush_threshold=flush_threshold,
                              merge_policy=TieredMergePolicy(k=3))


def _drive(name: str, *, n_ingest: int, n_query: int, per_lane: int,
           duration_s: float, crash: bool = False) -> dict:
    ds = _dataset(flush_threshold=256)
    h = ServeHarness(ds, n_ingest=n_ingest, n_query=n_query,
                     pump_batch=64, records_per_lane=per_lane)
    total = n_ingest * per_lane
    rep = h.run(duration_s=duration_s,
                checkpoint_after=total // 4 if crash else None,
                crash_after=total // 2 if crash else None)
    d = rep.as_dict()
    assert d["torn_reads"] == 0, f"{name}: torn reads {d['torn_reads']}"
    assert d["lost_acks"] == 0, f"{name}: lost-ack reads {d['lost_acks']}"
    assert d["lost_acked_final"] == 0, \
        f"{name}: acked records missing from final scan"
    assert not d["query_errors"], f"{name}: {d['query_errors'][:3]}"
    assert d["ingest_acked"] >= n_ingest * per_lane, \
        f"{name}: only {d['ingest_acked']} acked"
    assert d["ingest_rate"] > 0, f"{name}: zero sustained ingest"
    assert d["queries"] > 0 and d["query_p99_ms"] is not None, \
        f"{name}: no query latency measured"
    return {"bench": name,
            "us_per_call": 1e6 / d["ingest_rate"],
            "ingest_rate": round(d["ingest_rate"], 1),
            "ingest_acked": d["ingest_acked"],
            "queries": d["queries"],
            "admission_rejected": d["admission_rejected"],
            "query_p50_ms": round(d["query_p50_ms"], 3),
            "query_p99_ms": round(d["query_p99_ms"], 3),
            "torn_reads": d["torn_reads"],
            "lost_acked": d["lost_acked_final"] + d["lost_acks"],
            "recoveries": d["recoveries"],
            "derived": f"{d['ingest_rate']:.0f} rec/s, "
                       f"p99 {d['query_p99_ms']:.1f}ms, "
                       f"{d['queries']} queries"}


def run(smoke: bool = False) -> list:
    per_lane = 1500 if smoke else 8000
    budget = 20.0 if smoke else 90.0
    rows = [
        # steady state: 2 ingest lanes + 2 query workers
        _drive("serve_mixed_2x2", n_ingest=2, n_query=2,
               per_lane=per_lane, duration_s=budget),
        # fault injection: checkpoint, crash, WAL recovery + feed replay
        _drive("serve_crash_replay", n_ingest=2, n_query=2,
               per_lane=per_lane, duration_s=budget, crash=True),
    ]
    if not smoke:
        rows.append(_drive("serve_mixed_4x4", n_ingest=4, n_query=4,
                           per_lane=per_lane, duration_s=budget))
    return rows


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    args = p.parse_args()
    for r in run(smoke=args.smoke):
        print(r)
