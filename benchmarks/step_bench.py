"""LM substrate benches: reduced-config train/decode step wall-time on CPU
(the "one size fits a bunch" breadth claim: the same runtime serves BDMS
queries, feeds, AND the training/serving steps) + kernel interpret checks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models import model as M
from repro.models.layers import init_params
from repro.optim.adamw import OptimizerConfig
from repro.training.train_step import init_train_state, make_train_step

from ._timing import timed


def _bench(fn, *args, warmup=2, repeat=3):
    return timed(lambda: fn(*args), repeat=repeat, warmup=warmup,
                 block=jax.block_until_ready)[1]


def run() -> list:
    rows = []
    for arch in ("deepseek-67b", "olmoe-1b-7b", "jamba-v0.1-52b",
                 "xlstm-125m"):
        cfg = reduced(get_config(arch))
        params = init_params(M.model_specs(cfg), jax.random.key(0),
                             jnp.float32)
        B, S = 4, 64
        toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :S], "labels": toks[:, 1:]}
        if cfg.prefix_len:
            batch["prefix_emb"] = jnp.zeros((B, cfg.prefix_len, cfg.d_model))
        step = jax.jit(make_train_step(cfg, OptimizerConfig()))
        opt = init_train_state(params, OptimizerConfig())

        def run_step():
            p2, o2, m = step(params, opt, batch)
            return m["loss"]

        t = _bench(run_step)
        tok_s = B * S / t
        rows.append({"bench": f"train_step_{arch}",
                     "us_per_call": t * 1e6,
                     "derived": f"reduced cfg, {tok_s:.0f} tok/s CPU"})
    return rows
