"""Table 2 analogue: dataset storage size, Schema (all fields declared)
vs KeyOnly (only the primary key declared; everything else open fields).

The paper reports Users 192 vs 360 GB, Messages 120 vs 240 GB, Tweets
330 vs 600 GB — KeyOnly ~1.8-2x larger because open fields carry their
names inline.  We reproduce the *ratio* on the TinySocial generators.
"""

from __future__ import annotations

import time

from repro.configs.tinysocial import (gen_messages, gen_users, message_type,
                                      user_type)


def run() -> list:
    users = gen_users(400)
    msgs = gen_messages(2000, 400)
    rows = []
    for name, dtype, data, pk in [
            ("users", user_type(), users, "id"),
            ("messages", message_type(), msgs, "message-id")]:
        schema_bytes = sum(dtype.encoded_size(r) for r in data)
        key_only = dtype.key_only(pk)
        keyonly_bytes = sum(key_only.encoded_size(r) for r in data)
        rows.append({
            "bench": f"table2_{name}",
            "schema_bytes": schema_bytes,
            "keyonly_bytes": keyonly_bytes,
            "ratio": round(keyonly_bytes / schema_bytes, 3),
            "paper_ratio": {"users": round(360 / 192, 3),
                            "messages": round(240 / 120, 3)}[name],
        })
    return rows
