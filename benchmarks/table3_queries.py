"""Table 3 analogue: the paper's query classes, indexed vs full-scan paths.

The paper compares absolute times against System-X/Hive/MongoDB on a 10-node
cluster; on one host we reproduce the paper's *structural* claims instead:

  * record lookup touches one partition;
  * a secondary index turns a range scan from O(N) into O(result);
  * select-join with small/large selectivity: indexed nested-loop vs hash;
  * aggregation splits local/global (Figure 6), moving O(partitions) rows;
  * grouped top-K with limit-into-sort moves O(K·partitions) rows
    (the beyond-paper R5 rewrite — §5.3.2 lists its absence as a gap).
"""

from __future__ import annotations

import datetime as dt

from repro.configs.tinysocial import build_dataverse
from repro.core import algebra as A
from repro.core.rewriter import RewriteConfig
from repro.storage.query import run_query

N_USERS, N_MSGS = 4000, 12000


from ._timing import timed as _timed


def run() -> list:
    _, ds = build_dataverse(N_USERS, N_MSGS, num_partitions=4,
                            flush_threshold=256)
    rows = []
    lo, hi = dt.datetime(2010, 1, 1), dt.datetime(2010, 2, 1)
    mlo = dt.datetime(2014, 2, 1)

    # -- record lookup ------------------------------------------------------
    (r, t) = _timed(lambda: ds["MugshotUsers"].lookup(123))
    rows.append({"bench": "table3_rec_lookup", "us_per_call": t * 1e6,
                 "derived": "routed to 1 of 4 partitions"})

    # -- range scan ± index -------------------------------------------------
    plan = A.select(A.scan("MugshotUsers"),
                    pred=lambda rr: lo <= rr["user-since"] <= hi,
                    fields=["user-since"], ranges={"user-since": (lo, hi)})
    (res_ix, t_ix) = _timed(lambda: run_query(plan, ds))
    (res_sc, t_sc) = _timed(lambda: run_query(
        plan, ds, config=RewriteConfig(use_indexes=False)))
    assert sorted(r["id"] for r in res_ix[0]) == \
        sorted(r["id"] for r in res_sc[0])
    rows.append({"bench": "table3_range_scan", "us_per_call": t_sc * 1e6,
                 "us_with_index": t_ix * 1e6,
                 "derived": f"speedup {t_sc / t_ix:.1f}x, "
                            f"{len(res_ix[0])} rows"})

    # -- the same index plan through the columnar engine --------------------
    run_query(plan, ds, vectorize=True)      # warm jit caches
    (res_iv, t_iv) = _timed(lambda: run_query(plan, ds, vectorize=True))
    assert sorted(r["id"] for r in res_iv[0]) == \
        sorted(r["id"] for r in res_ix[0])   # zero result diffs
    assert res_iv[1].stats.rows_index_vectorized > 0
    assert res_iv[1].stats.rows_fallback == 0
    rows.append({"bench": "table3_range_scan_columnar",
                 "us_per_call": t_ix * 1e6,
                 "us_columnar": t_iv * 1e6,
                 "derived": f"vectorized index path {t_ix / t_iv:.1f}x vs "
                            f"row index path "
                            f"({res_iv[1].stats.rows_index_vectorized} "
                            f"idx-vec rows)"})

    # -- select-join (small & large selectivity) ± index --------------------
    for sel_name, m_hi in [("sm", dt.datetime(2014, 1, 4)),
                           ("lg", dt.datetime(2014, 2, 15))]:
        sel = A.select(A.scan("MugshotMessages"),
                       pred=lambda rr, h=m_hi: rr["timestamp"] <= h,
                       fields=["timestamp"],
                       ranges={"timestamp": (dt.datetime(2014, 1, 1), m_hi)})
        plan_h = A.join(sel, A.scan("MugshotUsers"), ["author-id"], ["id"])
        plan_nl = A.join(sel, A.scan("MugshotUsers"), ["author-id"], ["id"],
                         hints=["indexnl"])
        (res_h, t_h) = _timed(lambda: run_query(plan_h, ds))
        (res_nl, t_nl) = _timed(lambda: run_query(plan_nl, ds))
        assert len(res_h[0]) == len(res_nl[0])
        rows.append({"bench": f"table3_sel_join_{sel_name}",
                     "us_per_call": t_h * 1e6,
                     "us_with_index": t_nl * 1e6,
                     "derived": f"{len(res_h[0])} rows; indexnl hint "
                                f"{t_h / max(t_nl, 1e-9):.1f}x vs hash"})

    # -- aggregation: local/global split (Figure 6) --------------------------
    agg = A.aggregate(A.select(A.scan("MugshotMessages"),
                               pred=lambda rr: rr["timestamp"] >= mlo,
                               fields=["timestamp"],
                               ranges={"timestamp": (mlo,
                                                     dt.datetime(2015, 1, 1))}),
                      {"cnt": ("count", "*"), "avg_author": ("avg",
                                                             "author-id")})
    (res_s, t_s) = _timed(lambda: run_query(agg, ds))
    (res_n, t_n) = _timed(lambda: run_query(
        agg, ds, config=RewriteConfig(split_aggregation=False)))

    # -- the same aggregate, row engine vs columnar engine ------------------
    agg_v = A.aggregate(
        A.select(A.scan("MugshotMessages"),
                 pred=lambda rr: rr["timestamp"] >= mlo,
                 fields=["timestamp"],
                 ranges={"timestamp": (mlo, dt.datetime(2015, 1, 1))},
                 ranges_exact=True, hints=["skip-index"]),
        {"cnt": ("count", "*"), "avg_author": ("avg", "author-id")})
    (res_vr, t_vr) = _timed(lambda: run_query(agg_v, ds))
    (res_vc, t_vc) = _timed(lambda: run_query(agg_v, ds, vectorize=True))
    from .columnar_bench import approx_equal
    assert approx_equal(res_vr[0], res_vc[0])   # exact on CPU; f32 on TPU
    rows.append({"bench": "table3_agg_columnar",
                 "us_per_call": t_vr * 1e6,
                 "us_columnar": t_vc * 1e6,
                 "derived": f"columnar engine {t_vr / t_vc:.1f}x vs "
                            f"row engine on the same plan "
                            f"({res_vc[1].stats.rows_vectorized} rows "
                            f"vectorized)"})
    moved_split = res_s[1].stats.rows_moved.get("ReplicateToOne", 0)
    moved_nosplit = res_n[1].stats.rows_moved.get("ReplicateToOne", 0)
    rows.append({"bench": "table3_agg",
                 "us_per_call": t_s * 1e6,
                 "derived": f"rows moved split={moved_split} vs "
                            f"nosplit={moved_nosplit} "
                            f"({moved_nosplit / max(moved_split, 1):.0f}x)"})

    # -- fuzzy select (ngram index) + fuzzy join ----------------------------
    from repro.data.dedup import FuzzyJoin
    from repro.fuzzy import fuzzy_predicate
    users = ds["MugshotUsers"]
    users.create_index("name", kind="ngram")
    spec = ("name", "ed", "User Number 123", 1)
    fz = A.select(A.scan("MugshotUsers"), pred=fuzzy_predicate(spec),
                  fields=["name"], fuzzy=spec)
    (res_fr, t_fr) = _timed(lambda: run_query(fz, ds))
    run_query(fz, ds, vectorize=True)        # warm jit caches
    (res_fc, t_fc) = _timed(lambda: run_query(fz, ds, vectorize=True))
    assert sorted(r["id"] for r in res_fc[0]) == \
        sorted(r["id"] for r in res_fr[0])
    assert res_fc[1].stats.rows_fuzzy_vectorized > 0
    assert res_fc[1].stats.rows_fallback == 0
    rows.append({"bench": "table3_fuzzy_select",
                 "us_per_call": t_fr * 1e6,
                 "us_columnar": t_fc * 1e6,
                 "derived": f"ngram T-occurrence chain {t_fr / t_fc:.1f}x "
                            f"vs row chain ({len(res_fc[0])} rows, "
                            f"{res_fc[1].stats.rows_fuzzy_vectorized} "
                            f"fuzzy-vec rows)"})
    join_recs = [(m["message-id"], set(m["tags"]))
                 for m in ds["MugshotMessages"].scan()[:1500]]
    (fj_out, t_fj) = _timed(
        lambda: FuzzyJoin(threshold=0.6).run(join_recs), repeat=1)
    rows.append({"bench": "table3_fuzzy_join",
                 "us_per_call": t_fj * 1e6,
                 "derived": f"{fj_out[1]['candidates']} candidates -> "
                            f"{fj_out[1]['pairs']} pairs "
                            f"(batched Jaccard verify)"})

    # -- grouped agg + top-K (limit-into-sort, beyond paper) ----------------
    grp = A.limit(A.order_by(
        A.group_by(A.scan("MugshotMessages"), ["author-id"],
                   {"cnt": ("count", "*")}), ["cnt"], desc=True), 10)
    (res_p, t_p) = _timed(lambda: run_query(grp, ds))
    (res_np, t_np) = _timed(lambda: run_query(
        grp, ds, config=RewriteConfig(push_limit_into_sort=False)))
    assert [r["cnt"] for r in res_p[0]] == [r["cnt"] for r in res_np[0]]
    rows.append({"bench": "table3_grp_topk",
                 "us_per_call": t_np * 1e6,
                 "us_with_index": t_p * 1e6,
                 "derived": f"limit-into-sort moves "
                            f"{res_p[1].stats.rows_moved.get('ReplicateToOne', 0)}"
                            f" vs {res_np[1].stats.rows_moved.get('ReplicateToOne', 0)} rows"})
    return rows
