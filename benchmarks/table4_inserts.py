"""Table 4 analogue: insert cost vs batch size (1 vs 20 vs 200).

Paper: single-record inserts cost 0.091s/record in AsterixDB vs 0.010s at
batch 20 — a ~9x amortization because *each statement pays Hyracks job
generation and start-up*.  Our steps are pre-compiled functions, so there is
no per-statement job-generation cost to amortize: per-record time should be
~flat across batch sizes.  That flat line IS the reproduction finding — the
paper's own diagnosis ("mainly due to Hyracks job generation and start-up
overheads") predicts the gap disappears when plans are compiled once, which
is exactly how the training-step side of this framework works too (one jit'd
step, millions of invocations).  LSM flush/merge counters confirm ingestion
cost stays amortized (no in-place index updates).
"""

from __future__ import annotations

from repro.configs.tinysocial import build_dataverse, gen_messages

from ._timing import stopwatch


def run() -> list:
    rows = []
    recs = gen_messages(4000, 400, seed=7)
    for batch in (1, 20, 200):
        _, ds = build_dataverse(50, 0, num_partitions=4,
                                flush_threshold=256)
        msgs = ds["MugshotMessages"]
        with stopwatch() as sw:
            for i in range(0, 2000, batch):
                msgs.insert_batch(recs[i:i + batch])
        stats = [p.primary.stats for p in msgs.partitions]
        rows.append({
            "bench": f"table4_insert_b{batch}",
            "us_per_call": sw.seconds / 2000 * 1e6,
            "derived": f"flushes={sum(s['flushes'] for s in stats)} "
                       f"merges={sum(s['merges'] for s in stats)}",
        })
    return rows
