"""BDMS tour: every query from the paper's §3 expressed on the engine, plus
feeds, fuzzy joins, and crash recovery — the full "one size fits a bunch"
demonstration.

Run: PYTHONPATH=src python examples/bdms_tour.py
"""

import datetime as dt

from repro.configs.tinysocial import build_dataverse, gen_messages
from repro.core import algebra as A
from repro.core.rewriter import Catalog, IndexInfo, RewriteConfig, explain
from repro.data.dedup import FuzzyJoin
from repro.data.feeds import Feed, SocketAdaptor
from repro.storage.query import run_query

dv, ds = build_dataverse(num_users=300, num_messages=1500)
users, msgs = ds["MugshotUsers"], ds["MugshotMessages"]

print("== Query 2: datetime range scan (index path) ==")
lo, hi = dt.datetime(2010, 7, 22), dt.datetime(2012, 7, 29)
plan = A.select(A.scan("MugshotUsers"),
                pred=lambda r: lo <= r["user-since"] <= hi,
                fields=["user-since"], ranges={"user-since": (lo, hi)})
rows, _ = run_query(plan, ds)
print(f"  {len(rows)} users joined in window")

print("== EXPLAIN (the Figure-6 physical plan) ==")
cat = Catalog(primary_keys={"MugshotUsers": ("id",),
                            "MugshotMessages": ("message-id",)},
              indexes=[IndexInfo("ix", "MugshotUsers", "user-since")],
              num_partitions=4)
print(explain(plan, cat))

print("== Query 3: equijoin ==")
plan = A.project(
    A.join(A.scan("MugshotMessages"), A.scan("MugshotUsers"),
           ["author-id"], ["id"]),
    ["name", "message"])
rows, ex = run_query(plan, ds)
print(f"  {len(rows)} (uname, message) pairs; "
      f"rows moved: {ex.stats.rows_moved}")

print("== Query 7: existential quantification over an OPEN field ==")
users.insert({"id": 9001, "alias": "pt", "name": "Part Timer",
              "user-since": dt.datetime(2013, 2, 2),
              "address": {"street": "1 A", "city": "irvine", "state": "CA",
                          "zip": "98765", "country": "USA"},
              "friend-ids": [], "employment": [],
              "job-kind": "part-time"})      # undeclared field!
plan = A.select(A.scan("MugshotUsers"),
                pred=lambda r: r.get("job-kind") == "part-time",
                fields=["job-kind"])
rows, _ = run_query(plan, ds)
print(f"  part-timers via open field: {[r['id'] for r in rows]}")

print("== Columnar engine: same plan, vectorized operators ==")
plan = A.aggregate(
    A.select(A.scan("MugshotMessages"),
             pred=lambda r: r["timestamp"] >= dt.datetime(2014, 2, 1),
             fields=["timestamp"],
             ranges={"timestamp": (dt.datetime(2014, 2, 1),
                                   dt.datetime(2030, 1, 1))},
             ranges_exact=True, hints=["skip-index"]),
    {"cnt": ("count", "*"), "avg_author": ("avg", "author-id")})
rows_row, _ = run_query(plan, ds)
rows_col, ex = run_query(plan, ds, vectorize=True)
assert rows_row[0]["cnt"] == rows_col[0]["cnt"]
assert abs(rows_row[0]["avg_author"] - rows_col[0]["avg_author"]) < 1e-3
print(f"  filter+aggregate fused on column batches: {rows_col[0]} "
      f"({ex.stats.rows_vectorized} rows vectorized, "
      f"{ex.stats.rows_fallback} fell back)")

print("== Query 10/11: aggregation + grouped top-k ==")
plan = A.aggregate(A.scan("MugshotMessages"),
                   {"n": ("count", "*"), "avg_author": ("avg", "author-id")})
rows, _ = run_query(plan, ds)
print(f"  global agg: {rows[0]}")
plan = A.limit(A.order_by(A.group_by(
    A.scan("MugshotMessages"), ["author-id"], {"cnt": ("count", "*")}),
    ["cnt"], desc=True), 3)
rows, _ = run_query(plan, ds)
print(f"  top-3 chatty: {rows}")

print("== Query 5: spatial selection (rtree index + post-validate) ==")
from repro.core.functions import spatial_distance, edit_distance_check, \
    word_tokens
msgs.create_index("sender-location", kind="rtree")
center, radius = (33.5, -117.5), 0.1
plan = A.select(A.scan("MugshotMessages"),
                pred=lambda r: spatial_distance(r["sender-location"],
                                                center) <= radius,
                fields=["sender-location"],
                spatial=("sender-location", center, radius))
rows, ex = run_query(plan, ds)
print(f"  {len(rows)} messages within {radius} of {center} "
      f"(index candidates: {ex.stats.op_rows['SPATIAL_INDEX_SEARCH']})")

print("== Query 6: fuzzy keyword selection (~= 'tonight', ed<=3) ==")
msgs.create_index("message", kind="keyword")
plan = A.select(A.scan("MugshotMessages"),
                pred=lambda r: any(edit_distance_check(t, "tonight", 3)
                                   for t in word_tokens(r["message"])),
                fields=["message"],
                keyword=("message", "tonight", 3))
rows, _ = run_query(plan, ds)
print(f"  {len(rows)} messages fuzzily mention 'tonight'")

print("== Query 13: fuzzy self-join on tags (Jaccard >= 0.3) ==")
sample = [(m["message-id"], set(m["tags"])) for m in msgs.scan()[:300]]
pairs, stats = FuzzyJoin(threshold=0.5).run(sample)
print(f"  {stats['pairs']} similar-tag pairs "
      f"({stats['candidates']} candidates vs "
      f"{len(sample) * (len(sample) - 1) // 2} brute pairs)")

print("== Data feeds (Data definition 4): socket -> UDF -> Dataset ==")
sock = SocketAdaptor()
n0 = len(msgs)
feed = Feed("socket_feed", adaptor=sock,
            udfs=[lambda r: r if len(r["tags"]) >= 2 else None],
            store=lambda rs: [msgs.insert(r) for r in rs])
sock.push(gen_messages(200, 300, seed=42)[100:])  # fresh message-ids? ids overlap
new = [dict(m, **{"message-id": 100000 + i})
       for i, m in enumerate(gen_messages(200, 300, seed=42))]
sock.queue.clear()
sock.push(new)
while feed.pump(64):
    pass
print(f"  ingested {len(msgs) - n0} (filtered {200 - (len(msgs) - n0)} "
      f"low-tag records); cursor={feed.cursor}")

print("== Update 2 + crash recovery (paper §4.4) ==")
users.delete(9001)
before = len(users)
users.crash_and_recover()
assert len(users) == before and users.lookup(9001) is None
print(f"  {before} users survive crash+recover; tombstone intact")
print("bdms_tour OK")
