"""EXPLAIN ANALYZE tour: profile the Figure-6 secondary-index chain.

Builds the TinySocial dataverse, runs an aggregate over a B+-tree index
range select with ``explain_analyze``, and pretty-prints the annotated
physical plan: per-operator wall time, rows in/out, lowering outcome
(columnar / fused / fallback+reason / row), kernel dispatches, and
host<->device transfer bytes.  Runs the same plan a second time to show
the device buffer pool and fused plan cache at work: the warm totals
collapse to ``h2d_bytes == 0`` with every plan shape a cache hit.  Then
repeats the run with the obs tracer enabled and dumps a Chrome-trace
timeline (open chrome://tracing or https://ui.perfetto.dev and load the
file).

Run: PYTHONPATH=src python examples/explain_analyze.py
"""

import datetime as dt

from repro import obs
from repro.configs.tinysocial import build_dataverse
from repro.core import algebra as A
from repro.storage.query import explain_analyze

dv, ds = build_dataverse(num_users=2000, num_messages=8000)

# Aggregate over an index-accelerated range select: the rewriter compiles
# SECONDARY_INDEX_SEARCH -> SORT -> PRIMARY_INDEX_LOOKUP -> POST_VALIDATE
# (Figure 6) and the columnar engine fuses the whole chain; the avg over
# a numeric column runs through the fused filter+aggregate kernel, so the
# report also shows dispatch counts and transfer bytes.
lo, hi = 100, 900
plan = A.aggregate(
    A.select(A.scan("MugshotMessages"),
             pred=lambda r: lo <= r["author-id"] <= hi,
             fields=["author-id"],
             ranges={"author-id": (lo, hi)}, ranges_exact=True),
    {"n": ("count", "*"), "avg_msg": ("avg", "message-id")})


def show(node, depth=0):
    pad = "  " * depth
    line = f"{pad}{node['op']} [{node.get('mode', '?')}]"
    if "wall_s" in node:
        line += (f"  wall={node['wall_s'] * 1e3:.2f}ms"
                 f" (self {node['self_wall_s'] * 1e3:.2f}ms)")
    if "rows_out" in node:
        line += f"  rows={node.get('rows_in', '?')}->{node['rows_out']}"
    if node.get("kernel_dispatches"):
        line += (f"  dispatches={node['kernel_dispatches']}"
                 f" h2d={node['h2d_bytes']}B d2h={node['d2h_bytes']}B")
    if node.get("rows_moved"):
        line += f"  moved={node['rows_moved']}"
    if "fallback_reason" in node:
        line += f"  !! {node['fallback_reason']}"
    print(line)
    for child in node["children"]:
        show(child, depth + 1)


report = explain_analyze(plan, ds)
print("== annotated physical plan ==")
show(report["plan"])
print("\n== totals ==")
for k, v in report["totals"].items():
    print(f"  {k}: {v}")
print(f"  fallback_reasons: {report['stats'].fallback_reasons}")
print(f"  rows_moved: {report['stats'].rows_moved}")

# Run the identical plan again: the cold run uploaded the padded columns
# and postings into the device buffer pool and traced the fused chain
# core, so the warm run is pure cache — h2d_bytes drops to 0 and every
# per-partition chain dispatch is a plan-cache hit.
report2 = explain_analyze(plan, ds)
t1, t2 = report["totals"], report2["totals"]
print("\n== warm re-run: device residency ==")
print(f"  h2d_bytes: {t1['h2d_bytes']} cold -> {t2['h2d_bytes']} warm")
print(f"  plan_cache: {t2['plan_cache_hits']} hits, "
      f"{t2['plan_cache_misses']} misses "
      f"(cold run: {t1['plan_cache_misses']} misses)")
snap = obs.snapshot()
print(f"  buffer_pool: {snap['buffer_pool.hits']} hits / "
      f"{snap['buffer_pool.misses']} uploads, "
      f"{snap['buffer_pool.resident_bytes']} B resident")
print(f"  plan_cache.entries: {snap['plan_cache.entries']}")

# Same query on a Chrome-trace timeline: spans cover executor operators,
# fused columnar pipelines, and any LSM flush/merge they trigger.
obs.enable()
explain_analyze(plan, ds)
n = obs.dump_trace("explain_analyze.trace.json")
obs.disable()
print(f"\nwrote {n} trace events -> explain_analyze.trace.json")
