"""Quickstart: the three faces of the framework in one script.

  1. BDMS: create the TinySocial dataverse, run the paper's queries;
  2. LM substrate: train a reduced arch for a few steps on CPU;
  3. Serving: prefill + LSM-tiered decode.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import datetime as dt
import tempfile

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
print("=== 1. BDMS: TinySocial (paper §2-3) " + "=" * 30)
from repro.configs.tinysocial import build_dataverse
from repro.core import algebra as A
from repro.storage.query import run_query

dv, ds = build_dataverse(num_users=200, num_messages=1000)
print("catalog (metadata-as-data, Query 1):")
for rec in dv.catalog_records():
    print("  ", rec)

lo, hi = dt.datetime(2010, 7, 22), dt.datetime(2012, 7, 29)
plan = A.select(A.scan("MugshotUsers"),
                pred=lambda r: lo <= r["user-since"] <= hi,
                fields=["user-since"], ranges={"user-since": (lo, hi)})
rows, ex = run_query(plan, ds)
print(f"Query 2 (datetime range w/ index): {len(rows)} users; "
      f"rows via index: {ex.stats.op_rows.get('SECONDARY_INDEX_SEARCH')}")

plan = A.limit(A.order_by(
    A.group_by(A.scan("MugshotMessages"), ["author-id"],
               {"cnt": ("count", "*")}), ["cnt"], desc=True), 3)
rows, ex = run_query(plan, ds)
print(f"Query 11 (top-3 chatty users): {rows}")
print(f"  connector rows moved: {ex.stats.rows_moved}")

# ---------------------------------------------------------------------------
print("\n=== 2. Train a reduced LM for 5 steps " + "=" * 28)
from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.training.trainer import Trainer
from repro.optim.adamw import OptimizerConfig

cfg = reduced(get_config("olmoe-1b-7b"))
with tempfile.TemporaryDirectory() as ckpt_dir:
    tr = Trainer(cfg, global_batch=4, seq_len=32, ckpt_dir=ckpt_dir,
                 opt_cfg=OptimizerConfig(peak_lr=1e-3, warmup_steps=2,
                                         decay_steps=50))
    tr.init_or_restore()
    out = tr.run(5, checkpoint_every=2)
    print(f"5 steps of {cfg.name} (reduced): loss "
          f"{tr.history[0]['loss']:.3f} -> {tr.history[-1]['loss']:.3f}, "
          f"{out['wall_s']:.1f}s")
    print(f"checkpoints (validity-bit components): {tr.ckpt.valid_steps()}")

# ---------------------------------------------------------------------------
print("\n=== 3. Serve: prefill + LSM-tiered decode " + "=" * 24)
from repro.models import model as M
from repro.models.layers import init_params
from repro.kvcache.lsm_cache import (TieredCacheConfig, init_tiered_cache,
                                     tiered_decode_attention)

params = init_params(M.model_specs(cfg), jax.random.key(0), jnp.float32)
prefill = jax.jit(M.make_prefill_fn(cfg))
toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
logits, cache = prefill(params, {"tokens": toks})
print(f"prefill: last-token logits {logits.shape}, cache layers cached")

ccfg = TieredCacheConfig(tail_cap=8, l1_comps=2, max_len=64)
kv = init_tiered_cache(2, cfg.num_kv_heads, cfg.resolved_head_dim, ccfg,
                       jnp.float32)
q = jax.random.normal(jax.random.key(2),
                      (2, cfg.num_heads, cfg.resolved_head_dim))
step = jax.jit(lambda c, q, k, v: tiered_decode_attention(c, q, k, v, ccfg))
for t in range(20):
    kvt = jax.random.normal(jax.random.key(10 + t),
                            (2, 1, cfg.num_kv_heads, cfg.resolved_head_dim))
    out, kv = step(kv, q, kvt, kvt)
print(f"20 tiered-decode steps: flushes={int(kv['flushes'])} "
      f"merges={int(kv['merges'])} (LSM components at work)")
print("\nquickstart OK")
