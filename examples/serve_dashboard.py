"""Serving-tier dashboard tour: the Prometheus exporter + request
tracing + SLO ledger over a live mixed workload.

Drives a short ``ServeHarness`` run (2 feed pumps + 2 snapshot-isolated
query workers, per-request deadline) with ``obs.serve_http()`` live,
then shows everything a scrape-based dashboard would see:

* a mid-run ``/metrics`` scrape — Prometheus text with the serve
  counters, queue-wait/latency summaries, and ``*_rate`` gauges from
  the background sampler (point Prometheus/Grafana at this URL);
* the ``/snapshot`` and ``/trace`` endpoints (raw registry JSON and a
  Chrome-trace of the 1-in-N sampled request span trees — load the
  latter in https://ui.perfetto.dev);
* the SLO ledger and tail-latency attribution from the
  ``ServeReport``: attainment, queue-wait p50/p99, per-phase p99s, and
  which phase dominates the tail.

Run: PYTHONPATH=src python examples/serve_dashboard.py
"""

import json
import urllib.request

from repro import obs
from repro.core import adm
from repro.serve import ServeHarness
from repro.storage.dataset import PartitionedDataset

rt = adm.RecordType("DashType",
                    (adm.Field("pk", adm.INT64),
                     adm.Field("val", adm.INT64),
                     adm.Field("text", adm.STRING)),
                    open=True)
ds = PartitionedDataset("dashboard", rt, "pk", num_partitions=4,
                        flush_threshold=256)

h = ServeHarness(ds, n_ingest=2, n_query=2, pump_batch=64,
                 records_per_lane=4000, deadline_s=5.0,
                 profile_every=4)

# one call starts the sampler + HTTP endpoint; port=0 -> ephemeral
server = obs.serve_http(port=0, sample_interval_s=0.25,
                        trace_source=h.tracker.profile_spans)
print(f"== exporter live at {server.url} ==")
print("   /metrics   Prometheus text (scrape me)")
print("   /snapshot  raw metrics.snapshot() JSON")
print("   /trace     Chrome trace of sampled request spans\n")

try:
    rep = h.run(duration_s=8.0)

    text = urllib.request.urlopen(server.url + "/metrics",
                                  timeout=10).read().decode()
    serve_lines = [ln for ln in text.splitlines()
                   if ln.split("{")[0].rstrip("_sumcount")
                                      .startswith(("serve_", "feed_sink"))]
    print(f"== /metrics: {len(text.splitlines())} lines, "
          f"serve-tier excerpt ==")
    for ln in serve_lines[:24]:
        print(f"  {ln}")

    trace = json.loads(urllib.request.urlopen(server.url + "/trace",
                                              timeout=10).read())
    print(f"\n== /trace: {len(trace['traceEvents'])} span events from "
          f"{len(h.tracker.profiles)} sampled requests ==")
finally:
    server.stop()

d = rep.as_dict()
print("\n== SLO ledger (deadline "
      f"{d['slo']['deadline_ms']:.0f}ms) ==")
print(f"  attained {d['slo']['attained']}  missed {d['slo']['missed']}  "
      f"rejected-by-deadline {d['slo']['rejected_deadline']}  "
      f"attainment {d['slo']['attainment']:.3f}")
print(f"  ingest {d['ingest_rate']:.0f} rec/s acked, "
      f"{d['queries']} queries, {d['admission_rejected']} shed")

print("\n== tail-latency attribution ==")
print(f"  queue wait  p50 {d['queue_wait_p50_ms']:.3f}ms  "
      f"p99 {d['queue_wait_p99_ms']:.3f}ms")
for phase, p99 in sorted(d["phase_p99_ms"].items()):
    mark = "  <- dominates p99" if phase == d["slowest_phase_p99"] else ""
    p99s = "-" if p99 is None else f"{p99:.3f}ms"
    print(f"  {phase:<10}  p99 {p99s}{mark}")
