"""Serving example: batched requests through prefill + cached decode, with
both flat and LSM-tiered KV attention paths cross-checked.

Run: PYTHONPATH=src python examples/serve_decode.py [--tokens 48]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models import model as M
from repro.models.layers import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-67b")
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params = init_params(M.model_specs(cfg), jax.random.key(0), jnp.float32)
    prefill = jax.jit(M.make_prefill_fn(cfg))
    decode = jax.jit(M.make_decode_fn(cfg))

    # batched requests: shared-length prompts (a serving batch)
    B, P = args.batch, 16
    max_len = P + args.tokens
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0,
                                 cfg.vocab_size)
    logits, cache = prefill(params, {"tokens": prompts})

    # grow attention caches to max_len (serving allocator would pre-size)
    def grow(x):
        if x.ndim >= 3 and x.shape[-3] == P and \
                x.shape[-1] == cfg.resolved_head_dim:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, max_len - P)
            return jnp.pad(x, pad)
        return x

    cache = jax.tree.map(grow, cache)
    tok = jnp.argmax(logits, axis=-1)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for t in range(args.tokens - 1):
        logits, cache = decode(params, cache,
                               {"token": tok, "pos": jnp.int32(P + t)})
        tok = jnp.argmax(logits, axis=-1)[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"{cfg.name} (reduced): generated {gen.shape} greedy tokens")
    print(f"decode: {args.tokens * B / dt:.1f} tok/s (CPU, batch {B})")

    # oracle check: the full prefill of prompt+generated must predict the
    # same final token (cache path == full recompute)
    full = jnp.concatenate([prompts, gen[:, :-1]], axis=1)
    logits2, _ = prefill(params, {"tokens": full})
    agree = float(jnp.mean((jnp.argmax(logits2, -1) == gen[:, -1])))
    print(f"decode-vs-recompute final-token agreement: {agree:.2f}")
    assert agree > 0.95
    print("serve_decode OK")


if __name__ == "__main__":
    main()
