"""End-to-end driver (deliverable b): train a ~100M-class model for a few
hundred steps through the full production stack — feeds -> jit'd train step
-> LSM checkpointing with WAL — including a mid-run crash + recovery.

The arch is xlstm-125m at trimmed width (CPU wall-clock), exercising both
mLSTM and sLSTM blocks.  Run:

  PYTHONPATH=src python examples/train_100m.py [--steps 300] [--full]

``--full`` uses the real 125m width (slow on CPU; fine on real hardware).
"""

import argparse
import dataclasses
import tempfile
import time

from repro.configs.registry import get_config
from repro.optim.adamw import OptimizerConfig
from repro.training.trainer import InjectedFailure, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default="xlstm-125m")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = dataclasses.replace(
            cfg, d_model=256, num_layers=4, vocab_size=8192,
            xlstm_heads=2, seq_chunk=32,
            num_heads=max(2, cfg.num_heads // 8),
            num_kv_heads=max(1, cfg.num_kv_heads // 8),
            d_ff=cfg.d_ff // 8 if cfg.d_ff else 0,
            num_experts=min(cfg.num_experts, 8),
            experts_per_token=min(cfg.experts_per_token, 2))
    print(f"training {cfg.name}: ~{cfg.params_total()/1e6:.0f}M params, "
          f"batch={args.batch} seq={args.seq}")

    opt = OptimizerConfig(peak_lr=3e-3, warmup_steps=20,
                          decay_steps=args.steps)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = Trainer(cfg, global_batch=args.batch, seq_len=args.seq,
                     ckpt_dir=ckpt_dir, opt_cfg=opt)
        tr.init_or_restore()
        t0 = time.time()
        half = args.steps // 2
        try:
            tr.run(args.steps, checkpoint_every=max(10, args.steps // 10),
                   fail_at_step=half, log_every=25)
        except InjectedFailure:
            print(f"!! injected node failure at step {half}; restarting "
                  f"from the newest VALID component ...")
        tr2 = Trainer(cfg, global_batch=args.batch, seq_len=args.seq,
                      ckpt_dir=ckpt_dir, opt_cfg=opt)
        tr2.init_or_restore()
        print(f"   recovered at step {tr2.step} "
              f"(WAL records: {len(tr2.ckpt.read_wal())})")
        tr2.run(args.steps - tr2.step,
                checkpoint_every=max(10, args.steps // 10))
        hist = tr2.history
        wall = time.time() - t0
        first, last = hist[0], hist[-1]
        print(f"step {first['step']}: loss {first['loss']:.3f}  ->  "
              f"step {last['step']}: loss {last['loss']:.3f}")
        tok_s = args.batch * args.seq * (len(hist)) / wall
        print(f"throughput ~{tok_s:.0f} tok/s on CPU; wall {wall:.0f}s")
        assert last["loss"] < first["loss"], "loss should decrease"
        print("train_100m OK (crash-recovered, loss decreasing)")


if __name__ == "__main__":
    main()
