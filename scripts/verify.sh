#!/usr/bin/env bash
# Tier-1 verify: the one command that must stay green (see ROADMAP.md).
# Collection regressions (import errors, missing optional deps) show up
# here before anything else does.
set -euo pipefail
cd "$(dirname "$0")/.."

# Fixed seed for the whole run: the row-vs-columnar differential harness
# (tests/test_differential.py, collected below) seeds per test name via
# the hypothesis shim (real hypothesis runs derandomized); exporting
# PYTHONHASHSEED pins the remaining hash-order dependence.
export PYTHONHASHSEED=0
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Index-path smoke bench: fails if any index-search plan silently falls
# back to the row engine or diverges from it.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.index_bench --smoke

# Ingest-pipeline smoke bench: feed -> flush -> merge -> scan; fails if
# the columnar-native pipeline diverges from the legacy row path or ever
# forces a component's lazy row view.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.ingest_bench --smoke

# Fuzzy smoke bench: ngram T-occurrence chain + batched FuzzyJoin verify;
# fails if a fuzzy plan silently falls back, diverges from the scalar
# predicates, or retraces its kernels on repeated queries.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.fuzzy_bench --smoke
