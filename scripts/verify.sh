#!/usr/bin/env bash
# Tier-1 verify: the one command that must stay green (see ROADMAP.md).
# This is exactly what CI (.github/workflows/ci.yml) runs on every push
# and pull request:
#
#   1. repo hygiene — no tracked bytecode (this regressed twice before
#      the gate existed);
#   2. the full pytest suite (collection regressions — import errors,
#      missing optional deps — show up here before anything else does);
#   3. the eight smoke benches via `benchmarks/run.py --smoke`
#      (columnar / index / residency / ingest / fuzzy / feeds / serve /
#      mesh), whose hard assertions catch: a row-vs-columnar divergence, an
#      index or fuzzy plan silently falling back to the row engine, a
#      candidate read regressing onto a python walk (the CSR postings
#      must beat the legacy secondary-LSM walk), a kernel retrace on
#      repeated queries, a warm index chain shipping host->device bytes
#      (the device buffer pool must keep operands resident), an ingest
#      pipeline divergence, a torn read / lost acknowledged record
#      under concurrent mixed ingest+query serving, or the SPMD
#      partition mesh diverging from (or losing to) the partition loop;
#   4. the structured bench report (`--json bench_smoke.json`) parses,
#      carries schema_version 1, contains rows from every smoke module,
#      the serve rows report nonzero sustained ingest, a p99 query
#      latency and a zero deadline-miss SLO ledger, and the residency
#      rows show warm queries uploading zero bytes at >= 3x the cold
#      latency — CI uploads the file as a run artifact;
#   5. the bench-history regression gate (`benchmarks/history.py
#      --check`) compares the fresh report row-by-row against the
#      committed `benchmarks/baseline.json` tolerance bands and fails on
#      any regression beyond band or drifted correctness invariant —
#      the delta table lands in bench_delta.json (also a CI artifact).
#      After a PR that legitimately moves the numbers, regenerate with
#      `python -m benchmarks.history --update` and commit the diff.
set -euo pipefail
cd "$(dirname "$0")/.."

# Repo hygiene: committed __pycache__/bytecode has regressed twice —
# fail fast if any tracked path matches.
if git ls-files | grep -E '(^|/)__pycache__(/|$)|\.pyc$' >/dev/null; then
    echo "verify: tracked bytecode files found:" >&2
    git ls-files | grep -E '(^|/)__pycache__(/|$)|\.pyc$' >&2
    exit 1
fi

# Fixed seed for the whole run: the row-vs-columnar differential harness
# (tests/test_differential.py, collected below) seeds per test name via
# the hypothesis shim (real hypothesis runs derandomized); exporting
# PYTHONHASHSEED pins the remaining hash-order dependence.
export PYTHONHASHSEED=0
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Smoke-bench matrix: one invocation, one exit code (see run.py --smoke),
# plus a structured JSON report CI keeps as an artifact.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --smoke --json bench_smoke.json

# The report must parse, be schema-stable, and cover every smoke
# module — a bench that crashed or was silently skipped fails here.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json

report = json.load(open("bench_smoke.json"))
assert report["schema_version"] == 1, report["schema_version"]
assert report["smoke"] is True
assert not report["failures"], report["failures"]
from benchmarks.run import SMOKE_MODULES
ran = {row["module"] for row in report["benches"].values()}
missing = set(SMOKE_MODULES) - ran
assert not missing, f"smoke benches missing from report: {sorted(missing)}"
assert report["metrics"], "obs metric snapshot is empty"
# Concurrent-serving rows must carry real numbers: sustained ingest,
# measured tail latency, and a clean consistency ledger.
serve_rows = [r for r in report["benches"].values()
              if r["module"] == "serve"]
assert serve_rows, "no serve bench rows in report"
for row in serve_rows:
    assert row["ingest_rate"] > 0, f"zero sustained ingest: {row}"
    assert row["query_p99_ms"] is not None, f"missing p99: {row}"
    assert row["torn_reads"] == 0 and row["lost_acked"] == 0, row
    # deadline SLO ledger must be clean at smoke load
    assert row["slo_missed"] == 0, f"deadline misses at smoke load: {row}"
    assert row["slo_rejected_deadline"] == 0, f"deadline rejections: {row}"
    assert row["queue_wait_p99_ms"] is not None, f"missing queue wait: {row}"
# Residency rows must prove upload-once semantics: warm repeats of a
# Figure-6 chain ship nothing host->device, never retrace, and beat
# the cold (trace + upload) execution by >= 3x.
res_rows = [r for r in report["benches"].values()
            if r["module"] == "residency"]
assert res_rows, "no residency bench rows in report"
for row in res_rows:
    assert row["h2d_cold"] > 0, f"cold run uploaded nothing: {row}"
    assert row["h2d_warm"] == 0, f"warm query shipped bytes: {row}"
    assert row["retraces_warm"] == 0, f"warm query retraced: {row}"
    assert row["speedup"] >= 3.0, f"warm speedup under 3x: {row}"
# Mesh rows must prove the SPMD refactor held: one shard_map dispatch
# answered for all partitions, bit-identically, from resident shards.
mesh_rows = [r for r in report["benches"].values()
             if r["module"] == "mesh"]
assert mesh_rows, "no mesh bench rows in report"
for row in mesh_rows:
    assert row["spmd_dispatches"] >= 1, f"no SPMD dispatch: {row}"
    assert row["h2d_warm"] == 0, f"warm mesh query shipped bytes: {row}"
    assert row["retraces_warm"] == 0, f"warm mesh query retraced: {row}"
print(f"verify: bench_smoke.json ok "
      f"({len(report['benches'])} benches, {len(report['metrics'])} metrics)")
EOF

# Bench-history regression gate: the fresh smoke numbers must stay
# within the committed baseline's per-row tolerance bands.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.history --check \
        --baseline benchmarks/baseline.json \
        --fresh bench_smoke.json --report bench_delta.json
