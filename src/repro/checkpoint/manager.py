"""LSM-style checkpointing (paper §4.3-4.4 applied to training state).

Every checkpoint is an immutable *component*:

  write  -> a shadow directory ``step_N.tmp/`` (one .npy per pytree leaf +
            manifest.json carrying tree structure, logical axes, and the
            save-time mesh);
  install-> atomic rename to ``step_N/`` then an fsync'd ``VALID`` marker —
            the validity bit: a crash mid-write leaves no VALID file and
            recovery ignores the component (shadowing, §4.4);
  merge  -> retention works like a merge policy: keep the newest K
            components, delete older ones (GC never touches the newest
            VALID component);
  WAL    -> a step-metadata journal (jsonl) appended every step; recovery
            replays the tail to verify/restore the data-feed cursor.

Elastic restore: leaves are saved UNSHARDED (gathered) with their logical
axes; ``load_latest`` re-resolves PartitionSpecs against the *current* mesh,
so a 512-chip checkpoint restores onto 256 chips (or a CPU test mesh) — the
framework's elastic-scaling path.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree: Any, prefix: Tuple[str, ...] = ()) -> Dict[Tuple[str, ...], Any]:
    if isinstance(tree, dict):
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
        return out
    return {prefix: tree}


def _unflatten(flat: Dict[Tuple[str, ...], Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        if path == ():
            return v
        d = root
        for p in path[:-1]:
            d = d.setdefault(p, {})
        d[path[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.wal_path = self.dir / "steps.wal"
        self._async_thread: Optional[threading.Thread] = None

    # -- WAL (step metadata journal) ----------------------------------------
    def log_step(self, record: Dict[str, Any]) -> None:
        with open(self.wal_path, "a") as f:
            f.write(json.dumps(record) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def read_wal(self) -> List[Dict[str, Any]]:
        if not self.wal_path.exists():
            return []
        out = []
        for line in self.wal_path.read_text().splitlines():
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                break  # torn tail write: ignore the rest (no-steal WAL)
        return out

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None,
             crash_before_validity: bool = False,
             asynchronous: bool = False) -> Path:
        """Shadow-install a checkpoint component.  ``crash_before_validity``
        simulates dying between data write and validity install."""
        if asynchronous:
            host_state = jax.tree.map(np.asarray, state)  # snapshot now
            t = threading.Thread(
                target=self._save_sync,
                args=(step, host_state, extra, crash_before_validity))
            self.wait()
            self._async_thread = t
            t.start()
            return self.dir / f"step_{step}"
        return self._save_sync(step, state, extra, crash_before_validity)

    def _save_sync(self, step, state, extra, crash_before_validity) -> Path:
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        flat = _flatten(state)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for i, (path, leaf) in enumerate(flat.items()):
            arr = np.asarray(leaf)
            np.save(tmp / f"leaf_{i}.npy", arr)
            manifest["leaves"].append({
                "path": list(path), "file": f"leaf_{i}.npy",
                "dtype": str(arr.dtype), "shape": list(arr.shape)})
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        if crash_before_validity:
            return final                    # no VALID marker: invisible
        with open(final / "VALID", "w") as f:
            f.write("1")
            f.flush()
            os.fsync(f.fileno())
        self._gc()
        return final

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # -- load ----------------------------------------------------------------
    def valid_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") \
                    and (p / "VALID").exists():
                steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def load(self, step: int, shardings: Optional[Any] = None
             ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Returns (state, extra).  ``shardings``: optional pytree of
        NamedShardings (same structure) to reshard onto the current mesh."""
        final = self.dir / f"step_{step}"
        manifest = json.loads((final / "manifest.json").read_text())
        flat: Dict[Tuple[str, ...], Any] = {}
        for leaf in manifest["leaves"]:
            flat[tuple(leaf["path"])] = np.load(final / leaf["file"])
        state = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            state = _unflatten({
                p: jax.device_put(v, flat_sh[p]) if p in flat_sh else v
                for p, v in flat.items()})
        return state, manifest["extra"]

    def load_latest(self, shardings: Optional[Any] = None
                    ) -> Optional[Tuple[int, Dict[str, Any], Dict[str, Any]]]:
        """Crash recovery: newest VALID component (invalid shadow dirs are
        removed, paper §4.4), plus its extra state."""
        for p in self.dir.glob("step_*.tmp"):
            shutil.rmtree(p)                # torn writes
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not (p / "VALID").exists():
                shutil.rmtree(p)            # shadow without validity bit
        steps = self.valid_steps()
        if not steps:
            return None
        state, extra = self.load(steps[-1], shardings)
        return steps[-1], state, extra

    def _gc(self) -> None:
        steps = self.valid_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}")
