"""Columnar vectorized query engine (after Alkowaileet & Carey's columnar
formats for schemaless LSM document stores, PAPERS.md).

Bridges the ADM record world (core/adm, storage/) onto dense arrays the
jax/Pallas substrate can chew on:

  schema.py    — column-kind inference from a RecordType + observed open
                 fields (schemaless records still get columns)
  batch.py     — ColumnBatch: dense arrays + validity bitmaps + a sorted
                 string dictionary per column
  operators.py — vectorized physical operators over batches (filter,
                 project, aggregate, group, sort/top-k, hash join,
                 hash repartitioning)
  lower.py     — lowers supported PhysicalOp subplans to columnar
                 pipelines for storage/query.Executor(vectorize=True)

The predicate/reduction hot path lives in kernels/columnar_ops.py
(fused Pallas kernels on TPU, jnp fallback elsewhere).
"""

from .batch import Column, ColumnBatch
from .schema import ColumnSchema, infer_kind, unify_kinds

__all__ = ["Column", "ColumnBatch", "ColumnSchema", "infer_kind",
           "unify_kinds"]
