"""ColumnBatch: the columnar record format (dense arrays + validity
bitmaps + per-column sorted string dictionaries).

One ColumnBatch holds N shredded ADM records.  Every column carries a
validity bitmap (False = field absent in that record) so open types and
optional fields round-trip losslessly: ``ColumnBatch.from_rows(rows)
.to_rows() == rows`` for anything core/adm validates, with
present-but-null and non-scalar values riding in ``obj`` columns.

String columns dictionary-encode against a *sorted* per-batch dictionary,
so code order equals lexicographic order and range predicates evaluate
directly on the int32 codes.

ColumnBatch is also the *primary* on-disk representation of immutable
LSM components (core/lsm): flush shreds the memtable in sorted-key order
(``sort_by`` is the batch-level counterpart for callers holding an
already-shredded batch), ``merge_sorted`` gathers a column-wise k-way
merge from the ``sorted_merge_take`` kernel's take-indices, and every
column caches a pow2-padded view of its arrays (``Column.padded``) so
the jitted kernels see a bounded, shape-stable set of operand shapes
across repeated scans and merges.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, Iterable, List, Optional, Sequence

import numpy as np

from .schema import ColumnSchema, decode_scalar, encode_scalar, infer_kind, \
    unify_kinds

__all__ = ["Column", "ColumnBatch", "MISSING", "pow2_len",
           "promotes_lossless"]


class _Missing:
    def __repr__(self) -> str:
        return "<MISSING>"


MISSING = _Missing()

_NP_DTYPE = {"i64": np.int64, "f64": np.float64, "bool": np.bool_,
             "dt": np.int64, "date": np.int64, "str": np.int32}


def pow2_len(n: int) -> int:
    """Smallest power of two >= n (and >= 1): the shape-stable storage
    granule for kernel operands."""
    return 1 << max(0, (n - 1).bit_length())


def promotes_lossless(arrays: Sequence[np.ndarray]) -> bool:
    """True when concatenating these numeric arrays under numpy's common
    dtype loses no values.  The one guard the sorted-key paths (LSM merge
    take-indices, the dataset's live-row selection) share against silent
    key corruption: int64+float64 or int64+uint64 promote to float64,
    which rounds integers beyond 2**53."""
    if len({a.dtype for a in arrays}) <= 1:
        return True
    promo = np.result_type(*(a.dtype for a in arrays))
    return promo.kind in "biuf" and all(
        np.array_equal(a.astype(promo).astype(a.dtype), a) for a in arrays)


@dataclass
class Column:
    kind: str
    data: np.ndarray                    # physical values (codes for 'str')
    valid: np.ndarray                   # bool bitmap: field present?
    values: Optional[List[str]] = None  # sorted dictionary for 'str'
    # pow2-padded (data, valid) view, built once per immutable column
    _padded: Optional[tuple] = field(default=None, repr=False, compare=False)
    # int64 widening of padded() for bool columns, built once (stable
    # identity: the device buffer pool keys on the padded arrays)
    _padded_i64: Optional[tuple] = field(default=None, repr=False,
                                         compare=False)

    def __len__(self) -> int:
        return int(self.data.shape[0])

    def padded(self) -> tuple:
        """``(data, valid)`` padded to the next power of two with invalid
        rows.  Columns are immutable, so the padded view is cached: kernel
        calls over the same component batch reuse one allocation, and the
        jitted cores see pow2 shapes only (no per-length retraces)."""
        n = len(self)
        np2 = pow2_len(n)
        if np2 == n:
            return self.data, self.valid
        if self._padded is None:
            pad = np2 - n
            if self.data.dtype == object:
                data = np.empty(np2, dtype=object)
                data[:n] = self.data
            else:
                data = np.concatenate(
                    [self.data, np.zeros(pad, dtype=self.data.dtype)])
            valid = np.concatenate([self.valid, np.zeros(pad, dtype=bool)])
            self._padded = (data, valid)
        return self._padded

    def padded_int64(self) -> tuple:
        """``padded()`` with the data widened to int64 — what the kernels
        compare bool columns as.  Cached so repeated queries hand the
        same arrays to the device pool instead of re-widening per call."""
        if self._padded_i64 is None:
            data, valid = self.padded()
            self._padded_i64 = (data.astype(np.int64), valid)
        return self._padded_i64

    def take(self, idx: np.ndarray) -> "Column":
        return Column(self.kind, self.data[idx], self.valid[idx], self.values)

    def decode(self) -> List[Any]:
        """Python values; MISSING where invalid."""
        if self.kind == "obj":
            out = list(self.data)
        elif self.kind == "str":
            # invalid rows carry code 0 even when the dictionary is empty
            # (all-missing column): only valid rows may index the dict
            vals = self.values or []
            nv = len(vals)
            out = [vals[c] if c < nv else None
                   for c in self.data.tolist()]
        elif self.kind in ("dt", "date"):
            out = [decode_scalar(x, self.kind) for x in self.data.tolist()]
        else:
            out = self.data.tolist()
        ok = self.valid
        return [v if ok[i] else MISSING for i, v in enumerate(out)]


def _empty_column(kind: str, n: int) -> Column:
    if kind == "obj":
        data = np.empty(n, dtype=object)
    else:
        data = np.zeros(n, dtype=_NP_DTYPE[kind])
    vals: Optional[List[str]] = [] if kind == "str" else None
    return Column(kind, data, np.zeros(n, dtype=bool), vals)


def build_column(raw: Sequence[Any], kind: str) -> Column:
    """Shred one field's values (MISSING marks absent) into a Column,
    downgrading to ``obj`` if any present value defies the kind."""
    n = len(raw)
    valid = np.fromiter((v is not MISSING for v in raw), dtype=bool, count=n)
    if kind == "obj":
        data = np.empty(n, dtype=object)
        for i, v in enumerate(raw):
            data[i] = None if v is MISSING else v
        return Column("obj", data, valid)
    try:
        if kind == "str":
            present = sorted({v for v in raw if v is not MISSING})
            if any(not isinstance(v, str) for v in present):
                raise TypeError("non-string in str column")
            code = {v: i for i, v in enumerate(present)}
            data = np.fromiter(
                (0 if v is MISSING else code[v] for v in raw),
                dtype=np.int32, count=n)
            return Column("str", data, valid, present)
        data = np.fromiter(
            (0 if v is MISSING else encode_scalar(v, kind) for v in raw),
            dtype=_NP_DTYPE[kind], count=n)
        return Column(kind, data, valid)
    except (TypeError, ValueError, OverflowError):
        return build_column(raw, "obj")


def _remap_dictionary(col: Column, merged: List[str]) -> Column:
    """Re-express a str column's codes against a larger sorted dictionary."""
    if col.values == merged:
        return col
    old = np.asarray(col.values if col.values else [""], dtype=object)
    lut = np.searchsorted(np.asarray(merged, dtype=object), old)
    data = lut[col.data].astype(np.int32) if len(col.values or []) \
        else np.zeros(len(col), dtype=np.int32)
    return Column("str", data, col.valid, merged)


@dataclass
class ColumnBatch:
    columns: Dict[str, Column] = field(default_factory=dict)
    length: int = 0

    # -- construction -------------------------------------------------------
    @classmethod
    def from_rows(cls, rows: Sequence[Dict[str, Any]],
                  schema: Optional[ColumnSchema] = None,
                  columns: Optional[Sequence[str]] = None) -> "ColumnBatch":
        """Shred row dicts.  Without a schema, kinds are inferred from the
        values (open-type friendly).  ``columns`` restricts shredding to a
        projection."""
        if schema is None:
            schema = ColumnSchema()
            for r in rows:
                for k, v in r.items():
                    schema.observe_value(k, v)
        names = list(columns) if columns is not None else list(schema)
        out: Dict[str, Column] = {}
        for name in names:
            if columns is not None and name not in schema:
                continue
            raw = [r.get(name, MISSING) for r in rows]
            out[name] = build_column(raw, schema.kind(name))
        return cls(out, len(rows))

    @classmethod
    def concat(cls, batches: Sequence["ColumnBatch"]) -> "ColumnBatch":
        batches = [b for b in batches]
        if not batches:
            return cls({}, 0)
        if len(batches) == 1:
            return batches[0]
        n = sum(b.length for b in batches)
        names: List[str] = []
        for b in batches:
            for k in b.columns:
                if k not in names:
                    names.append(k)
        out: Dict[str, Column] = {}
        for name in names:
            pieces = [b.columns.get(name) for b in batches]
            kinds = {p.kind for p in pieces if p is not None}
            if len(kinds) > 1:          # mixed representations: objectify
                decoded: List[Any] = []
                for b, p in zip(batches, pieces):
                    decoded.extend(p.decode() if p is not None
                                   else [MISSING] * b.length)
                out[name] = build_column(decoded, "obj")
                continue
            kind = kinds.pop()
            cols = [p if p is not None else _empty_column(kind, b.length)
                    for b, p in zip(batches, pieces)]
            if kind == "str":
                merged = sorted(set().union(*(c.values or [] for c in cols)))
                cols = [_remap_dictionary(c, merged) for c in cols]
                out[name] = Column(
                    "str", np.concatenate([c.data for c in cols]),
                    np.concatenate([c.valid for c in cols]), merged)
            else:
                out[name] = Column(
                    kind, np.concatenate([c.data for c in cols]),
                    np.concatenate([c.valid for c in cols]))
        return cls(out, n)

    # -- relational views ---------------------------------------------------
    def project(self, cols: Sequence[str]) -> "ColumnBatch":
        return ColumnBatch({c: self.columns[c] for c in cols
                            if c in self.columns}, self.length)

    def take(self, idx: np.ndarray) -> "ColumnBatch":
        return ColumnBatch({k: c.take(idx) for k, c in self.columns.items()},
                           int(len(idx)))

    def filter(self, mask: np.ndarray) -> "ColumnBatch":
        return self.take(np.nonzero(mask)[0])

    def slice(self, n: int) -> "ColumnBatch":
        return self.take(np.arange(min(n, self.length)))

    def with_column(self, name: str, col: Column) -> "ColumnBatch":
        cols = dict(self.columns)
        cols[name] = col
        return ColumnBatch(cols, self.length)

    def sort_by(self, keys: Sequence[str], desc: bool = False
                ) -> "ColumnBatch":
        """Rows reordered by the named columns (vectorized lexsort when
        every key column is dense and comparable; decoded fallback for
        ``obj`` keys or columns with absent values)."""
        n = self.length
        arrs = []
        vectorized = bool(keys)
        for k in keys:
            col = self.columns.get(k)
            if col is None or col.kind == "obj" or not col.valid.all():
                vectorized = False
                break
            a = col.data.astype(np.int64) if col.kind == "bool" else col.data
            arrs.append(-a if desc else a)
        if vectorized:
            order = np.lexsort(tuple(reversed(arrs)))
        elif n == 0:
            order = np.zeros(0, dtype=np.int64)
        else:
            rows = self.to_rows()
            # absent values sort first via the presence flag, so a
            # missing field is never compared against a real value
            order = np.asarray(
                sorted(range(n),
                       key=lambda i: tuple((k in rows[i], rows[i].get(k))
                                           for k in keys),
                       reverse=desc), dtype=np.int64)
        return self.take(order)

    @classmethod
    def merge_sorted(cls, batches: Sequence["ColumnBatch"],
                     key_arrays: Sequence[np.ndarray],
                     tombs: Optional[Sequence[np.ndarray]] = None,
                     *, drop_tombstones: bool = False
                     ) -> tuple:
        """Column-wise k-way merge of sorted runs (the LSM merge path).

        ``key_arrays[i]`` holds batch i's sorted, unique keys; batches are
        ordered newest -> oldest and the newest wins each duplicate key.
        The ``sorted_merge_take`` kernel computes take-indices once, then
        every column — string dictionaries included (``concat`` remaps
        codes onto the merged dictionary) — is gathered without
        materializing a single row.  Returns ``(batch, keys, tomb)``
        aligned with each other; see the kernel for tombstone semantics.
        """
        from ..kernels import columnar_ops as K
        keys, take, tomb = K.sorted_merge_take(
            key_arrays, tombs, drop_tombstones=drop_tombstones)
        merged = cls.concat(list(batches)).take(take)
        return merged, keys, tomb

    def row_at(self, i: int) -> Dict[str, Any]:
        """Reassemble one record without decoding the whole batch (the
        LSM point-lookup path over columnar components)."""
        r: Dict[str, Any] = {}
        for k, c in self.columns.items():
            if not c.valid[i]:
                continue
            if c.kind == "obj":
                r[k] = c.data[i]
            elif c.kind == "str":
                r[k] = (c.values or [])[int(c.data[i])]
            else:
                r[k] = decode_scalar(c.data[i], c.kind)
        return r

    # -- record reassembly --------------------------------------------------
    def to_rows(self) -> List[Dict[str, Any]]:
        """Reassemble record dicts; absent (invalid) fields are omitted."""
        decoded = {k: c.decode() for k, c in self.columns.items()}
        out: List[Dict[str, Any]] = []
        for i in range(self.length):
            r = {}
            for k, vals in decoded.items():
                v = vals[i]
                if v is not MISSING:
                    r[k] = v
            out.append(r)
        return out

    def schema(self) -> ColumnSchema:
        return ColumnSchema({k: c.kind for k, c in self.columns.items()})

    def __len__(self) -> int:
        return self.length
