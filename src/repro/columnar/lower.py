"""Lowers supported PhysicalOp subplans to columnar pipelines.

``storage/query.Executor(vectorize=True)`` calls ``try_lower`` on every
operator before falling back to row-at-a-time execution.  A successful
lowering executes the whole subtree on ColumnBatches — connectors
included (hash repartitioning is placement-identical to the row engine's
``hash_partition``) — and converts back to row dicts only at the
boundary.  Unsupported operators (index access paths, opaque predicates
without sargable ranges, exotic aggregate/kind combos) return None and
the row engine runs them; their *children* still get their own chance to
vectorize.

Lowered operator set:

  DATASET_SCAN            per-component column projection scan
  STREAM_SELECT           sargable ranges (+ residual pred re-check
                          unless the plan declared ``ranges_exact``)
  POST_VALIDATE_SELECT /
  PRIMARY_INDEX_LOOKUP    Figure-6 index access chains (secondary btree /
                          rtree / keyword search -> SORT_PK -> primary
                          lookup [-> post-validate]): each partition's
                          per-component CSR postings probe yields a
                          candidate position bitmap over the primary's
                          cached ColumnBatches directly (datasets exposing
                          only sorted candidate-PK arrays go through the
                          fused sorted-intersection kernel instead);
                          multi-index conjunctions AND bitmaps before any
                          record decode, and post-validation runs on the
                          gathered columns.  The fuzzy chains (NGRAM_INDEX_SEARCH
                          -> T_OCCURRENCE -> same tail) produce the bitmap
                          straight from the ngram postings' T-occurrence
                          count kernel and verify candidates with the
                          batched similarity kernels (fuzzy/verify)
  STREAM_PROJECT          column projection
  LOCAL_AGG/GLOBAL_AGG    fused filter+aggregate kernel when the child
                          is an exact-range select
  LOCAL_PREAGG/HASH_GROUP/GLOBAL_GROUP   vectorized grouped aggregation
  LOCAL_SORT/SORT_MERGE_GATHER/LOCAL_TOPK/TOPK_MERGE/STREAM_LIMIT
  HYBRID_HASH_JOIN        int/str/f64-domain equality keys

Every lowered operator records its cardinality in ``ExecStats.op_rows``
(same keys as the row engine) plus ``rows_vectorized``; index-path
operators additionally count into ``rows_index_vectorized``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from ..core.algebra import Connector, PhysicalOp
from .. import obs as _obs
from ..runtime import spmd as SP
from . import operators as O
from .batch import ColumnBatch

__all__ = ["try_lower", "Unsupported"]

# node() -> per-partition batches
Node = Callable[[], List[ColumnBatch]]


class Unsupported(Exception):
    """This subplan stays on the row engine."""


def _columnar_dataset(ex: Any, name: str, index: bool = False,
                      fuzzy: bool = False) -> Any:
    """The one capability probe for columnar dataset access: the named
    dataset must expose the columnar scan surface (plus the candidate-PK
    index surface when ``index``, plus the ngram candidate-bitmap surface
    when ``fuzzy``), else the subplan stays on the row engine."""
    ds = ex.datasets.get(name)
    if ds is None or not hasattr(ds, "scan_partition_batch"):
        raise Unsupported("dataset has no columnar scan")
    if index and not (hasattr(ds, "partition_pk_array")
                      and (hasattr(ds, "secondary_candidate_mask")
                           or hasattr(ds, "secondary_candidate_pks"))):
        raise Unsupported("dataset has no columnar index access")
    if fuzzy and not hasattr(ds, "ngram_candidate_mask"):
        raise Unsupported("dataset has no ngram candidate access")
    return ds


_VECTOR_COMPUTE = {
    "STREAM_SELECT", "LOCAL_AGG", "GLOBAL_AGG", "LOCAL_PREAGG",
    "HASH_GROUP", "GLOBAL_GROUP", "LOCAL_SORT", "SORT_MERGE_GATHER",
    "LOCAL_TOPK", "TOPK_MERGE", "HYBRID_HASH_JOIN",
    "POST_VALIDATE_SELECT", "PRIMARY_INDEX_LOOKUP",
}

_INDEX_SEARCHES = {"SECONDARY_INDEX_SEARCH", "SPATIAL_INDEX_SEARCH",
                   "KEYWORD_INDEX_SEARCH", "NGRAM_INDEX_SEARCH"}


def _decline(ex: Any, op: PhysicalOp, reason: str) -> None:
    """Record why this subplan stays on the row engine: always into
    ``ExecStats.fallback_reasons`` (queryable by the differential
    harness), and per-node for ``explain_analyze`` when active."""
    ex.stats.fell_back(op.kind, reason)
    reasons = getattr(ex, "_fallback_reasons", None)
    if reasons is not None:
        reasons[id(op)] = reason


def try_lower(op: PhysicalOp, ex: Any) -> Optional[Callable[[], list]]:
    """Compile ``op``'s subtree to a columnar pipeline, or None.  The
    returned callable yields the row engine's row Parts up to row order
    inside unordered operators (grouped/joined row order may be permuted;
    sorts, top-k and limits are order-exact).  A None return always
    leaves its reason in ``ex.stats.fallback_reasons``."""
    if not _profitable(op):
        _decline(ex, op, "not profitable (no vectorized compute)")
        return None
    if op.kind == "HYBRID_HASH_JOIN":
        # a join at the pipeline root materializes its full output as row
        # dicts at the boundary, which costs more than the row engine's
        # dict merge; joins vectorize only under a reducing operator
        # (aggregate/group/top-k), where the output never widens to rows
        _decline(ex, op, "join at pipeline root")
        return None
    try:
        node = _compile(op, ex, None)
    except Unsupported as e:
        _decline(ex, op, str(e))
        return None

    def run() -> list:
        with _obs.span("columnar." + op.kind):
            return [b.to_rows() for b in node()]
    return run


def _profitable(op: PhysicalOp) -> bool:
    """A pipeline that only scans/projects/limits would pay shred+decode
    for nothing; require at least one vectorized compute operator."""
    if op.kind in _VECTOR_COMPUTE:
        return True
    return any(_profitable(c) for c in op.children)


def _check_aggs(aggs: Dict[str, Tuple[str, str]]) -> None:
    for name, (fn, _col) in aggs.items():
        if fn not in O._AGG_FNS:
            raise Unsupported(f"aggregate {fn}")


def _empty(n: int) -> List[ColumnBatch]:
    return [ColumnBatch({}, 0) for _ in range(n)]


def _total(cparts: Sequence[ColumnBatch]) -> int:
    return sum(len(b) for b in cparts)


def _apply_conn(conn: Connector, cparts: List[ColumnBatch], ex: Any,
                p: int) -> List[ColumnBatch]:
    import numpy as np
    if conn.name == "OneToOne":
        return cparts
    if conn.name in ("MToNHashPartition", "MToNHashPartitionMerge"):
        # on an active partition mesh the repartition lowers to one tiled
        # all_to_all per column plane (placement- and order-identical to
        # the host bucketing below); host path covers string/obj schemas
        # whose dictionary codes are partition-local
        exg = SP.exchange_batches(cparts, conn.keys, p)
        if exg is not None:
            out, moved = exg
            if conn.name == "MToNHashPartitionMerge" and conn.sort_keys:
                out = [O.sort_batch(b, conn.sort_keys, False) for b in out]
            ex.stats.moved(conn.name, moved)
            return out
        buckets: List[List[ColumnBatch]] = [[] for _ in range(p)]
        moved = 0
        for i, b in enumerate(cparts):
            if not len(b):
                continue
            ids = O.partition_ids(b, conn.keys, p)
            moved += int((ids != i).sum())
            for j in range(p):
                sel = ids == j
                if sel.any():
                    buckets[j].append(b.filter(sel))
        out = [ColumnBatch.concat(bs) if bs else ColumnBatch({}, 0)
               for bs in buckets]
        if conn.name == "MToNHashPartitionMerge" and conn.sort_keys:
            out = [O.sort_batch(b, conn.sort_keys, False) for b in out]
        ex.stats.moved(conn.name, moved)
        return out
    if conn.name == "MToNReplicate":
        allb = O.concat_gather(cparts)
        ex.stats.moved(conn.name, len(allb) * (p - 1))
        return [allb for _ in range(p)]
    if conn.name == "ReplicateToOne":
        ex.stats.moved(conn.name, sum(len(b) for b in cparts[1:]))
        return [O.concat_gather(cparts)] + _empty(p - 1)
    raise Unsupported(conn.name)


def _agg_out_cols(aggs: Dict[str, Tuple[str, str]]) -> Set[str]:
    return {c for (_fn, c) in aggs.values() if c != "*"}


def _compile(op: PhysicalOp, ex: Any, needed: Optional[Set[str]]) -> Node:
    k = op.kind
    p = ex.num_partitions
    attrs = op.attrs

    if k == "DATASET_SCAN":
        ds = _columnar_dataset(ex, attrs["dataset"])
        cols = None if needed is None else sorted(needed)

        def run_scan():
            cparts = [ds.scan_partition_batch(i, cols)
                      for i in range(ds.num_partitions)]
            cparts += _empty(p - ds.num_partitions)
            ex.stats.vectorized(k, _total(cparts))
            return cparts
        return run_scan

    if k == "STREAM_SELECT":
        ranges = attrs.get("ranges") or {}
        if not ranges:
            raise Unsupported("opaque predicate (no sargable ranges)")
        pred = attrs.get("pred")
        residual = not attrs.get("ranges_exact", False)
        child_needed = None if residual else (
            None if needed is None else needed | set(ranges))
        child = _compile(op.children[0], ex, child_needed)
        conn = op.connectors[0]

        def run_select():
            cparts = child()
            cparts = _apply_conn(conn, cparts, ex, p)
            # SPMD: every partition's range mask in one shard_map
            # dispatch; None entries (empty batch / absent column) and a
            # None return (no mesh, operand drift) keep the loop path
            masks = SP.batched_range_masks(cparts, ranges)
            out = [O.select_batch_with_mask(b, masks[i], pred, residual)
                   if masks is not None and masks[i] is not None
                   else O.select_batch(b, ranges, pred, residual)
                   for i, b in enumerate(cparts)]
            ex.stats.vectorized(k, _total(out))
            return out
        return run_select

    if k in ("POST_VALIDATE_SELECT", "PRIMARY_INDEX_LOOKUP"):
        return _compile_index_path(op, ex, needed, p)

    if k == "STREAM_PROJECT":
        cols = tuple(attrs["cols"])
        child = _compile(op.children[0], ex, set(cols))
        conn = op.connectors[0]

        def run_project():
            cparts = child()
            cparts = _apply_conn(conn, cparts, ex, p)
            out = [b.project(cols) for b in cparts]
            ex.stats.vectorized(k, _total(out))
            return out
        return run_project

    if k == "LOCAL_AGG":
        aggs = attrs["aggs"]
        _check_aggs(aggs)
        child_op = op.children[0]
        conn = op.connectors[0]
        # fusion: exact-range select directly below the aggregate runs as
        # one filter+reduce kernel pass per partition
        fuse = (child_op.kind == "STREAM_SELECT"
                and bool(child_op.attrs.get("ranges"))
                and bool(child_op.attrs.get("ranges_exact")))
        if fuse:
            ranges = child_op.attrs["ranges"]
            inner = _compile(child_op.children[0], ex,
                             _agg_out_cols(aggs) | set(ranges))
            sel_conn = child_op.connectors[0]

            def run_fused_agg():
                cparts = inner()
                cparts = _apply_conn(sel_conn, cparts, ex, p)
                # SPMD: all partitions' filter+reduce as one shard_map
                # dispatch; per-partition None entries (and a None
                # return) keep the per-partition kernel path
                batched = SP.batched_select_aggregate(cparts, ranges, aggs)
                out, survivors = [], 0
                for i, b in enumerate(cparts):
                    r = batched[i] if batched is not None else None
                    if r is None:
                        r = O.fused_select_aggregate(b, ranges, aggs,
                                                     partial=True)
                    if r is None:
                        sb = O.select_batch(b, ranges,
                                            child_op.attrs.get("pred"),
                                            residual=False)
                        r = O.aggregate_batch(sb, aggs, partial=True)
                    row, surv = r
                    survivors += surv
                    out.append(ColumnBatch.from_rows([row]))
                ex.stats.vectorized("STREAM_SELECT", survivors)
                ex.stats.vectorized(k, len(out))
                analysis = getattr(ex, "analysis", None)
                if analysis is not None:
                    analysis[id(child_op)] = {"op": "STREAM_SELECT",
                                              "mode": "fused",
                                              "rows_out": survivors}
                out = _apply_conn(conn, out, ex, p)
                return out
            return run_fused_agg
        # a secondary-index chain directly below the aggregate compiles
        # into the fused whole-chain dispatch (probe -> bitmap -> filter
        # -> reduce as one plan-cached kernel; columnar/plancache) with
        # the per-operator chain as its partitionwise fallback
        if child_op.kind in ("POST_VALIDATE_SELECT",
                             "PRIMARY_INDEX_LOOKUP") \
                and conn.name == "OneToOne":
            try:
                inner = _compile_index_path(child_op, ex,
                                            _agg_out_cols(aggs) or None,
                                            p, aggs=aggs)
            except Unsupported:
                inner = None
            if inner is not None:
                def run_index_agg():
                    out = inner()
                    ex.stats.vectorized(k, len(out))
                    return _apply_conn(conn, out, ex, p)
                return run_index_agg
        child = _compile(child_op, ex, _agg_out_cols(aggs) or None)

        def run_local_agg():
            cparts = child()
            cparts = _apply_conn(conn, cparts, ex, p)
            out = []
            for b in cparts:
                row, _surv = O.aggregate_batch(b, aggs, partial=True)
                out.append(ColumnBatch.from_rows([row]))
            ex.stats.vectorized(k, len(out))
            return out
        return run_local_agg

    if k == "GLOBAL_AGG":
        aggs = attrs["aggs"]
        _check_aggs(aggs)
        child = _compile(op.children[0], ex, None)
        conn = op.connectors[0]

        def run_global_agg():
            from ..storage.query import _agg_merge, _agg_row
            cparts = child()
            cparts = _apply_conn(conn, cparts, ex, p)
            rows = [r for b in cparts for r in b.to_rows()]
            merged = _agg_merge(rows, aggs) if rows \
                else _agg_row([], aggs, partial=False)
            out = [ColumnBatch.from_rows([merged])] + _empty(p - 1)
            ex.stats.vectorized(k, 1)
            return out
        return run_global_agg

    if k in ("LOCAL_PREAGG", "HASH_GROUP", "GLOBAL_GROUP"):
        keys = tuple(attrs["keys"])
        aggs = attrs["aggs"]
        _check_aggs(aggs)
        mode = {"LOCAL_PREAGG": "partial", "HASH_GROUP": "final",
                "GLOBAL_GROUP": "merge"}[k]
        child_needed = None if mode == "merge" \
            else set(keys) | _agg_out_cols(aggs)
        child = _compile(op.children[0], ex, child_needed)
        conn = op.connectors[0]

        def run_group():
            cparts = child()
            cparts = _apply_conn(conn, cparts, ex, p)
            out = [O.group_aggregate(b, keys, aggs, mode)
                   for b in cparts]
            ex.stats.vectorized(k, _total(out))
            return out
        return run_group

    if k in ("LOCAL_SORT", "LOCAL_TOPK"):
        keys = tuple(attrs["keys"])
        desc = attrs.get("desc", False)
        limit = attrs.get("n") if k == "LOCAL_TOPK" else None
        child_needed = None if needed is None else needed | set(keys)
        child = _compile(op.children[0], ex, child_needed)
        conn = op.connectors[0]

        def run_local_sort():
            cparts = child()
            cparts = _apply_conn(conn, cparts, ex, p)
            out = [O.sort_batch(b, keys, desc, limit) for b in cparts]
            ex.stats.vectorized(k, _total(out))
            return out
        return run_local_sort

    if k in ("SORT_MERGE_GATHER", "TOPK_MERGE"):
        keys = tuple(attrs["keys"])
        desc = attrs.get("desc", False)
        limit = attrs.get("n") if k == "TOPK_MERGE" else None
        child_needed = None if needed is None else needed | set(keys)
        child = _compile(op.children[0], ex, child_needed)
        conn = op.connectors[0]

        def run_merge_sort():
            cparts = child()
            cparts = _apply_conn(conn, cparts, ex, p)
            out = [O.sort_batch(cparts[0], keys, desc, limit)] \
                + list(cparts[1:])
            ex.stats.vectorized(k, _total(out))
            return out
        return run_merge_sort

    if k == "STREAM_LIMIT":
        n = attrs["n"]
        child = _compile(op.children[0], ex, needed)
        conn = op.connectors[0]

        def run_limit():
            cparts = child()
            cparts = _apply_conn(conn, cparts, ex, p)
            out = [b.slice(n) for b in cparts]
            ex.stats.vectorized(k, _total(out))
            return out
        return run_limit

    if k == "HYBRID_HASH_JOIN":
        lk, rk = tuple(attrs["lkeys"]), tuple(attrs["rkeys"])
        lneeded = None if needed is None else needed | set(lk)
        rneeded = None if needed is None else needed | set(rk)
        left = _compile(op.children[0], ex, lneeded)
        right = _compile(op.children[1], ex, rneeded)
        lconn, rconn = op.connectors

        def run_join():
            lparts = left()
            rparts = right()
            lparts = _apply_conn(lconn, lparts, ex, p)
            rparts = _apply_conn(rconn, rparts, ex, p)
            out = [O.join_batches(lb, rb, lk, rk)
                   for lb, rb in zip(lparts, rparts)]
            ex.stats.vectorized(k, _total(out))
            return out
        return run_join

    raise Unsupported(k)


# ---------------------------------------------------------------------------
# index access paths (the Figure-6 chain, vectorized)
# ---------------------------------------------------------------------------

def _chain_child(op: PhysicalOp, kind: str) -> PhysicalOp:
    """The chain's edges are all OneToOne (R2 keeps secondary lookups
    node-local); anything else stays on the row engine."""
    if len(op.children) != 1 or op.connectors[0].name != "OneToOne":
        raise Unsupported(f"{op.kind} connector")
    child = op.children[0]
    if child.kind != kind:
        raise Unsupported(f"{op.kind} over {child.kind}")
    return child


def _pk_intersect_mask(ds: Any, i: int, cands) -> Optional[Any]:
    """Legacy candidate-PK surface -> position bitmap via the fused
    sorted-intersection kernel (datasets without the bitmap surface)."""
    if not len(cands):
        return None
    keys = ds.partition_pk_array(i)
    if not len(keys):
        return None
    return O.candidate_position_mask(keys, cands)


def _search_mask(ds: Any, i: int, search: PhysicalOp):
    """Candidate position bitmap of the chain's own index search on one
    partition (None: provably empty).  Datasets exposing the per-
    component postings surface produce the bitmap straight from CSR
    probes (searchsorted range slice / segment gather + one scatter);
    the PK-array surface falls back to sorted-intersection."""
    a = search.attrs
    if search.kind == "SECONDARY_INDEX_SEARCH":
        if hasattr(ds, "secondary_candidate_mask"):
            return ds.secondary_candidate_mask(i, a["field"], a["lo"],
                                               a["hi"])
        return _pk_intersect_mask(
            ds, i, ds.secondary_candidate_pks(i, a["field"], a["lo"],
                                              a["hi"]))
    if search.kind == "SPATIAL_INDEX_SEARCH":
        center, radius = a["args"]
        if hasattr(ds, "spatial_candidate_mask"):
            return ds.spatial_candidate_mask(i, a["field"], center, radius)
        return _pk_intersect_mask(
            ds, i, ds.spatial_candidate_pks(i, a["field"], center, radius))
    token, fuzzy_ed = a["args"]
    if hasattr(ds, "keyword_candidate_mask"):
        return ds.keyword_candidate_mask(i, a["field"], token, fuzzy_ed)
    return _pk_intersect_mask(
        ds, i, ds.keyword_candidate_pks(i, a["field"], token, fuzzy_ed))


def _range_mask(ds: Any, i: int, f: str, lo: Any, hi: Any):
    """One extra btree-indexed range field's candidate bitmap (multi-
    index conjunction)."""
    if hasattr(ds, "secondary_candidate_mask"):
        return ds.secondary_candidate_mask(i, f, lo, hi)
    return O.candidate_position_mask(
        ds.partition_pk_array(i), ds.secondary_candidate_pks(i, f, lo, hi))


def _compile_index_path(op: PhysicalOp, ex: Any,
                        needed: Optional[Set[str]], p: int,
                        aggs: Optional[Dict[str, Tuple[str, str]]] = None
                        ) -> Node:
    """Lower POST_VALIDATE_SELECT <- PRIMARY_INDEX_LOOKUP <- SORT_PK <-
    {SECONDARY,SPATIAL,KEYWORD}_INDEX_SEARCH onto the columnar engine:
    each partition's search yields a candidate position bitmap straight
    from the per-component CSR postings (searchsorted over the sorted
    key dictionary -> gathered position segments -> one scatter pass,
    composed with the newest-wins live selection; every additional
    btree-indexed range field contributes another bitmap, ANDed in
    before any gather), and the surviving positions gather the cached
    columns for post-validation — no (key, pk) pair is ever walked and
    no row dict is materialized for a non-matching candidate.  Datasets
    exposing only sorted candidate-PK arrays keep the fused
    sorted-intersection kernel path.

    The fuzzy variant (SORT_PK <- T_OCCURRENCE <- NGRAM_INDEX_SEARCH)
    joins the same pipeline one step earlier: the ngram T-occurrence
    kernel produces the position bitmap *directly* (postings store row
    positions, so no PK intersection is needed), conjunctions AND in
    exactly as above, and the VERIFY stage replaces the row-at-a-time
    predicate with the batched similarity kernels over the gathered
    column's dictionary (``fuzzy.verify.verify_mask``).  Chain rows count
    into ``ExecStats.rows_fuzzy_vectorized``."""
    if op.kind == "POST_VALIDATE_SELECT":
        validate: Optional[PhysicalOp] = op
        lookup = _chain_child(op, "PRIMARY_INDEX_LOOKUP")
    else:
        validate, lookup = None, op
    sort = _chain_child(lookup, "SORT_PK")
    search = sort.children[0] if len(sort.children) == 1 else None
    tocc = None
    if search is not None and search.kind == "T_OCCURRENCE":
        tocc = search
        search = _chain_child(search, "NGRAM_INDEX_SEARCH")
    if search is None or search.kind not in _INDEX_SEARCHES \
            or sort.connectors[0].name != "OneToOne":
        raise Unsupported("SORT_PK without an index search below")
    is_fuzzy = search.kind == "NGRAM_INDEX_SEARCH"
    ds = _columnar_dataset(ex, lookup.attrs["dataset"], index=True,
                           fuzzy=is_fuzzy)
    if search.attrs["dataset"] != lookup.attrs["dataset"]:
        raise Unsupported("index search against a different dataset")

    ranges = dict(validate.attrs.get("ranges") or {}) if validate else {}
    pred = validate.attrs.get("pred") if validate else None
    fields = tuple(validate.attrs.get("fields", ())) if validate else ()
    residual = not (validate.attrs.get("ranges_exact", False)
                    if validate else True)
    fuzzy_spec = search.attrs.get("spec") if is_fuzzy else None
    if is_fuzzy:
        # verification uses the *spec's* gram length (the predicate's
        # semantics); the index's gram_length only shapes the candidate
        # postings.  Like every other access path, the full pred
        # re-checks the gathered survivors unless the plan declared
        # ``ranges_exact`` (pred may carry conjuncts beyond the spec).
        from ..fuzzy.ngram import spec_gram_length
        gram_k = spec_gram_length(fuzzy_spec)
    else:
        gram_k = 3
    # fields names exactly what pred reads, so projected gathers stay safe
    # even when a range column degrades to a row-at-a-time re-check
    fz_cols = {fuzzy_spec[0]} if fuzzy_spec is not None else set()
    cols = None if needed is None \
        else sorted(set(needed) | set(ranges) | set(fields) | fz_cols)
    # multi-index conjunction: every other btree-indexed range field adds
    # a candidate bitmap of its own
    search_field = search.attrs.get("field")
    extra_fields = tuple(
        f for f in ranges
        if f != search_field
        and getattr(ds, "index_kinds", {}).get(f) == "btree")
    # ranges already guaranteed by a candidate bitmap (the index holds the
    # row's *current* value, so live entries are never stale here) need no
    # vectorized re-check; only non-indexed range fields remain
    validate_ranges = dict(ranges)
    for f in extra_fields:
        validate_ranges.pop(f, None)
    if search.kind == "SECONDARY_INDEX_SEARCH" \
            and search_field in validate_ranges \
            and tuple(validate_ranges[search_field]) == \
                (search.attrs["lo"], search.attrs["hi"]):
        validate_ranges.pop(search_field)

    if aggs is not None and is_fuzzy:
        raise Unsupported("fuzzy aggregate chain")   # generic path handles
    # whole-chain fused dispatch (columnar/plancache): compiled once per
    # plan shape, runs the probe -> AND -> filter (-> reduce) pipeline as
    # one kernel over pooled device buffers.  Partitions it declines fall
    # through to the per-operator path below — results are identical.
    fused = None
    if not is_fuzzy and search.kind == "SECONDARY_INDEX_SEARCH":
        from . import plancache as PC
        chain_ops = (search.kind, "SORT_PK", "PRIMARY_INDEX_LOOKUP") \
            + (("POST_VALIDATE_SELECT",) if validate is not None else ()) \
            + (("LOCAL_AGG",) if aggs is not None else ())
        fused = PC.compile_chain(
            ds, chain_ops=chain_ops, search_field=search_field,
            search_bounds=(search.attrs["lo"], search.attrs["hi"]),
            extra=[(f,) + tuple(ranges[f]) for f in extra_fields],
            validate_ranges=validate_ranges, pred=pred,
            residual=residual, fields=fields, aggs=aggs)

    def run_index_path():
        from ..fuzzy.verify import verify_mask
        stat = ex.stats.fuzzy_vectorized if is_fuzzy \
            else ex.stats.index_vectorized
        out: List[ColumnBatch] = []
        n_cand = n_found = n_valid = 0
        empty_row = None
        if aggs is not None:
            from . import plancache as PC
            # what LOCAL_AGG yields for an empty / padding partition
            empty_row = PC.empty_partition_agg(aggs)

        def emit_empty():
            out.append(ColumnBatch.from_rows([dict(empty_row)])
                       if aggs is not None else ColumnBatch({}, 0))

        # SPMD: all partitions' fused chains as one stacked shard_map
        # dispatch over the active mesh (plancache.run_all); a None
        # return or per-partition None entries keep the loop below
        spmd_res = fused.run_all(cols) if fused is not None else None
        for i in range(ds.num_partitions):
            if spmd_res is not None:
                res = spmd_res[i]      # None: legacy path, same as loop
            else:
                res = fused(i, cols) if fused is not None else None
            if res is not None:
                n_cand += res.n_cand
                n_found += res.n_found
                n_valid += res.n_valid
                out.append(ColumnBatch.from_rows([res.row])
                           if aggs is not None else res.batch)
                continue
            if is_fuzzy:
                # T-occurrence candidate bitmap, already position-aligned
                # with the partition's scan batch — no PK intersection
                mask = ds.ngram_candidate_mask(i, search.attrs["field"],
                                               fuzzy_spec)
                n_cand += int(mask.sum())
                if not mask.any():
                    emit_empty()                 # no candidates
                    continue
            else:
                mask = _search_mask(ds, i, search)
                if mask is None or not mask.any():
                    emit_empty()                 # short-circuit: no scan
                    continue
                n_cand += int(mask.sum())
            for f in extra_fields:
                if not mask.any():
                    break
                lo, hi = ranges[f]
                mask = mask & _range_mask(ds, i, f, lo, hi)
            if not mask.any():
                emit_empty()                     # empty intersection
                continue
            n_found += int(mask.sum())           # live candidates gathered
            batch = ds.scan_partition_batch(i, cols)
            if fuzzy_spec is not None and validate is not None:
                # VERIFY: batched similarity kernels over the candidate
                # positions' dictionary-coded column (per distinct value)
                mask = verify_mask(batch, mask, fuzzy_spec, gram_k)
            if validate is not None and (validate_ranges
                                         or (residual and pred is not None)):
                got = O.index_post_validate(batch, mask, validate_ranges,
                                            pred, residual, fields)
            else:
                got = batch.filter(mask)
            n_valid += len(got)
            if aggs is not None:
                row, _surv = O.aggregate_batch(got, aggs, partial=True)
                out.append(ColumnBatch.from_rows([row]))
            else:
                out.append(got)
        if aggs is not None:
            for _ in range(p - ds.num_partitions):
                emit_empty()
        else:
            out += _empty(p - ds.num_partitions)
        stat(search.kind, n_cand)
        if is_fuzzy:
            stat("T_OCCURRENCE", n_cand)
        stat("SORT_PK", n_cand)
        stat("PRIMARY_INDEX_LOOKUP", n_found)
        if validate is not None:
            stat("POST_VALIDATE_SELECT", n_valid)
        analysis = getattr(ex, "analysis", None)
        if analysis is not None:
            # per-stage cardinalities for explain_analyze: the chain runs
            # as one fused closure, so its inner ops never see execute_op
            entries = [(search, n_cand), (sort, n_cand), (lookup, n_found)]
            if tocc is not None:
                entries.insert(1, (tocc, n_cand))
            if validate is not None:
                entries.append((validate, n_valid))
            for chain_op, n in entries:
                analysis[id(chain_op)] = {"op": chain_op.kind,
                                          "mode": "fused", "rows_out": n}
        return out
    return run_index_path
