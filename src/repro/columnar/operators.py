"""Vectorized physical operators over ColumnBatches.

Each function mirrors the row-at-a-time semantics of one
``storage/query.py`` operator exactly (same aggregate null handling, same
partial/merge calculus, same hash-partition placement), but evaluates on
dense columns via ``kernels/columnar_ops``.  When a batch turns out not
to be vectorizable at runtime (``obj`` columns where the plan needs
comparisons — schema drift on open types), operators degrade to a
row-at-a-time pass over the decoded batch rather than failing: the
lowering decision was made before the data was seen.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels import columnar_ops as K
from .batch import Column, ColumnBatch, MISSING, build_column
from .schema import VECTOR_KINDS, decode_scalar, encode_scalar

__all__ = [
    "EMPTY", "make_range_preds", "select_batch", "select_batch_with_mask",
    "aggregate_batch", "fused_select_aggregate", "group_aggregate",
    "sort_batch",
    "join_batches", "partition_ids", "concat_gather",
    "candidate_position_mask", "index_post_validate",
]

EMPTY = object()          # make_range_preds: "no row can match"

_INT_LIKE = ("i64", "dt", "date", "bool")
_AGG_FNS = ("count", "sum", "min", "max", "avg")


# ---------------------------------------------------------------------------
# predicates
# ---------------------------------------------------------------------------

def _str_bounds(col: Column, lo: Any, hi: Any) -> Tuple[Any, Any]:
    """Translate string bounds into dictionary-code bounds (the dictionary
    is sorted, so code order == lexicographic order)."""
    vals = np.asarray(col.values or [], dtype=object)
    clo = None if lo is None else int(np.searchsorted(vals, lo, "left"))
    chi = None if hi is None else int(np.searchsorted(vals, hi, "right")) - 1
    return clo, chi


def make_range_preds(batch: ColumnBatch,
                     ranges: Dict[str, Tuple[Any, Any]]
                     ) -> Optional[List[K.Pred]]:
    """Compile sargable [lo, hi] bounds into kernel predicates.  Returns
    EMPTY when a referenced column is entirely absent, None when any
    column/literal cannot be evaluated vectorized."""
    preds: List[K.Pred] = []
    for fld, (lo, hi) in ranges.items():
        col = batch.columns.get(fld)
        if col is None:
            return EMPTY          # type: ignore[return-value]
        if col.kind not in VECTOR_KINDS:
            return None
        try:
            if col.kind == "str":
                if not (lo is None or isinstance(lo, str)) \
                        or not (hi is None or isinstance(hi, str)):
                    return None
                lo, hi = _str_bounds(col, lo, hi)
                if hi is not None and hi < 0:
                    return EMPTY  # type: ignore[return-value]
            else:
                lo = None if lo is None else encode_scalar(lo, col.kind)
                hi = None if hi is None else encode_scalar(hi, col.kind)
        except (TypeError, ValueError, OverflowError):
            return None
        # cached pow2 views: stable shapes AND stable identities (the
        # device buffer pool keys on these arrays)
        data, valid = col.padded_int64() if col.kind == "bool" \
            else col.padded()
        preds.append((data, valid, lo, hi))
    return preds


def select_batch(batch: ColumnBatch, ranges: Dict[str, Tuple[Any, Any]],
                 pred: Optional[Any], residual: bool) -> ColumnBatch:
    """STREAM_SELECT: vectorized range mask, then (unless the plan marked
    the ranges exact) the full row predicate re-checked on survivors."""
    n = len(batch)
    preds = make_range_preds(batch, ranges) if ranges else None
    if preds is EMPTY:
        return batch.take(np.zeros(0, dtype=np.int64))
    if preds is None:
        # not vectorizable here: decoded row-at-a-time pass
        keep = np.fromiter((bool(pred(r)) for r in batch.to_rows()),
                           dtype=bool, count=n)
        return batch.filter(keep)
    out = batch.filter(K.range_mask(preds, n))
    if residual and pred is not None:
        rows = out.to_rows()
        keep = np.fromiter((bool(pred(r)) for r in rows), dtype=bool,
                           count=len(rows))
        out = out.filter(keep)
    return out


def select_batch_with_mask(batch: ColumnBatch, mask: np.ndarray,
                           pred: Optional[Any], residual: bool
                           ) -> ColumnBatch:
    """:func:`select_batch`'s tail when the range mask was already
    computed elsewhere (the SPMD path batches all partitions' masks into
    one dispatch — ``runtime/spmd.batched_range_masks``)."""
    out = batch.filter(mask)
    if residual and pred is not None:
        rows = out.to_rows()
        keep = np.fromiter((bool(pred(r)) for r in rows), dtype=bool,
                           count=len(rows))
        out = out.filter(keep)
    return out


# ---------------------------------------------------------------------------
# index access: candidate PKs -> position bitmap
# ---------------------------------------------------------------------------

def candidate_position_mask(keys: np.ndarray, cands: np.ndarray
                            ) -> np.ndarray:
    """Position bitmap of a sorted candidate-PK array over a partition's
    sorted live-pk array (``storage.dataset.partition_pk_array``).  Numeric
    pk domains run the fused Pallas/jnp sorted-intersection kernel; object
    pks (strings, tuples) intersect via the numpy sorted merge, degrading
    to set membership when the key domain is not totally ordered.  Multi-
    index conjunctions AND these bitmaps together before any record is
    gathered or decoded."""
    n = int(len(keys))
    if n == 0 or len(cands) == 0:
        return np.zeros(n, dtype=bool)
    if keys.dtype != object and keys.dtype.kind in "biuf" \
            and cands.dtype != object and cands.dtype.kind in "biuf":
        return K.sorted_intersect_mask(keys, cands)
    try:
        return K._sorted_merge_mask(keys, cands)
    except TypeError:          # mixed / incomparable pk types
        cs = set(cands.tolist())
        return np.fromiter((k in cs for k in keys.tolist()),
                           dtype=bool, count=n)


def index_post_validate(batch: ColumnBatch, mask: np.ndarray,
                        ranges: Dict[str, Tuple[Any, Any]],
                        pred: Optional[Any], residual: bool,
                        fields: Sequence[str] = ()) -> ColumnBatch:
    """POST_VALIDATE_SELECT over a candidate position bitmap: the sargable
    ranges are re-checked vectorized on the *partition* batch (stable
    shapes, so the jitted mask kernel never retraces per query) and ANDed
    into the bitmap before the gather; the residual row predicate — or the
    whole predicate, for opaque (spatial/keyword) criteria and columns
    that degrade to ``obj`` — runs row-at-a-time on the gathered survivors
    only.  When the bitmap is sparse relative to the partition, the whole
    re-check runs row-at-a-time on the few gathered candidates instead
    (``ranges`` is implied by ``pred``, the select contract), dodging the
    whole-partition mask's dispatch floor on selective queries."""
    n = len(batch)
    found = int(mask.sum())
    need_pred = pred is not None and residual
    if ranges:
        if pred is not None and found * 8 < n:
            need_pred = True       # pred implies ranges (select contract)
        else:
            preds = make_range_preds(batch, ranges)
            if preds is EMPTY:
                return batch.take(np.zeros(0, dtype=np.int64))
            if preds is None:      # obj-degraded column: pred row-checks
                need_pred = pred is not None
            else:
                mask = mask & K.range_mask(preds, n)
    got = batch.filter(mask)
    if need_pred and len(got):
        # decode only the fields pred declares it reads (the select
        # contract R1 also relies on): survivors alone pay full decode
        view = got.project(list(fields)) if fields else got
        rows = view.to_rows()
        keep = np.fromiter((bool(pred(r)) for r in rows), dtype=bool,
                           count=len(rows))
        got = got.filter(keep)
    return got


# ---------------------------------------------------------------------------
# aggregation (matches storage/query._agg_row / _agg_merge exactly)
# ---------------------------------------------------------------------------

def _kernel_agg_cols(batch: ColumnBatch,
                     aggs: Dict[str, Tuple[str, str]]
                     ) -> Tuple[List[Tuple[np.ndarray, np.ndarray]],
                                List[Tuple[str, str, str, Column]]]:
    """Columns the fused kernel can reduce: [(data, valid)], plus
    bookkeeping (name, fn, kind, col) aligned with them."""
    arrays, meta = [], []
    for name, (fn, cname) in aggs.items():
        if cname == "*":
            continue
        col = batch.columns.get(cname)
        if col is None or col.kind == "obj":
            continue
        if fn in ("sum", "avg") and col.kind not in ("i64", "f64", "bool"):
            continue
        # cached pow2 views: stable shapes and pool-stable identities
        data, valid = col.padded_int64() if col.kind == "bool" \
            else col.padded()
        arrays.append((data, valid))
        meta.append((name, fn, col.kind, col))
    return arrays, meta


def _decode_agg(v: Any, kind: str, col: Column) -> Any:
    if v is None:
        return None
    if kind == "str":
        return (col.values or [])[int(v)]
    if kind == "bool":
        return bool(v)
    return decode_scalar(v, kind)


def _py_agg_vals(batch: ColumnBatch, cname: str) -> List[Any]:
    col = batch.columns.get(cname)
    if col is None:
        return []
    return [v for v in col.decode() if v is not MISSING and v is not None]


def _finish_agg(out: Dict[str, Any], name: str, fn: str, partial: bool,
                cnt: int, s: Any, mn: Any, mx: Any) -> None:
    if fn == "count":
        out[name] = cnt
    elif fn == "sum":
        out[name] = s if cnt else 0
    elif fn == "min":
        out[name] = mn
    elif fn == "max":
        out[name] = mx
    elif fn == "avg":
        if partial:
            out[name + "__sum"] = s if cnt else 0
            out[name + "__cnt"] = cnt
        else:
            out[name] = (s / cnt) if cnt else None


def aggregate_batch(batch: ColumnBatch, aggs: Dict[str, Tuple[str, str]],
                    partial: bool,
                    ranges: Optional[Dict[str, Tuple[Any, Any]]] = None
                    ) -> Optional[Tuple[Dict[str, Any], int]]:
    """LOCAL_AGG (partial=True) / direct aggregation of one batch.  With
    ``ranges`` the predicate is fused into the same kernel pass (the
    filter+aggregate hot path); returns None if the fused predicate is
    not vectorizable (caller filters first, then retries without
    ranges).  Returns (aggregate row, predicate survivor count)."""
    n = len(batch)
    preds: List[K.Pred] = []
    if ranges:
        made = make_range_preds(batch, ranges)
        if made is None:
            return None
        preds = [] if made is EMPTY else made
        if made is EMPTY:
            n = 0
            batch = batch.take(np.zeros(0, dtype=np.int64))
    arrays, meta = _kernel_agg_cols(batch, aggs)
    res = K.fused_filter_aggregate(preds, arrays, n)

    def survivors() -> ColumnBatch:
        # non-vectorizable columns pay one mask gather, shared across them
        return batch.filter(K.range_mask(preds, len(batch))) if preds \
            else batch

    return _finish_aggregate(aggs, meta, res, partial, survivors)


def _finish_aggregate(aggs: Dict[str, Tuple[str, str]],
                      meta: List[Tuple[str, str, str, Column]],
                      res: Dict[str, Any], partial: bool,
                      survivors: Any) -> Tuple[Dict[str, Any], int]:
    """Decode one fused-reduction result into the aggregate row — the
    single decode shared by the kernel loop path (:func:`aggregate_batch`)
    and the stacked SPMD path (``runtime/spmd.batched_select_aggregate``),
    so the two are bit-identical by construction.  ``res`` is the
    ``fused_filter_aggregate`` dict; ``survivors`` lazily materializes
    the predicate-filtered batch for non-vectorizable columns (called at
    most once)."""
    total = res["count"]
    out: Dict[str, Any] = {}
    by_name = {m[0]: (i, m) for i, m in enumerate(meta)}
    got: Optional[ColumnBatch] = None
    for name, (fn, cname) in aggs.items():
        if fn == "count" and cname == "*":
            out[name] = total
            continue
        if name in by_name and by_name[name][1][1] == fn:
            i, (_, _, kind, col) = by_name[name]
            s = res["sums"][i]
            mn = _decode_agg(res["mins"][i], kind, col)
            mx = _decode_agg(res["maxs"][i], kind, col)
            if kind == "i64" and isinstance(s, float):
                s = int(s)      # TPU f32 path returns floats
            _finish_agg(out, name, fn, partial, res["cnts"][i], s, mn, mx)
            continue
        # non-vectorizable column (obj / exotic combo): decoded python pass,
        # computing only the reduction the agg fn asks for (min/max of
        # non-summable values must not touch sum, like the row engine)
        if got is None:
            got = survivors()
        vals = got.to_rows() if cname == "*" else _py_agg_vals(got, cname)
        reduce_sum = fn in ("sum", "avg") and vals and cname != "*"
        _finish_agg(out, name, fn, partial, len(vals),
                    sum(vals) if reduce_sum else 0,
                    min(vals) if (fn == "min" and vals and cname != "*")
                    else None,
                    max(vals) if (fn == "max" and vals and cname != "*")
                    else None)
    return out, total


def fused_select_aggregate(batch: ColumnBatch,
                           ranges: Dict[str, Tuple[Any, Any]],
                           aggs: Dict[str, Tuple[str, str]],
                           partial: bool
                           ) -> Optional[Tuple[Dict[str, Any], int]]:
    """STREAM_SELECT(exact ranges) + LOCAL_AGG fused into one kernel
    pass."""
    return aggregate_batch(batch, aggs, partial, ranges=ranges)


# ---------------------------------------------------------------------------
# grouped aggregation
# ---------------------------------------------------------------------------

def _encode_group_keys(batch: ColumnBatch, keys: Sequence[str]
                       ) -> Optional[List[np.ndarray]]:
    arrs = []
    for k in keys:
        col = batch.columns.get(k)
        if col is None or col.kind not in VECTOR_KINDS \
                or col.kind == "f64" or not col.valid.all():
            return None
        arrs.append(col.data.astype(np.int64))
    return arrs


def _group_ids(arrs: List[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    if len(arrs) == 1:
        uniq, inv = np.unique(arrs[0], return_inverse=True)
        return uniq.reshape(-1, 1), inv
    stack = np.stack(arrs, axis=1)
    uniq, inv = np.unique(stack, axis=0, return_inverse=True)
    return uniq, inv


def _group_sum(inv: np.ndarray, g: int, data: np.ndarray, ok: np.ndarray,
               int_exact: bool) -> np.ndarray:
    if int_exact:
        out = np.zeros(g, dtype=np.int64)
        np.add.at(out, inv[ok], data[ok])
        return out
    return np.bincount(inv[ok], weights=data[ok].astype(np.float64),
                       minlength=g)


def _group_minmax(inv: np.ndarray, g: int, data: np.ndarray,
                  ok: np.ndarray, is_min: bool) -> np.ndarray:
    if np.issubdtype(data.dtype, np.integer):
        ident = np.iinfo(data.dtype).max if is_min \
            else np.iinfo(data.dtype).min
    else:
        ident = np.inf if is_min else -np.inf
    out = np.full(g, ident, dtype=data.dtype)
    (np.minimum if is_min else np.maximum).at(out, inv[ok], data[ok])
    return out


def group_aggregate(batch: ColumnBatch, keys: Sequence[str],
                    aggs: Dict[str, Tuple[str, str]], mode: str
                    ) -> ColumnBatch:
    """LOCAL_PREAGG (mode='partial') / HASH_GROUP ('final') /
    GLOBAL_GROUP ('merge').  Merge consumes partial columns when present
    and falls back to raw aggregation otherwise, exactly like
    storage/query._agg_merge.  Aggregates over empty value sets surface
    as explicit nulls (the row engine emits ``name: None``), so
    downstream operators and the row boundary see them."""
    arrs = _encode_group_keys(batch, keys)
    if arrs is None:
        return _group_aggregate_rows(batch, keys, aggs, mode)
    uniq, inv = _group_ids(arrs)
    g = uniq.shape[0]
    n = len(batch)
    cols: Dict[str, Column] = {}
    allv = np.ones(g, dtype=bool)
    for j, k in enumerate(keys):
        src = batch.columns[k]
        data = uniq[:, j].astype(src.data.dtype)
        cols[k] = Column(src.kind, data, allv.copy(), src.values)

    def put(name: str, kind: str, data: np.ndarray, valid: np.ndarray,
            values: Optional[List[str]] = None) -> None:
        if valid.all():
            cols[name] = Column(kind, data, valid, values)
            return
        # empty-group aggregate: materialize the row engine's explicit
        # None (invalid would read as "field absent" downstream)
        dec = Column(kind, data, valid, values).decode()
        obj = np.empty(len(dec), dtype=object)
        for i2, v2 in enumerate(dec):
            obj[i2] = None if v2 is MISSING else v2
        cols[name] = Column("obj", obj, np.ones(len(dec), dtype=bool))

    for name, (fn, cname) in aggs.items():
        merge_partial = (mode == "merge"
                         and (name in batch.columns
                              or name + "__sum" in batch.columns))
        if merge_partial:
            if fn in ("count", "sum"):
                src = batch.columns[name]
                if src.kind not in ("i64", "f64"):
                    return _group_aggregate_rows(batch, keys, aggs, mode)
                data = _group_sum(inv, g, src.data, src.valid,
                                  src.kind == "i64")
                put(name, src.kind, data, allv.copy())
            elif fn in ("min", "max"):
                src = batch.columns[name]
                if src.kind not in VECTOR_KINDS:
                    return _group_aggregate_rows(batch, keys, aggs, mode)
                ok = src.valid
                cnt = np.bincount(inv[ok], minlength=g)
                data = _group_minmax(inv, g, src.data, ok, fn == "min")
                put(name, src.kind, data, cnt > 0, src.values)
            elif fn == "avg":
                ssrc = batch.columns[name + "__sum"]
                csrc = batch.columns[name + "__cnt"]
                if "obj" in (ssrc.kind, csrc.kind):
                    return _group_aggregate_rows(batch, keys, aggs, mode)
                s = _group_sum(inv, g, ssrc.data, ssrc.valid, False)
                c = _group_sum(inv, g, csrc.data, csrc.valid, True)
                data = np.divide(s, c, out=np.zeros(g), where=c > 0)
                put(name, "f64", data, c > 0)
            continue
        partial = (mode == "partial")
        if fn == "count" and cname == "*":
            put(name, "i64", np.bincount(inv, minlength=g).astype(np.int64),
                allv.copy())
            continue
        col = batch.columns.get(cname)
        if col is None:
            zero = np.zeros(g, dtype=np.int64)
            if fn == "count":
                put(name, "i64", zero, allv.copy())
            elif fn == "sum":
                put(name, "i64", zero, allv.copy())
            elif fn in ("min", "max"):
                put(name, "obj", np.empty(g, dtype=object),
                    np.zeros(g, dtype=bool))
            elif fn == "avg":
                if partial:
                    put(name + "__sum", "i64", zero, allv.copy())
                    put(name + "__cnt", "i64", zero.copy(), allv.copy())
                else:
                    put(name, "obj", np.empty(g, dtype=object),
                        np.zeros(g, dtype=bool))
            continue
        if col.kind == "obj" \
                or (fn in ("sum", "avg")
                    and col.kind not in ("i64", "f64", "bool")):
            return _group_aggregate_rows(batch, keys, aggs, mode)
        ok = col.valid
        cnt = np.bincount(inv[ok], minlength=g)
        if fn == "count":
            put(name, "i64", cnt.astype(np.int64), allv.copy())
            continue
        data = col.data.astype(np.int64) if col.kind == "bool" else col.data
        if fn in ("min", "max"):
            out = _group_minmax(inv, g, data, ok, fn == "min")
            put(name, col.kind, out.astype(col.data.dtype, copy=False),
                cnt > 0, col.values)
            continue
        s = _group_sum(inv, g, data, ok, col.kind != "f64")
        if fn == "sum":
            put(name, "f64" if col.kind == "f64" else "i64", s, allv.copy())
        elif fn == "avg":
            if partial:
                put(name + "__sum", "f64" if col.kind == "f64" else "i64",
                    s, allv.copy())
                put(name + "__cnt", "i64", cnt.astype(np.int64),
                    allv.copy())
            else:
                put(name, "f64",
                    np.divide(s.astype(np.float64), cnt,
                              out=np.zeros(g), where=cnt > 0), cnt > 0)
    return ColumnBatch(cols, g)


def _group_aggregate_rows(batch: ColumnBatch, keys: Sequence[str],
                          aggs: Dict[str, Tuple[str, str]], mode: str
                          ) -> ColumnBatch:
    """Decoded row-at-a-time fallback replicating the row engine's group
    operator (used when keys or aggregates are not vectorizable)."""
    from ..storage.query import _agg_merge, _agg_row
    groups: Dict[Tuple, List[Dict[str, Any]]] = {}
    for r in batch.to_rows():
        groups.setdefault(tuple(r[k] for k in keys), []).append(r)
    out_rows = []
    for gk, grows in groups.items():
        row = (_agg_merge(grows, aggs) if mode == "merge"
               else _agg_row(grows, aggs, partial=(mode == "partial")))
        row.update(dict(zip(keys, gk)))
        out_rows.append(row)
    return ColumnBatch.from_rows(out_rows)


# ---------------------------------------------------------------------------
# sort / top-k
# ---------------------------------------------------------------------------

def sort_batch(batch: ColumnBatch, keys: Sequence[str], desc: bool,
               limit: Optional[int] = None) -> ColumnBatch:
    n = len(batch)
    arrs = []
    vectorized = True
    for k in keys:
        col = batch.columns.get(k)
        if col is None or col.kind not in VECTOR_KINDS \
                or not col.valid.all():
            vectorized = False
            break
        a = col.data.astype(np.int64) if col.kind == "bool" else col.data
        arrs.append(-a if desc else a)   # negate: stable desc like sorted()
    if vectorized and keys:
        order = np.lexsort(tuple(reversed(arrs)))
    else:
        rows = batch.to_rows()
        order = np.asarray(sorted(range(n),
                                  key=lambda i: tuple(rows[i][k]
                                                      for k in keys),
                           reverse=desc), dtype=np.int64) \
            if n else np.zeros(0, dtype=np.int64)
    if limit is not None:
        order = order[:limit]
    return batch.take(order)


# ---------------------------------------------------------------------------
# hash join (int-domain keys; order-preserving on the probe side)
# ---------------------------------------------------------------------------

def _join_key_ids(lb: ColumnBatch, rb: ColumnBatch, lk: Sequence[str],
                  rk: Sequence[str]
                  ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    larrs, rarrs = [], []
    for lkey, rkey in zip(lk, rk):
        lc, rc = lb.columns.get(lkey), rb.columns.get(rkey)
        if lc is None or rc is None or not lc.valid.all() \
                or not rc.valid.all():
            return None
        if lc.kind != rc.kind or lc.kind not in VECTOR_KINDS:
            return None
        if lc.kind == "str":
            merged = np.asarray(
                sorted(set(lc.values or []) | set(rc.values or [])),
                dtype=object)
            llut = np.searchsorted(
                merged, np.asarray(lc.values or ["\0"], dtype=object))
            rlut = np.searchsorted(
                merged, np.asarray(rc.values or ["\0"], dtype=object))
            la = llut[lc.data].astype(np.int64)
            ra = rlut[rc.data].astype(np.int64)
        elif lc.kind == "f64":
            both = np.concatenate([lc.data, rc.data])
            _, inv = np.unique(both, return_inverse=True)
            la, ra = inv[:len(lc)], inv[len(lc):]
        else:
            la = lc.data.astype(np.int64)
            ra = rc.data.astype(np.int64)
        larrs.append(la)
        rarrs.append(ra)
    if len(larrs) == 1:
        return larrs[0], rarrs[0]
    lstack = np.stack(larrs, axis=1)
    rstack = np.stack(rarrs, axis=1)
    both = np.concatenate([lstack, rstack], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    return inv[:len(lb)], inv[len(lb):]


def _merge_collision(lcol: Column, rcol: Column) -> Column:
    """{**r, **l} per-row: left wins where the left field is present."""
    if lcol.kind == rcol.kind and lcol.kind != "str":
        data = np.where(lcol.valid, lcol.data, rcol.data)
        return Column(lcol.kind, data, lcol.valid | rcol.valid)
    lvals, rvals = lcol.decode(), rcol.decode()
    merged = [lv if lv is not MISSING else rv
              for lv, rv in zip(lvals, rvals)]
    return build_column(merged, "obj")


def join_batches(lb: ColumnBatch, rb: ColumnBatch, lk: Sequence[str],
                 rk: Sequence[str]) -> ColumnBatch:
    """HYBRID_HASH_JOIN on one partition: build right, probe left, output
    rows ``{**right, **left}`` in probe order."""
    ids = _join_key_ids(lb, rb, lk, rk)
    if ids is None:
        return _join_rows(lb, rb, lk, rk)
    lids, rids = ids
    r_order = np.argsort(rids, kind="stable")
    rs = rids[r_order]
    lo = np.searchsorted(rs, lids, "left")
    hi = np.searchsorted(rs, lids, "right")
    counts = hi - lo
    total = int(counts.sum())
    l_idx = np.repeat(np.arange(len(lids)), counts)
    starts = np.repeat(lo, counts)
    within = np.arange(total) - np.repeat(np.cumsum(counts) - counts,
                                          counts)
    r_idx = r_order[starts + within]
    left_t = lb.take(l_idx)
    right_t = rb.take(r_idx)
    cols: Dict[str, Column] = dict(right_t.columns)
    for name, col in left_t.columns.items():
        cols[name] = (_merge_collision(col, cols[name])
                      if name in cols else col)
    return ColumnBatch(cols, total)


def _join_rows(lb: ColumnBatch, rb: ColumnBatch, lk: Sequence[str],
               rk: Sequence[str]) -> ColumnBatch:
    table: Dict[Tuple, List[Dict[str, Any]]] = {}
    for r in rb.to_rows():
        table.setdefault(tuple(r[k] for k in rk), []).append(r)
    out = []
    for l in lb.to_rows():
        for r in table.get(tuple(l[k] for k in lk), ()):
            out.append({**r, **l})
    return ColumnBatch.from_rows(out)


# ---------------------------------------------------------------------------
# hash repartitioning (placement-identical to storage/dataset)
# ---------------------------------------------------------------------------

def partition_ids(batch: ColumnBatch, keys: Sequence[str], p: int
                  ) -> np.ndarray:
    """Target partition per row; bit-for-bit identical to
    ``storage.dataset.hash_partition`` so columnar and row pipelines
    shuffle rows to the same places."""
    from ..storage.dataset import hash_partition, hash_partition_array
    if len(keys) == 1:
        col = batch.columns.get(keys[0])
        if col is not None and col.kind in ("i64", "bool") \
                and col.valid.all():
            return hash_partition_array(col.data, p)
        if col is not None and col.kind == "str" and col.valid.all():
            lut = np.asarray([hash_partition(v, p)
                              for v in (col.values or [])],
                             dtype=np.int64)
            return lut[col.data] if len(col.values or []) \
                else np.zeros(len(batch), dtype=np.int64)
    rows = batch.project(list(keys)).to_rows()
    return np.asarray(
        [hash_partition(tuple(r[k] for k in keys) if len(keys) > 1
                        else r[keys[0]], p) for r in rows],
        dtype=np.int64) if rows else np.zeros(0, dtype=np.int64)


def concat_gather(cparts: Sequence[ColumnBatch]) -> ColumnBatch:
    return ColumnBatch.concat([b for b in cparts if len(b)])
