"""Compiled fused Figure-6 chains: one cached jit dispatch per plan shape.

The per-operator columnar index path (``lower._compile_index_path``) runs
a secondary-index chain as a handful of kernel dispatches per partition:
CSR probe scatter, extra-field bitmap ANDs, validate-range mask, then —
under a LOCAL_AGG — the fused filter+aggregate reduction, with the
candidate bitmap round-tripping to host between every step.  This module
compiles the whole chain

    index probe -> bitmap AND -> live gather -> filter / aggregate

into a single jitted core (``_chain_core``) whose operands are the
device-resident pooled buffers (``kernels/device_pool``): the per-tier
pow2-padded CSR positions arrays, the live-selection index, and the
partition batch's padded columns.  Probe bounds travel as dynamic 0-d
scalars, so a repeated query over a warm pool is exactly one dispatch
with ``h2d_bytes == 0`` and zero retraces.

Plan shapes are keyed by the chain's op sequence plus every retrace-
relevant static: pow2 buckets of the storage concat and live selection,
per-field tier shape tuples, predicate/aggregate dtypes.  The
:class:`PlanCache` records first sightings (``plan_cache.misses`` — the
warm-up trace) vs. repeats (``plan_cache.hits``); the jit trace cache
itself is the compiled artifact, so a hit is purely a dictionary probe.

Declines are cheap and total: any input the fused core cannot represent
exactly (unordered key dictionary, obj-degraded validate column, fuzzy
chains, live/storage mismatches mid-race) returns None and the caller
falls back to the per-operator path — results are bit-identical either
way (``tests/test_residency.py`` checks this differentially).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .. import obs
from ..kernels import device_pool as _pool
from ..kernels.columnar_ops import _TRACES, _ident
from ..obs import record_dispatch as _record_dispatch
from ..obs import record_retrace as _record_retrace
from .batch import ColumnBatch, pow2_len

__all__ = ["PlanCache", "plan_cache", "set_enabled", "totals",
           "compile_chain", "ChainResult"]

_HITS = obs.counter("plan_cache.hits")
_MISSES = obs.counter("plan_cache.misses")
_ENTRIES = obs.gauge("plan_cache.entries")


class PlanCache:
    """Plan-shape accounting for the fused chain dispatch.  The jit trace
    cache holds the compiled executables; this records which shapes have
    been seen (hit/miss/entries metrics survive ``obs.reset`` via the
    internal tallies, which :func:`totals` exposes for ExecStats
    diffing)."""

    def __init__(self) -> None:
        self._keys: set = set()
        self._hits = 0
        self._misses = 0
        self.enabled = True

    def note(self, key: Tuple) -> bool:
        """Record one fused dispatch under plan shape ``key``; True if the
        shape was already compiled (a cache hit)."""
        hit = key in self._keys
        if hit:
            self._hits += 1
            _HITS.inc()
        else:
            self._keys.add(key)
            self._misses += 1
            _MISSES.inc()
        # set (not inc) every note: the gauge resurvives obs.reset()
        _ENTRIES.set(len(self._keys))
        return hit

    def totals(self) -> Tuple[int, int]:
        return self._hits, self._misses

    def entry_count(self) -> int:
        return len(self._keys)

    def clear(self) -> None:
        """Forget seen plan shapes (metrics accounting only — compiled
        jit traces persist, so re-seen shapes re-warm without a trace)."""
        self._keys.clear()
        _ENTRIES.set(0)


plan_cache = PlanCache()


def set_enabled(v: bool) -> None:
    """Disable to force every chain down the per-operator legacy path
    (the differential harness runs both and compares)."""
    plan_cache.enabled = bool(v)


def totals() -> Tuple[int, int]:
    return plan_cache.totals()


# ---------------------------------------------------------------------------
# the fused core
# ---------------------------------------------------------------------------

def _chain_math(tiers, bounds, idx_pad, n_live, preds, aggds,
                total_p2, live_p2):
    """The chain math, jit-agnostic: traced once per bucket by
    :func:`_chain_core` (python loop, one partition per dispatch) and
    once per (mesh, bucket) by ``runtime/spmd._chain_fn`` (vmapped over
    the stacked partition axis inside ``shard_map``).  Padding a
    partition into a larger common bucket is exact: extra tier lanes
    fall outside their ``[a, b)`` slice and scatter into the sentinel,
    extra live lanes die on the ``n_live`` lane mask, and aggregate
    sums only ever add exact zeros (sum) or dtype-extreme identities
    (min/max) for masked lanes."""
    lane = jnp.arange(live_p2, dtype=jnp.int64) < n_live
    field_masks = []
    for field_pos, field_bounds in zip(tiers, bounds):
        cnt = jnp.zeros(total_p2 + 1, dtype=jnp.int32)
        for pos, (a, b, off) in zip(field_pos, field_bounds):
            iota = jnp.arange(pos.shape[0], dtype=jnp.int64)
            sel = (iota >= a) & (iota < b)
            tgt = jnp.where(sel, pos + off, total_p2)
            cnt = cnt.at[tgt].add(1)
        field_masks.append((cnt[:total_p2] > 0)[idx_pad])
    cand = field_masks[0] & lane
    n_cand = jnp.sum(cand)
    comb = cand
    for m in field_masks[1:]:
        comb = comb & m
    n_found = jnp.sum(comb)
    mask = comb
    for data, valid, lo, hi in preds:
        mask = mask & valid & (data >= lo) & (data <= hi)
    n_valid = jnp.sum(mask)
    per_col = []
    for data, valid in aggds:
        ok = mask & valid
        cnt_c = jnp.sum(ok)
        s = jnp.sum(jnp.where(ok, data, jnp.asarray(0, data.dtype)))
        mn = jnp.min(jnp.where(ok, data, _ident(data.dtype, True)))
        mx = jnp.max(jnp.where(ok, data, _ident(data.dtype, False)))
        per_col.append((s, mn, mx, cnt_c))
    return n_cand, n_found, n_valid, mask, tuple(per_col)


@functools.partial(jax.jit, static_argnames=("total_p2", "live_p2"))
def _chain_core(tiers, bounds, idx_pad, n_live, preds, aggds,
                total_p2, live_p2):
    """Whole-chain dispatch.  Static shapes: ``total_p2`` (pow2 bucket of
    the storage concat the CSR positions scatter into) and ``live_p2``
    (pow2 bucket of the live selection / partition batch).  Everything
    else — probe slice bounds, tier offsets, live count, range bounds —
    is a dynamic 0-d operand, so bound changes never retrace.

    Per range field: scatter the in-slice posting positions (sentinel
    slot ``total_p2`` swallows out-of-slice and padding lanes) into an
    occurrence count over the storage concat, then gather the >0 bitmap
    through the newest-wins live selection.  The first field is the
    chain's own index search (its survivor count is ``n_cand``); the
    rest AND in as the multi-index conjunction (``n_found``).  Validate
    ranges AND in as column compares, and the optional aggregate tail
    reduces survivors without materializing a gather."""
    _TRACES["n"] += 1
    _record_retrace()
    return _chain_math(tiers, bounds, idx_pad, n_live, preds, aggds,
                       total_p2, live_p2)


# ---------------------------------------------------------------------------
# host wrapper: gather operands, key the shape, dispatch, assemble
# ---------------------------------------------------------------------------

class ChainResult:
    """One partition's fused chain outcome.  ``batch`` carries the
    gathered survivors (mask mode) or None (aggregate mode, where ``row``
    holds the partial-aggregate row instead)."""

    __slots__ = ("batch", "row", "n_cand", "n_found", "n_valid")

    def __init__(self, batch, row, n_cand, n_found, n_valid):
        self.batch = batch
        self.row = row
        self.n_cand = n_cand
        self.n_found = n_found
        self.n_valid = n_valid


def _field_tiers(ds: Any, i: int, fld: str, lo: Any, hi: Any
                 ) -> Optional[Tuple[List[np.ndarray], List[Tuple], int,
                                     np.ndarray]]:
    """(padded per-tier positions, per-tier (a, b, off) bounds, storage
    concat length, live index) for one range field, or None when any tier
    defeats the fused representation (unordered keys, unencodable
    bounds)."""
    sources, total, idx = ds.secondary_fused_inputs(i, fld)
    pads: List[np.ndarray] = []
    abs_: List[Tuple] = []
    for off, p in sources:
        ab = p.range_offsets(lo, hi)
        if ab is None:
            return None
        pads.append(p.padded_positions())
        abs_.append((ab[0], ab[1], off))
    return pads, abs_, total, idx


def compile_chain(ds: Any, *, chain_ops: Tuple[str, ...], search_field: str,
                  search_bounds: Tuple[Any, Any],
                  extra: Sequence[Tuple[str, Any, Any]],
                  validate_ranges: Dict[str, Tuple[Any, Any]],
                  pred: Optional[Any], residual: bool,
                  fields: Sequence[str],
                  aggs: Optional[Dict[str, Tuple[str, str]]] = None):
    """Compile-time half of the fused chain: returns a per-partition
    runner ``run(i, cols) -> Optional[ChainResult]`` or None when the
    chain can never fuse (dataset without the raw-operand surface,
    aggregate mode with a residual row predicate — the gathered-survivor
    semantics the core cannot reduce on-device)."""
    if not hasattr(ds, "secondary_fused_inputs"):
        return None
    if aggs is not None and residual and pred is not None:
        # legacy aggregates the row-checked survivors; the core cannot
        return None
    range_fields = [(search_field, search_bounds[0], search_bounds[1])]
    range_fields += [tuple(e) for e in extra]

    def _gather(i: int, cols: Optional[Sequence[str]]
                ) -> Optional[Dict[str, Any]]:
        """One partition's fused-chain operands, or None when this
        partition defeats the fused representation and must run the
        per-operator legacy path.  An ``{"empty": True}`` marker flags a
        short-circuitable partition (no storage / no live rows) — the
        loop path declines those to legacy, and ``run_all`` hands them
        back as per-partition fallbacks for exactly the same reason."""
        from . import operators as O
        tiers: List[Tuple[np.ndarray, ...]] = []
        bounds: List[Tuple[Tuple, ...]] = []
        total0 = idx0 = None
        for fld, lo, hi in range_fields:
            ft = _field_tiers(ds, i, fld, lo, hi)
            if ft is None:
                return None
            pads, abs_, total, idx = ft
            if total0 is None:
                total0, idx0 = total, idx
            elif total != total0 or idx is not idx0:
                return None        # raced a writer between field probes
            tiers.append(tuple(pads))
            bounds.append(tuple(abs_))
        n_live = int(idx0.shape[0])
        if total0 == 0 or n_live == 0:
            return {"empty": True}  # legacy short-circuits these for free
        batch = ds.scan_partition_batch(i, cols)
        if len(batch) != n_live:
            return None            # raced a writer between probe and scan
        preds = []
        if validate_ranges:
            made = O.make_range_preds(batch, validate_ranges)
            if made is None or made is O.EMPTY:
                return None
            preds = made
        agg_arrays: List[Tuple[np.ndarray, np.ndarray]] = []
        agg_meta: List[Tuple] = []
        if aggs is not None:
            agg_arrays, agg_meta = O._kernel_agg_cols(batch, aggs)
        total_p2 = pow2_len(total0)
        idx_pad = _pool.padded(idx0, fill="zero")
        live_p2 = int(idx_pad.shape[0])
        # every padded column must sit in the same pow2 bucket as the
        # live selection, or the core's mask/data shapes disagree
        if any(int(d.shape[0]) != live_p2 for d, _v, _lo, _hi in preds) \
                or any(int(d.shape[0]) != live_p2 for d, _v in agg_arrays):
            return None
        return {"tiers": tiers, "bounds": bounds, "total_p2": total_p2,
                "idx_pad": idx_pad, "live_p2": live_p2, "n_live": n_live,
                "batch": batch, "preds": preds, "agg_arrays": agg_arrays,
                "agg_meta": agg_meta}

    def _assemble(batch: ColumnBatch, n_live: int, mask_np: np.ndarray,
                  per_col: Sequence[Tuple], agg_meta: Sequence[Tuple],
                  n_cand: int, n_found: int, n_valid: int
                  ) -> ChainResult:
        """Shared result assembly for the loop and SPMD dispatch paths
        (``per_col`` scalars arrive as 0-d device results or stacked-row
        slices; both support ``.item()``)."""
        from . import operators as O
        if aggs is None:
            got = batch.filter(mask_np[:n_live])
            if residual and pred is not None and len(got):
                view = got.project(list(fields)) if fields else got
                rows = view.to_rows()
                keep = np.fromiter((bool(pred(r)) for r in rows),
                                   dtype=bool, count=len(rows))
                got = got.filter(keep)
            return ChainResult(got, None, n_cand, n_found, len(got))

        # aggregate mode: device scalars for kernelable columns, one host
        # pass over the gathered survivors for the rest — exactly
        # ``operators.aggregate_batch`` over the filtered batch
        row: Dict[str, Any] = {}
        by_name = {m[0]: (j, m) for j, m in enumerate(agg_meta)}
        got = None
        for name, (fn, cname) in aggs.items():
            if fn == "count" and cname == "*":
                row[name] = n_valid
                continue
            if name in by_name and by_name[name][1][1] == fn:
                j, (_, _, kind, col) = by_name[name]
                s, mn, mx, c = per_col[j]
                c = int(c)
                s = s.item()
                mn = O._decode_agg(mn.item() if c else None, kind, col)
                mx = O._decode_agg(mx.item() if c else None, kind, col)
                if kind == "i64" and isinstance(s, float):
                    s = int(s)
                O._finish_agg(row, name, fn, True, c, s, mn, mx)
                continue
            if got is None:        # numpy gather, no kernel dispatch
                got = batch.filter(mask_np[:n_live])
            vals = got.to_rows() if cname == "*" \
                else O._py_agg_vals(got, cname)
            reduce_sum = fn in ("sum", "avg") and vals and cname != "*"
            O._finish_agg(row, name, fn, True, len(vals),
                          sum(vals) if reduce_sum else 0,
                          min(vals) if (fn == "min" and vals
                                        and cname != "*") else None,
                          max(vals) if (fn == "max" and vals
                                        and cname != "*") else None)
        return ChainResult(None, row, n_cand, n_found, n_valid)

    def run(i: int, cols: Optional[Sequence[str]]
            ) -> Optional[ChainResult]:
        if not plan_cache.enabled:
            return None
        g = _gather(i, cols)
        if g is None or g.get("empty"):
            return None
        tiers, bounds = g["tiers"], g["bounds"]
        preds, agg_arrays = g["preds"], g["agg_arrays"]
        total_p2, live_p2 = g["total_p2"], g["live_p2"]
        idx_pad, n_live = g["idx_pad"], g["n_live"]
        key = (chain_ops, total_p2, live_p2,
               tuple(tuple(int(p.shape[0]) for p in fp) for fp in tiers),
               tuple(str(d.dtype) for d, _v, _lo, _hi in preds),
               tuple(str(d.dtype) for d, _v in agg_arrays),
               aggs is not None, _spmd().mesh_key())
        plan_cache.note(key)

        flat: List[np.ndarray] = []
        for fp in tiers:
            flat.extend(fp)
        flat.append(idx_pad)
        for d, v, _lo, _hi in preds:
            flat.extend((d, v))
        for d, v in agg_arrays:
            flat.extend((d, v))
        ops, missed = _pool.fetch(flat)
        it = iter(ops)
        dev_tiers = tuple(tuple(next(it) for _ in fp) for fp in tiers)
        dev_idx = next(it)
        dev_preds = []
        for _d, _v, lo, hi in preds:
            dd, dv = next(it), next(it)
            blo, bhi = _prep_pred_bounds(_d, lo, hi)
            dev_preds.append((dd, dv, blo, bhi))
        dev_aggs = tuple((next(it), next(it)) for _ in agg_arrays)
        dev_bounds = tuple(
            tuple((np.asarray(a, np.int64), np.asarray(b, np.int64),
                   np.asarray(off, np.int64)) for a, b, off in fb)
            for fb in bounds)
        with enable_x64():
            outs = _chain_core(dev_tiers, dev_bounds, dev_idx,
                               np.asarray(n_live, np.int64),
                               tuple(dev_preds), dev_aggs,
                               total_p2=total_p2, live_p2=live_p2)
            n_cand, n_found, n_valid, mask_d, per_col = jax.device_get(outs)
        mask_np = np.asarray(mask_d)
        _record_dispatch("fused_index_chain", h2d=missed, d2h=[mask_np])
        return _assemble(g["batch"], n_live, mask_np, per_col,
                         g["agg_meta"], int(n_cand), int(n_found),
                         int(n_valid))

    def run_all(cols: Optional[Sequence[str]]
                ) -> Optional[List[Optional[ChainResult]]]:
        """All partitions' chains as one stacked ``shard_map`` dispatch
        over the active partition mesh.  Returns a per-partition result
        list (None entries: that partition declined and must run the
        loop/legacy path), or None when the whole query should fall
        back to the per-partition loop (no mesh, fewer than two
        stackable partitions, or cross-partition operand drift)."""
        spmd = _spmd()
        mesh = spmd.active_mesh()
        if mesh is None or not plan_cache.enabled:
            return None
        P = int(ds.num_partitions)
        gathered: List[Optional[Dict[str, Any]]] = []
        entries: List[Tuple[int, Dict[str, Any]]] = []
        for i in range(P):
            g = _gather(i, cols)
            gathered.append(g)
            if g is not None and not g.get("empty"):
                entries.append((i, g))
        if len(entries) < 2:
            spmd.note_fallback()
            return None
        g0 = entries[0][1]
        n_fields = len(g0["tiers"])
        pred_sig = tuple(str(d.dtype) for d, _v, _lo, _hi in g0["preds"])
        agg_sig = tuple(str(d.dtype) for d, _v in g0["agg_arrays"])
        meta_sig = tuple((m[0], m[1], m[2]) for m in g0["agg_meta"])
        for _i, g in entries[1:]:
            if (len(g["tiers"]) != n_fields
                    or tuple(str(d.dtype) for d, _v, _lo, _hi
                             in g["preds"]) != pred_sig
                    or tuple(str(d.dtype)
                             for d, _v in g["agg_arrays"]) != agg_sig
                    or tuple((m[0], m[1], m[2])
                             for m in g["agg_meta"]) != meta_sig):
                spmd.note_fallback()
                return None
        # common buckets: every partition pads into the max pow2 bucket
        # (exact — see _chain_math) and missing tier slots become
        # zero-width (0, 0, 0) slices that scatter nothing
        total_p2 = max(g["total_p2"] for _i, g in entries)
        live_p2 = max(g["live_p2"] for _i, g in entries)
        n_tiers = [max(len(g["tiers"][f]) for _i, g in entries)
                   for f in range(n_fields)]
        tier_w = [[max((int(g["tiers"][f][t].shape[0])
                        for _i, g in entries if t < len(g["tiers"][f])),
                       default=1)
                   for t in range(n_tiers[f])] for f in range(n_fields)]
        rows = spmd.rows_for(len(entries), mesh)
        key = (chain_ops, total_p2, live_p2,
               tuple(tuple(w for w in tier_w[f]) for f in range(n_fields)),
               pred_sig, agg_sig, aggs is not None,
               spmd.mesh_key(mesh), rows, "spmd")
        plan_cache.note(key)

        sc = spmd.stack_cache
        st_tiers = []
        st_bounds = []
        for f in range(n_fields):
            fp, fb = [], []
            for t in range(n_tiers[f]):
                arrs = [g["tiers"][f][t] if t < len(g["tiers"][f]) else None
                        for _i, g in entries]
                dt = next(a.dtype for a in arrs if a is not None)
                fp.append(sc.stack(arrs, rows, tier_w[f][t], dt))
                a_v = np.zeros(rows, np.int64)
                b_v = np.zeros(rows, np.int64)
                o_v = np.zeros(rows, np.int64)
                for r, (_i, g) in enumerate(entries):
                    if t < len(g["bounds"][f]):
                        a, b, off = g["bounds"][f][t]
                        a_v[r], b_v[r], o_v[r] = a, b, off
                fb.append((a_v, b_v, o_v))
            st_tiers.append(tuple(fp))
            st_bounds.append(tuple(fb))
        idx_st = sc.stack([g["idx_pad"] for _i, g in entries], rows,
                          live_p2, g0["idx_pad"].dtype)
        n_live_v = np.zeros(rows, np.int64)
        for r, (_i, g) in enumerate(entries):
            n_live_v[r] = g["n_live"]
        st_preds = []
        for j in range(len(pred_sig)):
            d0 = g0["preds"][j][0]
            dd = sc.stack([g["preds"][j][0] for _i, g in entries], rows,
                          live_p2, d0.dtype)
            vv = sc.stack([g["preds"][j][1] for _i, g in entries], rows,
                          live_p2, np.bool_, fill=False)
            lo_v = np.zeros(rows, d0.dtype)
            hi_v = np.zeros(rows, d0.dtype)
            for r, (_i, g) in enumerate(entries):
                _d, _v, lo, hi = g["preds"][j]
                blo, bhi = _prep_pred_bounds(_d, lo, hi)
                lo_v[r], hi_v[r] = blo, bhi
            st_preds.append((dd, vv, lo_v, hi_v))
        st_aggs = []
        for j in range(len(agg_sig)):
            d0 = g0["agg_arrays"][j][0]
            dd = sc.stack([g["agg_arrays"][j][0] for _i, g in entries],
                          rows, live_p2, d0.dtype)
            vv = sc.stack([g["agg_arrays"][j][1] for _i, g in entries],
                          rows, live_p2, np.bool_, fill=False)
            st_aggs.append((dd, vv))
        n_cand_a, n_found_a, n_valid_a, mask_a, per_col_a = \
            spmd.run_chain_stack(mesh, tuple(st_tiers), tuple(st_bounds),
                                 idx_st, n_live_v, tuple(st_preds),
                                 tuple(st_aggs), total_p2, live_p2,
                                 len(entries))
        out: List[Optional[ChainResult]] = [None] * P
        for r, (i, g) in enumerate(entries):
            per_col = [tuple(x[r] for x in pc) for pc in per_col_a]
            out[i] = _assemble(g["batch"], g["n_live"], mask_a[r],
                               per_col, g["agg_meta"], int(n_cand_a[r]),
                               int(n_found_a[r]), int(n_valid_a[r]))
        return out

    run.run_all = run_all
    return run


def _spmd():
    """Lazy handle on the SPMD runtime (import cycle: spmd pulls
    :func:`_chain_math` out of this module at trace time)."""
    from ..runtime import spmd
    return spmd


def _prep_pred_bounds(data: np.ndarray, lo: Any, hi: Any
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Same-dtype 0-d bound operands (unbounded -> dtype extremes), the
    ``columnar_ops._prep_bounds`` contract."""
    from ..kernels.columnar_ops import _prep_bounds
    return _prep_bounds(data, lo, hi)


def empty_partition_agg(aggs: Dict[str, Tuple[str, str]]) -> Dict[str, Any]:
    """The partial-aggregate row of an empty partition (what the legacy
    LOCAL_AGG computes for short-circuited / padding partitions)."""
    from . import operators as O
    row, _ = O.aggregate_batch(ColumnBatch({}, 0), aggs, partial=True)
    return row
