"""Columnar secondary-index postings: per-component CSR structures for the
btree / rtree(grid) / keyword index kinds (paper §4.1), generalizing the
fuzzy subsystem's ngram ``GramPostings``.

Like ngram postings, these are *derived columnar data* carried by every
primary LSM component (built at flush/merge beside the component's
ColumnBatch, adopted as-is by recovery, backfilled by a late
``create_index``) — not a separate LSM tree of (key, pk) rows.  The
structure per indexed field is a CSR over component-local row positions:

  keys       sorted distinct key dictionary.  btree: the field's values
             in their *physical* column domain (int64 epoch micros for
             datetimes, dictionary strings for str columns, raw python
             scalars for ``obj`` drift); rtree: uint64-encoded grid-cell
             codes; keyword: sorted distinct token strings
  offsets    int64 [K+1] segment bounds into ``positions``
  positions  int64 component-local row positions, grouped by key (one
             entry per (distinct key, row) pair; btree/rtree rows appear
             exactly once, keyword rows once per distinct token)
  has_value  bool [n_rows]: row holds an indexable value at all

Because ``keys`` is sorted, a btree range probe is two binary searches
plus ONE contiguous ``positions`` slice; rtree circle probes and keyword
token probes are a searchsorted against a (deduplicated) probe-key array
plus one vectorized segment gather.  Candidate *bitmaps* then come from a
single scatter pass (``kernels.fuzzy_ops.t_occurrence_mask`` with
threshold 1 — the same kernel the ngram T-occurrence path dispatches),
composed with the dataset's newest-wins live-row selection exactly the
way ngram candidate masks are: stale old-version positions are simply
never selected, so no per-(key, pk) tombstone maintenance is needed.

The CSR assembly (``csr_from_pairs``) and the vectorized segment
expansion (``segment_gather``) here are the shared builders the ngram
module now imports — one copy of the pattern for all four index kinds.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from ..core.functions import spatial_cell, word_tokens
from .batch import pow2_len
from .schema import encode_scalar

__all__ = ["FieldPostings", "csr_from_pairs", "segment_gather",
           "encode_cells", "cell_codes_for_query"]

_EMPTY_I64 = np.zeros(0, dtype=np.int64)

# numeric physical domains whose keys sort/probe as plain ndarrays
_NUMERIC_DOMAINS = frozenset({"i64", "f64", "bool", "dt", "date"})

_CELL_OFF = np.int64(2 ** 31)          # grid coords recentered to >= 0


def segment_gather(src: np.ndarray, starts: np.ndarray,
                   counts: np.ndarray) -> np.ndarray:
    """Concatenate ``src[starts[i]:starts[i]+counts[i]]`` segments in one
    vectorized gather — the CSR expansion every postings build and every
    multi-key probe share (hoisted from fuzzy/ngram)."""
    total = int(counts.sum())
    if total == 0:
        return src[:0]
    excl = np.concatenate([np.zeros(1, dtype=np.int64),
                           np.cumsum(counts)[:-1]])
    idx = np.repeat(starts - excl, counts) + np.arange(total)
    return src[idx]


def csr_from_pairs(all_keys: np.ndarray, all_pos: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(sorted distinct keys, offsets [K+1], positions grouped by key)
    from parallel (key, position) pair arrays.  Works for any key dtype
    numpy can argsort — uint64 gram hashes, int64/float columns, object
    arrays of strings."""
    if all_keys.shape[0] == 0:
        return all_keys, np.zeros(1, dtype=np.int64), _EMPTY_I64
    order = np.argsort(all_keys, kind="stable")
    keys, counts = np.unique(all_keys[order], return_counts=True)
    offsets = np.zeros(keys.shape[0] + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return keys, offsets, all_pos[order].astype(np.int64)


def encode_cells(xs: np.ndarray, ys: np.ndarray, cell: float) -> np.ndarray:
    """uint64 grid-cell codes for point coordinate arrays: one sortable
    scalar per cell, bit-identical placement to ``spatial_cell``."""
    cx = np.floor(xs / cell).astype(np.int64) + _CELL_OFF
    cy = np.floor(ys / cell).astype(np.int64) + _CELL_OFF
    return (cx.astype(np.uint64) << np.uint64(32)) | cy.astype(np.uint64)


def _cell_code(c: Tuple[int, int]) -> int:
    # one copy of the encoding: build (encode_cells) and probe must stay
    # bit-identical or rtree probes silently return empty
    off = int(_CELL_OFF)
    return ((int(c[0]) + off) << 32) | (int(c[1]) + off)


def cell_codes_for_query(cells: Sequence[Tuple[int, int]]) -> np.ndarray:
    """Sorted *deduplicated* cell-code probe array.  Deduplicating here —
    before any postings probe — is what keeps overlapping covering-cell
    candidates from being scanned twice (each cell's posting segment is
    gathered exactly once)."""
    if not cells:
        return np.zeros(0, dtype=np.uint64)
    return np.unique(np.asarray([_cell_code(c) for c in cells],
                                dtype=np.uint64))


def _obj_array(items: Sequence[Any]) -> np.ndarray:
    out = np.empty(len(items), dtype=object)
    for i, x in enumerate(items):
        out[i] = x
    return out


@dataclass
class FieldPostings:
    """Per-component columnar CSR postings for one secondary-indexed
    field (immutable, like the component batch it sits beside).

    ``spec`` is the index spec the structure was built for — ``("btree",
    None)``, ``("rtree", cell_size)`` or ``("keyword", None)`` — so a
    changed spec (e.g. a new grid cell size) rebuilds instead of serving
    stale cells.  ``domain`` names the key representation: a physical
    column kind for btree keys, ``"cell"`` for rtree codes, ``"token"``
    for keyword strings, ``"obj"`` for raw python fallback keys.
    ``ordered`` is False only when an obj-domain key set refused a total
    order (mixed incomparable types) — range probes then filter the key
    dictionary per key instead of slicing."""

    spec: Tuple[str, Any]
    domain: str
    keys: np.ndarray
    offsets: np.ndarray       # int64 [K+1]
    positions: np.ndarray     # int64 row positions, grouped by key
    has_value: np.ndarray     # bool [n_rows]
    n_rows: int
    ordered: bool = True
    # pow2-padded positions view, built once per immutable postings
    # (Column.padded idiom): stable identity == stable device-pool key
    _padded: Optional[np.ndarray] = field(default=None, repr=False,
                                          compare=False)

    # -- constructors -------------------------------------------------------
    @classmethod
    def _empty(cls, spec: Tuple[str, Any], domain: str,
               has_value: np.ndarray) -> "FieldPostings":
        return cls(spec, domain, _EMPTY_I64, np.zeros(1, dtype=np.int64),
                   _EMPTY_I64, has_value, int(has_value.shape[0]))

    @classmethod
    def from_values(cls, vals: Sequence[Any],
                    spec: Tuple[str, Any]) -> "FieldPostings":
        """Build from python values (memtable tail, obj-kind columns,
        row-mode components).  This is build-time work — probes never
        touch python values again."""
        kind = spec[0]
        if kind == "btree":
            return cls._btree_from_values(vals, spec)
        if kind == "rtree":
            return cls._rtree_from_values(vals, spec)
        if kind == "keyword":
            return cls._keyword_from_values(vals, spec)
        raise ValueError(f"unknown postings kind {kind!r}")

    @classmethod
    def from_batch(cls, batch: Any, fld: str, spec: Tuple[str, Any],
                   n_rows: int) -> "FieldPostings":
        """Build from the component's shredded column: numeric and
        dictionary-coded columns assemble without decoding a single
        value; obj columns fall back to the value path."""
        col = batch.columns.get(fld)
        if col is None:
            dom = {"btree": "obj", "rtree": "cell",
                   "keyword": "token"}[spec[0]]
            return cls._empty(spec, dom, np.zeros(n_rows, dtype=bool))
        kind = spec[0]
        if kind == "keyword":
            return cls.keyword_from_column(col, spec, n_rows)
        if kind == "btree":
            if col.kind in _NUMERIC_DOMAINS:
                pos = np.nonzero(col.valid)[0].astype(np.int64)
                data = col.data[pos]
                if col.kind == "bool":
                    data = data.astype(np.int64)
                keys, offsets, positions = csr_from_pairs(data, pos)
                return cls(spec, col.kind, keys, offsets, positions,
                           col.valid.copy(), n_rows)
            if col.kind == "str":
                vals = col.values or []
                pos = np.nonzero(col.valid)[0].astype(np.int64)
                codes = col.data[pos].astype(np.int64)
                order = np.argsort(codes, kind="stable")
                counts = np.bincount(codes, minlength=len(vals)) \
                    if pos.shape[0] else np.zeros(len(vals), dtype=np.int64)
                offsets = np.zeros(len(vals) + 1, dtype=np.int64)
                np.cumsum(counts, out=offsets[1:])
                # dictionary is sorted, so it IS the key dictionary
                return cls(spec, "str", _obj_array(vals), offsets,
                           pos[order], col.valid.copy(), n_rows)
        decoded = col.decode()
        return cls.from_values(
            [v if not _missing(v) else None for v in decoded], spec)

    @classmethod
    def _btree_from_values(cls, vals: Sequence[Any],
                           spec: Tuple[str, Any]) -> "FieldPostings":
        from .schema import infer_kind, unify_kinds
        n = len(vals)
        has = np.fromiter((v is not None for v in vals), dtype=bool,
                          count=n)
        pos = np.nonzero(has)[0].astype(np.int64)
        raw = [vals[int(i)] for i in pos]
        if not raw:
            return cls._empty(spec, "obj", has)
        dom: Optional[str] = None
        for v in raw:
            dom = unify_kinds(dom, infer_kind(v))
        if dom in _NUMERIC_DOMAINS:
            data = np.asarray([encode_scalar(v, dom) for v in raw],
                              dtype=np.int64 if dom != "f64"
                              else np.float64)
            keys, offsets, positions = csr_from_pairs(data, pos)
            return cls(spec, dom, keys, offsets, positions, has, n)
        if dom == "str":
            keys, offsets, positions = csr_from_pairs(_obj_array(raw), pos)
            return cls(spec, "str", keys, offsets, positions, has, n)
        arr = _obj_array(raw)
        try:
            keys, offsets, positions = csr_from_pairs(arr, pos)
            return cls(spec, "obj", keys, offsets, positions, has, n)
        except TypeError:
            # incomparable mixed types: group by (type, repr) order —
            # range probes detect ``ordered=False`` and filter per key
            order = sorted(range(len(raw)),
                           key=lambda j: (type(raw[j]).__name__,
                                          repr(raw[j])))
            keys_l: List[Any] = []
            counts_l: List[int] = []
            for j in order:
                if keys_l and raw[j] == keys_l[-1] \
                        and type(raw[j]) is type(keys_l[-1]):
                    counts_l[-1] += 1
                else:
                    keys_l.append(raw[j])
                    counts_l.append(1)
            offsets = np.zeros(len(keys_l) + 1, dtype=np.int64)
            np.cumsum(np.asarray(counts_l, dtype=np.int64),
                      out=offsets[1:])
            positions = pos[np.asarray(order, dtype=np.int64)]
            return cls(spec, "obj", _obj_array(keys_l), offsets,
                       positions, has, n, ordered=False)

    @classmethod
    def _rtree_from_values(cls, vals: Sequence[Any],
                           spec: Tuple[str, Any]) -> "FieldPostings":
        cell = float(spec[1])
        n = len(vals)
        has = np.fromiter(
            (isinstance(v, (tuple, list)) and len(v) == 2 for v in vals),
            dtype=bool, count=n)
        pos = np.nonzero(has)[0].astype(np.int64)
        if pos.shape[0] == 0:
            return cls._empty(spec, "cell", has)
        pts = [vals[int(i)] for i in pos]
        try:
            xy = np.asarray(pts, dtype=np.float64)
            codes = encode_cells(xy[:, 0], xy[:, 1], cell)
        except (TypeError, ValueError):
            codes = np.asarray([_cell_code(spatial_cell(p, cell))
                                for p in pts], dtype=np.uint64)
        keys, offsets, positions = csr_from_pairs(codes, pos)
        return cls(spec, "cell", keys, offsets, positions, has, n)

    @classmethod
    def _keyword_from_values(cls, vals: Sequence[Any],
                             spec: Tuple[str, Any]) -> "FieldPostings":
        n = len(vals)
        cache = {}
        per_row: List[List[str]] = []
        has = np.zeros(n, dtype=bool)
        for i, v in enumerate(vals):
            if isinstance(v, str):
                toks = cache.get(v)
                if toks is None:
                    cache[v] = toks = sorted(set(word_tokens(v)))
                per_row.append(toks)
                has[i] = True
            else:
                per_row.append([])
        counts = np.fromiter((len(t) for t in per_row), np.int64, count=n)
        total = int(counts.sum())
        if total == 0:
            return cls._empty(spec, "token", has)
        all_toks = _obj_array([t for toks in per_row for t in toks])
        all_pos = np.repeat(np.arange(n, dtype=np.int64), counts)
        keys, offsets, positions = csr_from_pairs(all_toks, all_pos)
        return cls(spec, "token", keys, offsets, positions, has, n)

    @classmethod
    def keyword_from_column(cls, col: Any, spec: Tuple[str, Any],
                            n_rows: int) -> "FieldPostings":
        """Dictionary-coded build: tokenize once per *distinct* string and
        expand to rows by gathering code segments (the GramPostings
        pattern with tokens instead of gram hashes)."""
        if col.kind != "str":
            return cls.from_values(
                [v if isinstance(v, str) else None for v in col.decode()],
                spec)
        vals = col.values or []
        per_val = [sorted(set(word_tokens(v))) for v in vals]
        vcounts = np.fromiter((len(t) for t in per_val), np.int64,
                              count=len(vals))
        voffs = np.zeros(len(vals) + 1, dtype=np.int64)
        np.cumsum(vcounts, out=voffs[1:])
        flat = _obj_array([t for toks in per_val for t in toks])
        has = col.valid.copy()
        pos = np.nonzero(col.valid)[0].astype(np.int64)
        if pos.shape[0] == 0:
            return cls._empty(spec, "token", has)
        codes = col.data[pos].astype(np.int64)
        counts = vcounts[codes]
        if int(counts.sum()) == 0:
            return cls._empty(spec, "token", has)
        all_toks = segment_gather(flat, voffs[codes], counts)
        all_pos = np.repeat(pos, counts)
        keys, offsets, positions = csr_from_pairs(all_toks, all_pos)
        return cls(spec, "token", keys, offsets, positions, has, n_rows)

    # -- probes -------------------------------------------------------------
    def _encode_bound(self, v: Any, is_lo: bool) -> Any:
        """Map a raw probe bound into the key domain.  Integer bounds on
        f64 keys widen; fractional bounds on integer keys round *inward*
        (ceil for lo, floor for hi) so the slice stays exact.  Raises on
        anything else — the caller falls back to the per-key filter."""
        dom = self.domain
        if dom == "f64":
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                raise TypeError(v)
            return float(v)
        if dom in ("i64", "bool"):
            if isinstance(v, bool):
                return int(v)
            if isinstance(v, int):
                return v
            if isinstance(v, float):
                return math.ceil(v) if is_lo else math.floor(v)
            raise TypeError(v)
        if dom in ("dt", "date"):
            return encode_scalar(v, dom)
        if dom in ("str", "token"):
            if not isinstance(v, str):
                raise TypeError(v)
            return v
        return v                      # obj domain: probe with raw values

    def range_offsets(self, lo: Any, hi: Any) -> Optional[Tuple[int, int]]:
        """Positions-slice bounds ``[a, b)`` covering keys in [lo, hi]
        (raw, unencoded bounds; None = unbounded): two binary searches
        over the key dictionary.  Returns None when the dictionary is
        unordered or a bound cannot be encoded — callers fall back to
        the per-key filter.  The scalar pair (rather than the slice
        itself) is what the fused chain ships to the device, so the
        pooled ``padded_positions`` array stays the only big operand."""
        if not self.ordered:
            return None
        if self.keys.shape[0] == 0:
            return (0, 0)
        try:
            i = 0 if lo is None else int(
                np.searchsorted(self.keys, self._encode_bound(lo, True),
                                side="left"))
            j = self.keys.shape[0] if hi is None else int(
                np.searchsorted(self.keys, self._encode_bound(hi, False),
                                side="right"))
        except (TypeError, ValueError, OverflowError):
            return None
        if j <= i:
            return (0, 0)
        return (int(self.offsets[i]), int(self.offsets[j]))

    def range_positions(self, lo: Any, hi: Any) -> np.ndarray:
        """Row positions whose key falls in [lo, hi]: one contiguous
        positions slice via ``range_offsets``, or the per-key filter
        when the bounds defeat the sorted dictionary."""
        ab = self.range_offsets(lo, hi)
        if ab is None:
            return self._filter_positions(lo, hi)
        a, b = ab
        if b <= a:
            return _EMPTY_I64
        return self.positions[a:b]

    def padded_positions(self) -> np.ndarray:
        """Pow2-padded positions array, built once per immutable postings
        (``Column.padded`` idiom).  Padding lanes are zero and must be
        masked by the caller's ``[a, b)`` slice bounds (the fused chain
        selects lanes by offset, so padding never counts); the stable
        identity makes this a device-pool key for the component's whole
        lifetime."""
        if self._padded is None:
            n = int(self.positions.shape[0])
            np2 = pow2_len(n)
            if np2 == n and n > 0:
                self._padded = self.positions
            else:
                pad = np.zeros(max(np2, 1), dtype=np.int64)
                pad[:n] = self.positions
                self._padded = pad
        return self._padded

    def _filter_positions(self, lo: Any, hi: Any) -> np.ndarray:
        """Per-key fallback over the (small, distinct) key dictionary for
        bounds the domain cannot encode; incomparable keys never match."""
        from .schema import decode_scalar
        dec = [decode_scalar(k, self.domain)
               if self.domain in ("dt", "date") else k
               for k in self.keys.tolist()]
        sel = np.zeros(len(dec), dtype=bool)
        for idx, k in enumerate(dec):
            try:
                sel[idx] = (lo is None or k >= lo) \
                    and (hi is None or k <= hi)
            except TypeError:
                sel[idx] = False
        if not sel.any():
            return _EMPTY_I64
        starts = self.offsets[:-1][sel]
        counts = self.offsets[1:][sel] - starts
        return segment_gather(self.positions, starts, counts)

    def lookup_positions(self, probe_keys: np.ndarray) -> np.ndarray:
        """Row positions under any of the (sorted, deduplicated) probe
        keys: searchsorted both sides, one vectorized segment gather."""
        if self.keys.shape[0] == 0 or probe_keys.shape[0] == 0:
            return _EMPTY_I64
        lo = np.searchsorted(self.keys, probe_keys, side="left")
        hi = np.searchsorted(self.keys, probe_keys, side="right")
        found = hi > lo
        if not found.any():
            return _EMPTY_I64
        starts = self.offsets[lo[found]]
        counts = self.offsets[lo[found] + 1] - starts
        return segment_gather(self.positions, starts, counts)

    def token_positions(self, token: str, fuzzy_ed: int = 0) -> np.ndarray:
        """Keyword probe: the token's posting segment; with ``fuzzy_ed``
        the whole (distinct) token dictionary runs through one batched
        banded-DP call and every matching segment is gathered (positions
        deduplicated — a row may match several tokens)."""
        if self.keys.shape[0] == 0:
            return _EMPTY_I64
        if fuzzy_ed == 0:
            return self.lookup_positions(_obj_array([token]))
        from ..kernels.fuzzy_ops import edit_distances
        toks = self.keys.tolist()
        ok = edit_distances(toks, token, fuzzy_ed) <= fuzzy_ed
        if not ok.any():
            return _EMPTY_I64
        starts = self.offsets[:-1][ok]
        counts = self.offsets[1:][ok] - starts
        return np.unique(segment_gather(self.positions, starts, counts))


def _missing(v: Any) -> bool:
    from .batch import MISSING
    return v is MISSING or v is None
