"""Column schema inference for ADM records (open *and* closed types).

A column *kind* names the physical representation of one field:

  i64   int64 values                     (ADM int32/int64)
  f64   float64 values                   (ADM float/double)
  bool  bool values
  dt    int64 microseconds since epoch   (ADM datetime objects)
  date  int64 days since epoch           (ADM date objects)
  str   int32 codes into a sorted per-batch dictionary (code order ==
        lexicographic order, so range predicates run on codes)
  obj   object array passthrough (points, nested records, lists/bags,
        mixed-type open fields, present-but-null values) — carried
        losslessly but never vectorized

Declared fields map straight from their ADMType; open (undeclared) fields
are inferred from observed values, with conflicting observations unifying
to ``obj``.  This mirrors how the columnar-LSM paper shreds schemaless
documents: the schema is whatever the data has shown so far.
"""

from __future__ import annotations

import datetime as _dt
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core import adm

__all__ = ["ColumnSchema", "infer_kind", "unify_kinds", "kind_of_adm_type",
           "encode_scalar", "decode_scalar", "VECTOR_KINDS"]

# kinds whose physical representation is a comparable numeric array
VECTOR_KINDS = frozenset({"i64", "f64", "bool", "dt", "date", "str"})

_EPOCH_DT = _dt.datetime(1970, 1, 1)
_EPOCH_DATE = _dt.date(1970, 1, 1)

_ADM_KINDS = {
    "int32": "i64", "int64": "i64",
    "float": "f64", "double": "f64",
    "boolean": "bool",
    "datetime": "dt", "date": "date",
    "string": "str",
    "point": "obj",
}


def kind_of_adm_type(t: Any) -> str:
    """Physical column kind for a declared ADM field type."""
    if isinstance(t, adm.ADMType):
        return _ADM_KINDS.get(t.name, "obj")
    return "obj"   # nested records, lists, bags


def infer_kind(v: Any) -> str:
    """Kind of one observed (open-field) value.  ``None`` means
    present-but-null, which only ``obj`` can represent."""
    if v is None:
        return "obj"
    if isinstance(v, (bool, np.bool_)):
        return "bool"
    if isinstance(v, (int, np.integer)):
        return "i64" if -(2 ** 63) <= v < 2 ** 63 else "obj"
    if isinstance(v, (float, np.floating)):
        return "f64"
    if isinstance(v, str):
        return "str"
    if isinstance(v, _dt.datetime):
        return "dt" if v.tzinfo is None else "obj"
    if isinstance(v, _dt.date):
        return "date"
    return "obj"


def unify_kinds(a: Optional[str], b: Optional[str]) -> str:
    """Least common kind of two observations."""
    if a is None:
        return b or "obj"
    if b is None:
        return a
    if a == b:
        return a
    if {a, b} <= {"i64", "f64"}:
        return "f64"
    return "obj"


def encode_scalar(v: Any, kind: str) -> Any:
    """Encode one python value into the column's physical domain.  Raises
    (TypeError/ValueError/OverflowError) on mismatch — callers downgrade
    the column to ``obj``.  ``str`` kind returns the string itself (codes
    are per-batch; see batch.py)."""
    if kind == "i64":
        if isinstance(v, (bool, np.bool_)) \
                or not isinstance(v, (int, np.integer)):
            raise TypeError(f"not an int: {v!r}")
        if not -(2 ** 63) <= int(v) < 2 ** 63:
            raise OverflowError(v)
        return int(v)
    if kind == "f64":
        if isinstance(v, (bool, np.bool_)) \
                or not isinstance(v, (int, float, np.integer, np.floating)):
            raise TypeError(f"not a number: {v!r}")
        return float(v)
    if kind == "bool":
        if not isinstance(v, (bool, np.bool_)):
            raise TypeError(f"not a bool: {v!r}")
        return bool(v)
    if kind == "dt":
        if not isinstance(v, _dt.datetime) or v.tzinfo is not None:
            raise TypeError(f"not a naive datetime: {v!r}")
        delta = v - _EPOCH_DT
        return (delta.days * 86400 + delta.seconds) * 1_000_000 \
            + delta.microseconds
    if kind == "date":
        if isinstance(v, _dt.datetime) or not isinstance(v, _dt.date):
            raise TypeError(f"not a date: {v!r}")
        return (v - _EPOCH_DATE).days
    if kind == "str":
        if not isinstance(v, str):
            raise TypeError(f"not a string: {v!r}")
        return v
    return v   # obj: passthrough


def decode_scalar(x: Any, kind: str) -> Any:
    """Inverse of encode_scalar (exact round-trip)."""
    if kind == "i64":
        return int(x)
    if kind == "f64":
        return float(x)
    if kind == "bool":
        return bool(x)
    if kind == "dt":
        return _EPOCH_DT + _dt.timedelta(microseconds=int(x))
    if kind == "date":
        return _EPOCH_DATE + _dt.timedelta(days=int(x))
    return x


@dataclass
class ColumnSchema:
    """Ordered field-name -> kind mapping for a dataset or batch."""

    kinds: Dict[str, str] = field(default_factory=dict)

    @classmethod
    def from_record_type(cls, rt: adm.RecordType) -> "ColumnSchema":
        return cls({f.name: kind_of_adm_type(f.type) for f in rt.fields})

    def observe_value(self, name: str, v: Any) -> None:
        """Fold one open-field observation into the schema."""
        self.kinds[name] = unify_kinds(self.kinds.get(name), infer_kind(v))

    def observe_row(self, row: Dict[str, Any], declared: Tuple[str, ...]
                    ) -> None:
        for k, v in row.items():
            if k not in declared:
                self.observe_value(k, v)

    def kind(self, name: str) -> str:
        return self.kinds.get(name, "obj")

    def union(self, other: "ColumnSchema") -> "ColumnSchema":
        out = dict(self.kinds)
        for k, v in other.kinds.items():
            out[k] = unify_kinds(out.get(k), v)
        return ColumnSchema(out)

    def copy(self) -> "ColumnSchema":
        return ColumnSchema(dict(self.kinds))

    def __contains__(self, name: str) -> bool:
        return name in self.kinds

    def __iter__(self):
        return iter(self.kinds)
