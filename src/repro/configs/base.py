"""Model/run configuration records.

Configs are *closed* ADM record types (core/adm.py): unknown fields are
rejected at validation time, reproducing AsterixDB's closed-Datatype
semantics.  Experiment overlays may use ``open_overrides`` to carry extra
instance-level fields (open-type semantics) without widening the schema.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from ..core import adm

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "RunConfig",
           "validate_config", "reduced"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    ffn_kind: str = "swiglu"         # swiglu | gelu_mlp
    use_bias: bool = False
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    moe_dispatch: str = "einsum"     # einsum | sort (hash-partition hillclimb)
    kv_layout: str = "flat"          # flat | tiered (LSM components, paper C3)
    kv_tail_cap: int = 256           # tiered: memtable capacity
    kv_l1_comps: int = 4             # tiered: L1 ring slots
    # --- block pattern: tuple of (mixer, ffn) pairs cycled over layers.
    # mixer in {attn, mamba, mlstm, slstm}; ffn in {mlp, moe, none}
    block_pattern: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    # --- SSM (mamba) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model/16)
    # --- xLSTM ---
    xlstm_heads: int = 4
    # --- modality frontend stubs ---
    prefix_len: int = 0              # vlm/audio: precomputed-embedding prefix
    # --- numerics / scan ---
    seq_chunk: int = 128             # recurrent-block time chunk (remat unit)
    attn_chunk: int = 1024           # flash KV-block for the XLA path
    remat_policy: str = "nothing"    # nothing | dots | full
    scan_layers: bool = True
    # --- beyond-paper perf levers (EXPERIMENTS.md §Perf) ---
    seq_shard: bool = False          # Megatron-style sequence parallelism
    reduce_dtype: str = "float32"    # collective dtype of out-proj psums
    loss_chunk: int = 0              # chunked cross-entropy (0 = off)
    # per-arch sharding hints (paper §5.1 / Query 14's hint mechanism):
    # ((logical_axis, mesh_axes), ...) overriding the safe-rule table
    rule_hints: Tuple[Tuple[str, Any], ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def layer_pattern(self) -> Tuple[Tuple[str, str], ...]:
        p = self.block_pattern
        if self.num_layers % len(p) != 0:
            raise ValueError(
                f"{self.name}: block_pattern period {len(p)} must divide "
                f"num_layers {self.num_layers}")
        return p

    def params_per_token_active(self) -> int:
        """N_active for MODEL_FLOPS = 6*N_active*D (MoE counts top-k only)."""
        return _count_params(self, active_only=True)

    def params_total(self) -> int:
        return _count_params(self, active_only=False)


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    n = cfg.vocab_size * d                       # embed
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * d                  # lm head
    per = len(cfg.layer_pattern)
    cycles = cfg.num_layers // per
    for mixer, ffn in cfg.layer_pattern:
        if mixer == "attn":
            n_l = d * (cfg.num_heads * hd) + d * (2 * cfg.num_kv_heads * hd) \
                + (cfg.num_heads * hd) * d
        elif mixer == "mamba":
            di, st, dtr = cfg.ssm_inner, cfg.ssm_state, cfg.resolved_dt_rank
            n_l = d * 2 * di + di * cfg.ssm_conv + di * (dtr + 2 * st) \
                + dtr * di + di * st + di + di * d
        elif mixer == "mlstm":
            di = 2 * d
            # up + conv + qkv + if-gates + ln + down
            n_l = d * 2 * di + cfg.ssm_conv * di + di + 3 * di * di \
                + di * 2 * cfg.xlstm_heads + 2 * cfg.xlstm_heads + di \
                + di * d
        elif mixer == "slstm":
            dh = d // cfg.xlstm_heads
            # fused 4-gate input weights + bias + block-diag recurrent + ln
            n_l = d * 4 * d + 4 * d + 4 * cfg.xlstm_heads * dh * dh + d
        else:
            raise ValueError(mixer)
        if ffn == "mlp":
            mult = 3 if cfg.ffn_kind == "swiglu" else 2
            n_l += mult * d * cfg.d_ff
        elif ffn == "moe":
            e = cfg.experts_per_token if active_only else cfg.num_experts
            n_l += 3 * d * cfg.d_ff * e + d * cfg.num_experts
        n += n_l * cycles
    return n


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set — all 10 archs share it)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    dtype: str = "bfloat16"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_accum: int = 1
    grad_compression: bool = False
    seed: int = 0
    open_overrides: Dict[str, Any] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# ADM validation of configs (closed-type semantics)
# ---------------------------------------------------------------------------

def _model_config_adm() -> adm.RecordType:
    fields = []
    for f in dataclasses.fields(ModelConfig):
        t = {int: adm.INT64, str: adm.STRING, float: adm.DOUBLE,
             bool: adm.BOOLEAN}.get(f.type if isinstance(f.type, type) else
                                    {"int": int, "str": str, "float": float,
                                     "bool": bool}.get(str(f.type), str),
                                    adm.STRING)
        has_default = (f.default is not dataclasses.MISSING
                       or f.default_factory is not dataclasses.MISSING)  # type: ignore
        fields.append(adm.Field(f.name, t, optional=has_default))
    return adm.RecordType("ModelConfig", tuple(fields), open=False)


_MODEL_CONFIG_TYPE = None


def validate_config(cfg: ModelConfig) -> ModelConfig:
    """Closed-record validation + arithmetic sanity checks."""
    global _MODEL_CONFIG_TYPE
    if _MODEL_CONFIG_TYPE is None:
        _MODEL_CONFIG_TYPE = _model_config_adm()
    d = {f.name: getattr(cfg, f.name) for f in dataclasses.fields(cfg)}
    d = {k: (v if not isinstance(v, tuple) else None) for k, v in d.items()}
    _MODEL_CONFIG_TYPE.validate({k: v for k, v in d.items() if v is not None})
    assert cfg.num_heads % max(cfg.num_kv_heads, 1) == 0, \
        f"{cfg.name}: heads {cfg.num_heads} not a multiple of kv {cfg.num_kv_heads}"
    _ = cfg.layer_pattern
    return cfg


def reduced(cfg: ModelConfig, *, layers: Optional[int] = None) -> ModelConfig:
    """Smoke-test configs: same family/pattern, tiny dims (paper's 'reduced
    config of the same family')."""
    per = len(cfg.layer_pattern)
    nl = layers or (2 * per if 2 * per <= 8 else per)
    nl = max(per, (nl // per) * per)
    kv = min(cfg.num_kv_heads, 2)
    heads = max(2, 4 // max(1, 4 // max(cfg.num_heads, 1)))
    heads = 4 if cfg.num_heads >= 4 else cfg.num_heads
    heads = heads - heads % kv if heads % kv else heads
    return dataclasses.replace(
        cfg,
        num_layers=nl,
        d_model=64,
        num_heads=max(heads, kv),
        num_kv_heads=kv,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=8,
        ssm_dt_rank=8,
        xlstm_heads=2,
        prefix_len=min(cfg.prefix_len, 8),
        seq_chunk=16,
        attn_chunk=32,
    )
