"""command-r-plus-104b: dense GQA, no-bias layernorm
[hf:CohereForAI/c4ai-command-r-v01; unverified].  (Cohere's parallel
attention+FFN block is folded to sequential here; see docs/ARCHITECTURE.md
§Training-stack deviations.)"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    block_pattern=(("attn", "mlp"),),
    ffn_kind="swiglu", norm_kind="layernorm", use_bias=False,
    rope_theta=75000000.0, remat_policy="full",
)
