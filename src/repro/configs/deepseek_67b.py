"""deepseek-67b: llama-arch dense GQA [arXiv:2401.02954; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400,
    block_pattern=(("attn", "mlp"),),
    ffn_kind="swiglu", norm_kind="rmsnorm", use_bias=False,
    rope_theta=10000.0, remat_policy="full",
)
