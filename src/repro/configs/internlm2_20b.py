"""internlm2-20b: dense GQA [arXiv:2403.17297; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544,
    block_pattern=(("attn", "mlp"),),
    ffn_kind="swiglu", norm_kind="rmsnorm", use_bias=False,
    rope_theta=1000000.0, remat_policy="full",
)
