"""jamba-v0.1-52b: Mamba+attention 1:7 interleave with MoE every other layer
[arXiv:2403.19887; hf].  attn_layer_period=8 offset=4; expert_layer_period=2
offset=1; 16 experts top-2.  Hybrid -> runs the long_500k cell (only 4 of 32
layers hold KV caches)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    num_experts=16, experts_per_token=2,
    block_pattern=(
        ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
        ("attn", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
    ),
    ffn_kind="swiglu", norm_kind="rmsnorm", use_bias=False,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    remat_policy="full",
)
