"""musicgen-large: decoder-only over EnCodec tokens [arXiv:2306.05284; hf].
The audio frontend (EnCodec) and text conditioning (T5) are STUBS: the batch
carries ``prefix_emb`` [B, prefix_len, d] of precomputed conditioning frames.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    block_pattern=(("attn", "mlp"),),
    ffn_kind="gelu_mlp", norm_kind="layernorm", use_bias=True,
    prefix_len=64, remat_policy="full",
)
