"""olmoe-1b-7b: 64-expert top-8 MoE [arXiv:2409.02060; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    num_experts=64, experts_per_token=8,
    block_pattern=(("attn", "moe"),),
    ffn_kind="swiglu", norm_kind="rmsnorm", use_bias=False,
    rope_theta=10000.0, remat_policy="full",
)
