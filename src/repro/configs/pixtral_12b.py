"""pixtral-12b: pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].  The ViT frontend is a STUB:
``prefix_emb`` [B, prefix_len, d] stands in for patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=160,
    block_pattern=(("attn", "mlp"),),
    ffn_kind="swiglu", norm_kind="rmsnorm", use_bias=False,
    rope_theta=1000000000.0, prefix_len=256, remat_policy="full",
)
