"""Architecture registry: the 10 assigned configs + the paper's own workload.

Every entry is selectable via ``--arch <id>`` in the launchers.  Cell
applicability (``long_500k`` needs sub-quadratic attention) is centralized in
``shape_applicable`` and mirrored in docs/ARCHITECTURE.md §Architecture
applicability.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from .base import ModelConfig, ShapeConfig, SHAPES, validate_config

__all__ = ["ARCHS", "get_config", "list_archs", "shape_applicable",
           "applicable_cells", "OPTIMIZED_PROFILES", "optimized_config"]

# §Perf winners (EXPERIMENTS.md): per-arch beyond-paper profiles, applied
# via ``optimized_config(name)`` / ``--optimized`` in the launchers.  The
# plain configs stay paper-faithful baselines.
OPTIMIZED_PROFILES = {
    # A1+A3: sequence parallelism (MFU 0.277 -> 0.556 on train_4k/pod1)
    "command-r-plus-104b": {"seq_shard": True, "reduce_dtype": "bfloat16"},
    "deepseek-67b": {"seq_shard": True, "reduce_dtype": "bfloat16"},
    "internlm2-20b": {"seq_shard": True, "reduce_dtype": "bfloat16"},
    "pixtral-12b": {"seq_shard": True, "reduce_dtype": "bfloat16"},
    "musicgen-large": {"seq_shard": True, "reduce_dtype": "bfloat16"},
    "dbrx-132b": {"seq_shard": True, "reduce_dtype": "bfloat16"},
    "jamba-v0.1-52b": {"seq_shard": True, "reduce_dtype": "bfloat16"},
    "olmoe-1b-7b": {"seq_shard": True, "reduce_dtype": "bfloat16"},
    # B1: pure-DP/ZeRO-3 rule hint (MFU 0.020 -> 0.247); needs
    # global_batch >= chips — see EXPERIMENTS §Perf cell B (pod2 caveat)
    "starcoder2-3b": {
        "rule_hints": (("batch", ("data", "model")), ("d_ff", None),
                       ("act_ff", None), ("vocab", None)),
        "loss_chunk": 512,
    },
    "xlstm-125m": {
        "rule_hints": (("batch", ("data", "model")), ("vocab", None)),
    },
}


def optimized_config(name: str) -> ModelConfig:
    """The arch's beyond-paper §Perf profile (falls back to baseline)."""
    import dataclasses
    cfg = get_config(name)
    prof = OPTIMIZED_PROFILES.get(cfg.name, {})
    return dataclasses.replace(cfg, **prof) if prof else cfg

# id -> (module name, attribute); modules define CONFIG = ModelConfig(...)
_ARCH_MODULES = [
    "dbrx_132b", "olmoe_1b_7b", "command_r_plus_104b", "starcoder2_3b",
    "deepseek_67b", "internlm2_20b", "musicgen_large", "pixtral_12b",
    "xlstm_125m", "jamba_v0_1_52b",
]

ARCHS: Dict[str, ModelConfig] = {}


def _load() -> None:
    if ARCHS:
        return
    import importlib
    for mod_name in _ARCH_MODULES:
        mod = importlib.import_module(f"{__package__}.{mod_name}")
        cfg = validate_config(mod.CONFIG)
        ARCHS[cfg.name] = cfg


def get_config(name: str) -> ModelConfig:
    _load()
    name = name.replace("_", "-")
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> List[str]:
    _load()
    return sorted(ARCHS)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable?, reason).  Per task spec: long_500k decode requires
    sub-quadratic attention — run for SSM/hybrid, skip for pure full-attention
    archs (every assigned transformer is causal-decoder, so decode shapes
    apply to all)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("pure full-attention arch: 524288-token KV per "
                       "sequence is out of scope per task spec; noted in "
                       "docs/ARCHITECTURE.md §Architecture applicability")
    return True, ""


def applicable_cells() -> List[Tuple[str, str]]:
    """All (arch, shape) dry-run cells that must pass."""
    _load()
    cells = []
    for a, cfg in sorted(ARCHS.items()):
        for s, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if ok:
                cells.append((a, s))
    return cells
