"""starcoder2-3b: dense GQA(kv=2), RoPE, gelu MLP with bias
[arXiv:2402.19173; hf].  kv=2 < model-axis 16: the safe sharding rule
replicates KV heads (docs/ARCHITECTURE.md §Architecture applicability)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    block_pattern=(("attn", "mlp"),),
    ffn_kind="gelu_mlp", norm_kind="layernorm", use_bias=True,
    rope_theta=100000.0, remat_policy="full",
)
