"""TinySocial — the paper's own running example (Data definitions 1-2).

Defines the Mugshot.com Dataverse: EmploymentType, MugshotUserType (open),
MugshotMessageType (closed), the two Datasets with their secondary indexes,
and a synthetic data generator scaled for the Table 2-4 benchmarks.
"""

from __future__ import annotations

import datetime as dt
import random
from typing import Dict, List, Tuple

from ..core import adm
from ..storage.dataset import PartitionedDataset

__all__ = ["employment_type", "user_type", "message_type", "build_dataverse",
           "gen_users", "gen_messages", "TAG_VOCAB"]


def employment_type() -> adm.RecordType:
    return adm.RecordType("EmploymentType", (
        adm.Field("organization-name", adm.STRING),
        adm.Field("start-date", adm.DATE),
        adm.Field("end-date", adm.DATE, optional=True),
    ), open=True)


def user_type() -> adm.RecordType:
    address = adm.RecordType("AddressType", (
        adm.Field("street", adm.STRING),
        adm.Field("city", adm.STRING),
        adm.Field("state", adm.STRING),
        adm.Field("zip", adm.STRING),
        adm.Field("country", adm.STRING),
    ), open=False)
    return adm.RecordType("MugshotUserType", (
        adm.Field("id", adm.INT32),
        adm.Field("alias", adm.STRING),
        adm.Field("name", adm.STRING),
        adm.Field("user-since", adm.DATETIME),
        adm.Field("address", address),
        adm.Field("friend-ids", adm.BagType(adm.INT32)),
        adm.Field("employment", adm.OrderedListType(employment_type())),
    ), open=True)


def message_type() -> adm.RecordType:
    return adm.RecordType("MugshotMessageType", (
        adm.Field("message-id", adm.INT32),
        adm.Field("author-id", adm.INT32),
        adm.Field("timestamp", adm.DATETIME),
        adm.Field("in-response-to", adm.INT32, optional=True),
        adm.Field("sender-location", adm.POINT, optional=True),
        adm.Field("tags", adm.BagType(adm.STRING)),
        adm.Field("message", adm.STRING),
    ), open=False)


TAG_VOCAB = ["tpu", "jax", "lsm", "asterix", "bigdata", "nosql", "flwor",
             "hyracks", "algebricks", "feeds", "fuzzy", "spatial", "tonight",
             "coffee", "verona", "mesh", "pallas", "roofline"]

_STATES = ["CA", "WA", "OR", "NV", "AZ", "TX"]
_ORGS = ["Kongreen", "Codetechno", "Zamcorp", "Streettax", "Villa-tech"]


def gen_users(n: int, seed: int = 0) -> List[Dict]:
    rng = random.Random(seed)
    base = dt.datetime(2008, 1, 1)
    users = []
    for i in range(n):
        since = base + dt.timedelta(seconds=rng.randrange(6 * 365 * 86400))
        emp = [{"organization-name": rng.choice(_ORGS),
                "start-date": (since + dt.timedelta(days=30)).date()}]
        if rng.random() < 0.5:
            emp[0]["end-date"] = (since + dt.timedelta(days=400)).date()
        users.append({
            "id": i,
            "alias": f"user{i}",
            "name": f"User Number {i}",
            "user-since": since,
            "address": {
                "street": f"{i} Main St", "city": "Irvine",
                "state": rng.choice(_STATES),
                "zip": f"9{i % 10000:04d}", "country": "USA"},
            "friend-ids": [rng.randrange(n) for _ in range(rng.randrange(5))],
            "employment": emp,
        })
    return users


def gen_messages(n: int, num_users: int, seed: int = 1) -> List[Dict]:
    rng = random.Random(seed)
    base = dt.datetime(2014, 1, 1)
    msgs = []
    for i in range(n):
        ts = base + dt.timedelta(seconds=rng.randrange(120 * 86400))
        msgs.append({
            "message-id": i,
            "author-id": rng.randrange(num_users),
            "timestamp": ts,
            "sender-location": (rng.uniform(33.0, 34.0),
                                rng.uniform(-118.0, -117.0)),
            "tags": rng.sample(TAG_VOCAB, rng.randrange(1, 5)),
            "message": " ".join(rng.choice(TAG_VOCAB)
                                for _ in range(rng.randrange(4, 20))),
        })
    return msgs


def build_dataverse(num_users: int = 200, num_messages: int = 1000,
                    num_partitions: int = 4, flush_threshold: int = 128,
                    with_indexes: bool = True, seed: int = 0
                    ) -> Tuple[adm.Dataverse, Dict[str, PartitionedDataset]]:
    dv = adm.Dataverse("TinySocial")
    ut, mt = dv.create_type(user_type()), dv.create_type(message_type())
    users = PartitionedDataset("MugshotUsers", ut, "id",
                               num_partitions, flush_threshold)
    msgs = PartitionedDataset("MugshotMessages", mt, "message-id",
                              num_partitions, flush_threshold)
    if with_indexes:
        users.create_index("user-since")
        msgs.create_index("timestamp")
        msgs.create_index("author-id")
    for u in gen_users(num_users, seed):
        users.insert(u)
    for m in gen_messages(num_messages, num_users, seed + 1):
        msgs.insert(m)
    dv.create_dataset("MugshotUsers", users)
    dv.create_dataset("MugshotMessages", msgs)
    return dv, {"MugshotUsers": users, "MugshotMessages": msgs}
