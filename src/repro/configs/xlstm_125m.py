"""xlstm-125m: alternating mLSTM/sLSTM blocks [arXiv:2405.04517; unverified].
No FFN (d_ff=0): xLSTM blocks carry their own up/down projections.  Pure
recurrent state -> runs the long_500k cell (O(1) decode state)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, xlstm_heads=4,
    block_pattern=(("mlstm", "none"), ("slstm", "none")),
    norm_kind="layernorm", remat_policy="full", tie_embeddings=False,
)
