"""Core: the paper's contribution adapted to JAX (ADM types, Algebricks-style
algebra + rewriter, LSM component framework)."""
