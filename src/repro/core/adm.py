"""ADM-style open/closed record types (paper §2.1).

AsterixDB's data model (ADM) lets a Datatype be *open* (instances may carry
extra, undeclared fields — stored inline per instance, costing bytes) or
*closed* (instances are validated to contain exactly the declared fields).
Table 2 of the paper shows the storage-size consequence: "Schema" (all fields
declared) vs "KeyOnly" (only the primary key declared) differ ~2x on disk.

We reproduce that faithfully: declared fields are encoded positionally with no
name bytes; open (undeclared) fields are encoded with their name inline.  The
same machinery doubles as the framework's config system: arch configs are
closed records (strict validation), experiment overlays are open records.
"""

from __future__ import annotations

import datetime as _dt
import struct
from dataclasses import dataclass, field as _dc_field
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "ADMType", "INT32", "INT64", "FLOAT", "DOUBLE", "STRING", "BOOLEAN",
    "DATETIME", "DATE", "POINT", "Field", "RecordType", "BagType",
    "OrderedListType", "ValidationError", "Dataverse",
]


class ValidationError(ValueError):
    """Raised when an instance does not conform to its Datatype."""


# ---------------------------------------------------------------------------
# Primitive types
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ADMType:
    """A primitive ADM type tag with an encoder/decoder."""

    name: str
    tag: int  # 1-byte wire tag

    def validate(self, v: Any) -> Any:
        if not _PRIM_OK[self.name](v):
            raise ValidationError(f"value {v!r} is not a valid {self.name}")
        if isinstance(v, int) and self.name in ("float", "double") \
                and not isinstance(v, bool):
            return float(v)     # ADM casts ints into float fields at
        return v                # ingest, so the stored value does not
        #                         depend on memtable-vs-component state

    def encode(self, v: Any, out: bytearray) -> None:
        if self.name == "int32":
            out += struct.pack("<i", v)
        elif self.name == "int64":
            out += struct.pack("<q", v)
        elif self.name == "float":
            out += struct.pack("<f", float(v))
        elif self.name == "double":
            out += struct.pack("<d", float(v))
        elif self.name == "boolean":
            out += b"\x01" if v else b"\x00"
        elif self.name == "string":
            b = v.encode("utf-8")
            _put_varint(out, len(b))
            out += b
        elif self.name in ("datetime", "date"):
            s = v.isoformat() if not isinstance(v, str) else v
            b = s.encode("utf-8")
            _put_varint(out, len(b))
            out += b
        elif self.name == "point":
            out += struct.pack("<dd", float(v[0]), float(v[1]))
        else:  # pragma: no cover
            raise TypeError(self.name)

    def decode(self, buf: memoryview, pos: int) -> Tuple[Any, int]:
        if self.name == "int32":
            return struct.unpack_from("<i", buf, pos)[0], pos + 4
        if self.name == "int64":
            return struct.unpack_from("<q", buf, pos)[0], pos + 8
        if self.name == "float":
            return struct.unpack_from("<f", buf, pos)[0], pos + 4
        if self.name == "double":
            return struct.unpack_from("<d", buf, pos)[0], pos + 8
        if self.name == "boolean":
            return bool(buf[pos]), pos + 1
        if self.name in ("string", "datetime", "date"):
            n, pos = _get_varint(buf, pos)
            return bytes(buf[pos:pos + n]).decode("utf-8"), pos + n
        if self.name == "point":
            x, y = struct.unpack_from("<dd", buf, pos)
            return (x, y), pos + 16
        raise TypeError(self.name)  # pragma: no cover


INT32 = ADMType("int32", 1)
INT64 = ADMType("int64", 2)
FLOAT = ADMType("float", 3)
DOUBLE = ADMType("double", 4)
STRING = ADMType("string", 5)
BOOLEAN = ADMType("boolean", 6)
DATETIME = ADMType("datetime", 7)
DATE = ADMType("date", 8)
POINT = ADMType("point", 9)

_PRIMS_BY_TAG = {t.tag: t for t in
                 (INT32, INT64, FLOAT, DOUBLE, STRING, BOOLEAN, DATETIME, DATE, POINT)}


def _put_varint(out: bytearray, n: int) -> None:
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _get_varint(buf: memoryview, pos: int) -> Tuple[int, int]:
    shift = n = 0
    while True:
        b = buf[pos]
        pos += 1
        n |= (b & 0x7F) << shift
        if not b & 0x80:
            return n, pos
        shift += 7


# ---------------------------------------------------------------------------
# Composite types
# ---------------------------------------------------------------------------

# per-primitive validity predicates, hoisted out of ADMType.validate (the
# ingestion hot path calls it once per field per record)
_PRIM_OK = {
    "int32": lambda x: isinstance(x, int) and -(2**31) <= x < 2**31,
    # int64 range-checks at validation like int32 does: encode() packs
    # "<q" and would reject later anyway, but batch ingestion stores
    # columns without encoding, so both DML paths must gate here
    "int64": lambda x: isinstance(x, int) and -(2**63) <= x < 2**63,
    "float": lambda x: isinstance(x, (int, float)),
    "double": lambda x: isinstance(x, (int, float)),
    "string": lambda x: isinstance(x, str),
    "boolean": lambda x: isinstance(x, bool),
    "datetime": lambda x: isinstance(x, (_dt.datetime, str)),
    "date": lambda x: isinstance(x, (_dt.date, str)),
    # coords must be numeric here, not just at encode time: batch
    # ingestion stores columns without encoding, so validation is the
    # only gate both DML paths share
    "point": lambda x: (isinstance(x, (tuple, list)) and len(x) == 2
                        and all(isinstance(c, (int, float))
                                and not isinstance(c, bool) for c in x)),
}


@dataclass(frozen=True)
class OrderedListType:
    """ADM ordered list: ``[ItemType]``."""

    item: Any  # ADMType | RecordType | ...
    tag: int = 20

    def validate(self, v: Any) -> Any:
        if not isinstance(v, (list, tuple)):
            raise ValidationError(f"{v!r} is not an ordered list")
        return [self.item.validate(x) for x in v]

    def encode(self, v: Any, out: bytearray) -> None:
        _put_varint(out, len(v))
        for x in v:
            self.item.encode(x, out)

    def decode(self, buf: memoryview, pos: int) -> Tuple[Any, int]:
        n, pos = _get_varint(buf, pos)
        items = []
        for _ in range(n):
            x, pos = self.item.decode(buf, pos)
            items.append(x)
        return items, pos


@dataclass(frozen=True)
class BagType:
    """ADM bag (unordered list): ``{{ ItemType }}``. Stored canonically sorted
    where items are orderable so that bag equality is structural."""

    item: Any
    tag: int = 21

    def validate(self, v: Any) -> Any:
        if not isinstance(v, (list, tuple, set, frozenset)):
            raise ValidationError(f"{v!r} is not a bag")
        items = [self.item.validate(x) for x in v]
        try:
            return sorted(items)
        except TypeError:
            return list(items)

    encode = OrderedListType.encode
    decode = OrderedListType.decode


@dataclass(frozen=True)
class Field:
    name: str
    type: Any
    optional: bool = False  # the ADM ``?`` suffix
    default: Any = None


# Tag used when encoding an *undeclared* (open) field's value: we need a type
# tag per value since there is no schema to drive decoding.
def _encode_any(v: Any, out: bytearray) -> None:
    if isinstance(v, bool):
        out.append(BOOLEAN.tag); BOOLEAN.encode(v, out)
    elif isinstance(v, _dt.datetime):
        out.append(DATETIME.tag); DATETIME.encode(v, out)
    elif isinstance(v, _dt.date):
        out.append(DATE.tag); DATE.encode(v, out)
    elif isinstance(v, int):
        out.append(INT64.tag); INT64.encode(v, out)
    elif isinstance(v, float):
        out.append(DOUBLE.tag); DOUBLE.encode(v, out)
    elif isinstance(v, str):
        out.append(STRING.tag); STRING.encode(v, out)
    elif isinstance(v, (list, tuple)):
        out.append(20); _put_varint(out, len(v))
        for x in v:
            _encode_any(x, out)
    elif isinstance(v, dict):
        out.append(30); _put_varint(out, len(v))
        for k in sorted(v):
            STRING.encode(k, out)
            _encode_any(v[k], out)
    elif v is None:
        out.append(0)
    else:
        raise ValidationError(f"cannot encode open value {v!r}")


def _decode_any(buf: memoryview, pos: int) -> Tuple[Any, int]:
    tag = buf[pos]; pos += 1
    if tag == 0:
        return None, pos
    if tag in _PRIMS_BY_TAG:
        return _PRIMS_BY_TAG[tag].decode(buf, pos)
    if tag == 20:
        n, pos = _get_varint(buf, pos)
        items = []
        for _ in range(n):
            x, pos = _decode_any(buf, pos)
            items.append(x)
        return items, pos
    if tag == 30:
        n, pos = _get_varint(buf, pos)
        d = {}
        for _ in range(n):
            k, pos = STRING.decode(buf, pos)
            d[k], pos = _decode_any(buf, pos)
        return d, pos
    raise ValidationError(f"bad open-value tag {tag}")


@dataclass(frozen=True)
class RecordType:
    """ADM record type.  ``open=True`` (the AsterixDB default) permits
    instance-level extra fields; ``open=False`` (``closed``) forbids them."""

    name: str
    fields: Tuple[Field, ...]
    open: bool = True  # AsterixDB datatypes are open by default
    tag: int = 31

    def __post_init__(self):
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValidationError(f"duplicate field names in {self.name}")
        # frozen dataclass: sneak the cache in (fields are immutable, so
        # the map is too); validate/encode hit it once per record
        object.__setattr__(self, "_field_map",
                           {f.name: f for f in self.fields})

    @property
    def field_map(self) -> Dict[str, Field]:
        return self._field_map

    def validate(self, v: Any) -> Dict[str, Any]:
        if not isinstance(v, dict):
            raise ValidationError(f"{v!r} is not a record")
        out: Dict[str, Any] = {}
        fmap = self.field_map
        for f in self.fields:
            if f.name in v and v[f.name] is not None:
                out[f.name] = f.type.validate(v[f.name])
            elif f.optional:
                if f.default is not None:
                    out[f.name] = f.default
            else:
                raise ValidationError(
                    f"record of type {self.name} missing required field {f.name!r}")
        extras = {k: x for k, x in v.items() if k not in fmap}
        if extras:
            if not self.open:
                raise ValidationError(
                    f"closed type {self.name} forbids extra fields {sorted(extras)}")
            out.update(extras)
        return out

    # -- wire format ------------------------------------------------------
    def encode(self, v: Dict[str, Any], out: Optional[bytearray] = None) -> bytes:
        """Declared fields: positional, no name bytes.  Optional declared
        fields: 1-byte presence flag.  Open fields: (name, tagged value)."""
        buf = bytearray() if out is None else out
        fmap = self.field_map
        for f in self.fields:
            if f.optional:
                present = f.name in v
                buf.append(1 if present else 0)
                if present:
                    f.type.encode(v[f.name], buf)
            else:
                f.type.encode(v[f.name], buf)
        extras = sorted(k for k in v if k not in fmap)
        _put_varint(buf, len(extras))
        for k in extras:
            STRING.encode(k, buf)
            _encode_any(v[k], buf)
        return bytes(buf) if out is None else b""

    def decode(self, data: Any, pos: int = 0) -> Tuple[Dict[str, Any], int]:
        buf = memoryview(data) if not isinstance(data, memoryview) else data
        out: Dict[str, Any] = {}
        for f in self.fields:
            if f.optional:
                present = buf[pos]; pos += 1
                if not present:
                    continue
            out[f.name], pos = f.type.decode(buf, pos)
        n, pos = _get_varint(buf, pos)
        for _ in range(n):
            k, pos = STRING.decode(buf, pos)
            out[k], pos = _decode_any(buf, pos)
        return out, pos

    def encoded_size(self, v: Dict[str, Any]) -> int:
        return len(self.encode(self.validate(v)))

    # -- schema surgery (the Table-2 experiment) ---------------------------
    def key_only(self, *key_fields: str) -> "RecordType":
        """The paper's *KeyOnly* variant: declare only the primary key; every
        other field becomes an instance-level open field."""
        keep = tuple(f for f in self.fields if f.name in key_fields)
        missing = set(key_fields) - {f.name for f in keep}
        if missing:
            raise ValidationError(f"unknown key fields {sorted(missing)}")
        return RecordType(self.name + "_KeyOnly", keep, open=True)

    def closed(self) -> "RecordType":
        return RecordType(self.name, self.fields, open=False)


# ---------------------------------------------------------------------------
# Dataverse: the top-level namespace (paper §2.1)
# ---------------------------------------------------------------------------

@dataclass
class Dataverse:
    """A namespace of types + datasets; the system catalog is itself stored as
    data ("eats its own dog food", paper §3 Query 1)."""

    name: str
    types: Dict[str, RecordType] = _dc_field(default_factory=dict)
    datasets: Dict[str, Any] = _dc_field(default_factory=dict)

    def create_type(self, rt: RecordType) -> RecordType:
        if rt.name in self.types:
            raise ValidationError(f"type {rt.name} already exists in {self.name}")
        self.types[rt.name] = rt
        return rt

    def create_dataset(self, name: str, dataset: Any) -> Any:
        if name in self.datasets:
            raise ValidationError(f"dataset {name} already exists in {self.name}")
        self.datasets[name] = dataset
        return dataset

    def catalog_records(self) -> List[Dict[str, Any]]:
        """Metadata-as-data: one record per dataset (cf. Query 1)."""
        recs = []
        for dname, ds in self.datasets.items():
            recs.append({
                "dataverse": self.name,
                "dataset": dname,
                "datatype": getattr(getattr(ds, "dtype", None), "name", "?"),
                "primary_key": list(getattr(ds, "primary_key", ()) or ()),
                "num_partitions": getattr(ds, "num_partitions", 1),
            })
        return recs
