"""Algebricks-analogue logical/physical algebra (paper §4.1–4.2).

Jobs are DAGs of Operators and Connectors.  A *logical* plan describes what to
compute; the rewriter (core/rewriter.py) turns it into a *physical* plan where
every edge carries a Connector and every operator declares the partitioning
property it requires/delivers.  Data moves only when required != delivered —
the paper's central optimizer invariant.

Two backends execute the same algebra (Algebricks is "data-model-neutral"):
  * storage/query.py — the faithful mini-BDMS record engine (Tables 3/4)
  * the sharding planner — maps the same property calculus onto PartitionSpecs
    for train/serve steps (runtime/sharding.py)
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Partitioning", "RANDOM", "SINGLETON", "hash_partitioned", "broadcast",
    "Connector", "ONE_TO_ONE", "MToNHashPartition", "MToNReplicate",
    "MToNHashPartitionMerge", "ReplicateToOne",
    "LogicalOp", "PhysicalOp", "scan", "select", "project", "join",
    "group_by", "aggregate", "order_by", "limit",
]


# ---------------------------------------------------------------------------
# Partitioning properties
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Partitioning:
    """Structural property of an operator's output across N partitions."""

    kind: str                       # random | hash | broadcast | singleton
    keys: Tuple[str, ...] = ()
    # local (within-partition) order, used by merging connectors
    local_order: Tuple[str, ...] = ()

    def satisfies(self, required: "Partitioning") -> bool:
        if required.kind == "random":
            return True  # anything is a valid random partitioning
        if required.kind != self.kind:
            return False
        if required.keys and self.keys != required.keys:
            return False
        if required.local_order and self.local_order[:len(required.local_order)] \
                != required.local_order:
            return False
        return True


RANDOM = Partitioning("random")
SINGLETON = Partitioning("singleton")


def hash_partitioned(*keys: str, local_order: Sequence[str] = ()) -> Partitioning:
    return Partitioning("hash", tuple(keys), tuple(local_order))


def broadcast() -> Partitioning:
    return Partitioning("broadcast")


# ---------------------------------------------------------------------------
# Connectors (paper §4.1 lists the Hyracks connector library)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Connector:
    name: str
    keys: Tuple[str, ...] = ()
    sort_keys: Tuple[str, ...] = ()

    def __str__(self) -> str:
        extra = f"({','.join(self.keys)})" if self.keys else ""
        return f"{self.name}{extra}"


ONE_TO_ONE = Connector("OneToOne")


def MToNHashPartition(*keys: str) -> Connector:
    return Connector("MToNHashPartition", tuple(keys))


def MToNReplicate() -> Connector:
    return Connector("MToNReplicate")


def MToNHashPartitionMerge(keys: Sequence[str], sort_keys: Sequence[str]) -> Connector:
    return Connector("MToNHashPartitionMerge", tuple(keys), tuple(sort_keys))


def ReplicateToOne() -> Connector:
    """Fan-in to a singleton global operator (Figure 6's MToNReplicating into
    the one Global Aggregation instance)."""
    return Connector("ReplicateToOne")


# ---------------------------------------------------------------------------
# Logical operators
# ---------------------------------------------------------------------------

_ids = itertools.count()


@dataclass
class LogicalOp:
    kind: str
    children: Tuple["LogicalOp", ...] = ()
    attrs: Dict[str, Any] = field(default_factory=dict)
    op_id: int = field(default_factory=lambda: next(_ids))

    def replace_children(self, children: Sequence["LogicalOp"]) -> "LogicalOp":
        return LogicalOp(self.kind, tuple(children), dict(self.attrs))

    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        meta = {k: v for k, v in self.attrs.items() if not callable(v)}
        s = f"{pad}{self.kind} {meta}\n"
        for c in self.children:
            s += c.pretty(indent + 1)
        return s


def scan(dataset: str, **attrs: Any) -> LogicalOp:
    return LogicalOp("SCAN", (), {"dataset": dataset, **attrs})


def select(child: LogicalOp, pred: Callable, *, fields: Sequence[str],
           ranges: Optional[Dict[str, Tuple[Any, Any]]] = None,
           spatial: Optional[Tuple[str, Tuple[float, float], float]] = None,
           keyword: Optional[Tuple[str, str, int]] = None,
           fuzzy: Optional[Tuple[str, str, str, Any]] = None,
           hints: Sequence[str] = (),
           ranges_exact: bool = False) -> LogicalOp:
    """``pred`` evaluates a row -> bool.  ``ranges`` exposes sargable
    [lo, hi] bounds per field (btree rule); ``spatial`` = (field, center,
    radius) exposes a circle predicate (rtree rule, paper Q5); ``keyword`` =
    (field, token, edit_distance) exposes a token predicate (keyword index
    rule, paper Q6); ``fuzzy`` = (field, "ed"|"jaccard", target, param[,
    gram_k]) exposes a whole-field similarity predicate (ngram index
    rule, the paper's fuzzy selects) whose candidates the columnar engine
    generates via T-occurrence and verifies with the batched similarity
    kernels (``fuzzy.fuzzy_predicate(spec)`` builds the matching scalar
    oracle).  ``ranges_exact=True`` asserts that the declared access
    predicates — ``ranges``, plus the fuzzy spec when present — fully
    capture ``pred``, letting the columnar engine skip the row-at-a-time
    residual re-check (and fuse filter+aggregate into one kernel
    pass)."""
    return LogicalOp("SELECT", (child,),
                     {"pred": pred, "fields": tuple(fields),
                      "ranges": dict(ranges or {}), "spatial": spatial,
                      "keyword": keyword, "fuzzy": fuzzy,
                      "hints": tuple(hints),
                      "ranges_exact": bool(ranges_exact)})


def project(child: LogicalOp, cols: Sequence[str]) -> LogicalOp:
    return LogicalOp("PROJECT", (child,), {"cols": tuple(cols)})


def join(left: LogicalOp, right: LogicalOp, lkeys: Sequence[str],
         rkeys: Sequence[str], hints: Sequence[str] = ()) -> LogicalOp:
    return LogicalOp("JOIN", (left, right),
                     {"lkeys": tuple(lkeys), "rkeys": tuple(rkeys),
                      "hints": tuple(hints)})


def group_by(child: LogicalOp, keys: Sequence[str],
             aggs: Dict[str, Tuple[str, str]]) -> LogicalOp:
    """aggs: out_name -> (fn, col) with fn in count|sum|min|max|avg."""
    return LogicalOp("GROUPBY", (child,), {"keys": tuple(keys), "aggs": dict(aggs)})


def aggregate(child: LogicalOp, aggs: Dict[str, Tuple[str, str]]) -> LogicalOp:
    return LogicalOp("AGG", (child,), {"aggs": dict(aggs)})


def order_by(child: LogicalOp, keys: Sequence[str], desc: bool = False) -> LogicalOp:
    return LogicalOp("ORDERBY", (child,), {"keys": tuple(keys), "desc": desc})


def limit(child: LogicalOp, n: int) -> LogicalOp:
    return LogicalOp("LIMIT", (child,), {"n": int(n)})


# ---------------------------------------------------------------------------
# Physical operators
# ---------------------------------------------------------------------------

@dataclass
class PhysicalOp:
    """An operator instance with its delivered partitioning and, per input
    edge, the connector that feeds it."""

    kind: str
    children: Tuple["PhysicalOp", ...] = ()
    connectors: Tuple[Connector, ...] = ()
    attrs: Dict[str, Any] = field(default_factory=dict)
    delivered: Partitioning = RANDOM

    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        meta = {k: v for k, v in self.attrs.items() if not callable(v)}
        s = f"{pad}{self.kind} {meta} ~{self.delivered.kind}" \
            f"{list(self.delivered.keys) if self.delivered.keys else ''}\n"
        for conn, c in zip(self.connectors, self.children):
            s += f"{pad} <-[{conn}]-\n"
            s += c.pretty(indent + 1)
        return s

    def all_ops(self) -> List["PhysicalOp"]:
        out = [self]
        for c in self.children:
            out.extend(c.all_ops())
        return out

    def count_connectors(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for op in self.all_ops():
            for conn in op.connectors:
                counts[conn.name] = counts.get(conn.name, 0) + 1
        return counts
