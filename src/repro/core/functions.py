"""Advanced-type functions (paper Table 1): text similarity, spatial, and
temporal-binning primitives used by the fuzzy/spatial/temporal query paths.
"""

from __future__ import annotations

import datetime as _dt
import math
import re
from typing import Iterable, List, Sequence, Set, Tuple

__all__ = [
    "edit_distance", "edit_distance_check", "word_tokens",
    "similarity_jaccard", "similarity_jaccard_check", "gram_tokens",
    "spatial_distance", "spatial_intersect_circle", "spatial_cell",
    "interval_bin",
]


# -- text ---------------------------------------------------------------------

def edit_distance(a: str, b: str) -> int:
    """Levenshtein distance (banded DP not needed at these lengths)."""
    if len(a) < len(b):
        a, b = b, a
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        cur = [i]
        for j, cb in enumerate(b, 1):
            cur.append(min(prev[j] + 1, cur[j - 1] + 1,
                           prev[j - 1] + (ca != cb)))
        prev = cur
    return prev[-1]


def edit_distance_check(a: str, b: str, d: int) -> bool:
    """Early-exit check (paper: edit-distance-check): length filter first."""
    if abs(len(a) - len(b)) > d:
        return False
    return edit_distance(a, b) <= d


_WORD_RE = re.compile(r"[a-z0-9]+")


def word_tokens(s: str) -> List[str]:
    return _WORD_RE.findall(s.lower())


def gram_tokens(s: str, k: int = 3) -> List[str]:
    """ngram(k) tokens (the paper's fuzzy-search index unit)."""
    padded = f"{'#' * (k - 1)}{s.lower()}{'#' * (k - 1)}"
    return [padded[i:i + k] for i in range(len(padded) - k + 1)]


def similarity_jaccard(a: Iterable, b: Iterable) -> float:
    sa, sb = set(a), set(b)
    if not sa and not sb:
        return 1.0
    return len(sa & sb) / len(sa | sb)


def similarity_jaccard_check(a: Iterable, b: Iterable, t: float) -> bool:
    return similarity_jaccard(a, b) >= t


# -- spatial ------------------------------------------------------------------

def spatial_distance(p1: Sequence[float], p2: Sequence[float]) -> float:
    return math.hypot(p1[0] - p2[0], p1[1] - p2[1])


def spatial_intersect_circle(p: Sequence[float], center: Sequence[float],
                             radius: float) -> bool:
    return spatial_distance(p, center) <= radius


def spatial_cell(p: Sequence[float], cell: float) -> Tuple[int, int]:
    """Grid cell of a point — the unit of the grid-bucketed 'rtree' index."""
    return (math.floor(p[0] / cell), math.floor(p[1] / cell))


def cells_covering_circle(center: Sequence[float], radius: float,
                          cell: float) -> List[Tuple[int, int]]:
    x0, y0 = spatial_cell((center[0] - radius, center[1] - radius), cell)
    x1, y1 = spatial_cell((center[0] + radius, center[1] + radius), cell)
    return [(x, y) for x in range(x0, x1 + 1) for y in range(y0, y1 + 1)]


# -- temporal -----------------------------------------------------------------

def interval_bin(t: _dt.datetime, origin: _dt.datetime,
                 width: _dt.timedelta) -> _dt.datetime:
    """paper Table 1 interval-bin: the bin start containing ``t`` (used for
    the time-windowed aggregation the third pilot needed)."""
    n = (t - origin) // width
    return origin + n * width
