"""Generic LSM index framework (paper §4.3–4.4), columnar-native.

AsterixDB "wholly embraced" LSM trees: every index is a mutable *in-memory
component* plus immutable *disk components*; flush on memory threshold, merge
under a policy; recovery uses LSM-index-level **logical logging** (no-steal/
no-force WAL, one log record per index update) plus **component shadowing**
(a new component becomes real only when its *validity bit* is set — invalid
components are deleted at recovery).

This module is the host-side framework: it "LSM-ifies" a sorted-array index
(our B+-tree stand-in: binary search over sorted keys).  It backs the
partitioned storage engine (storage/) and the same component/validity/merge
calculus is reused device-side by the LSM-tiered KV cache (kvcache/) and by
the checkpoint manager (checkpoint/).

Storage is **columnar-first** (cf. the columnar-LSM paper in PAPERS.md):
``flush()`` shreds the memtable of a record (dict-valued) index straight
into a sorted-by-key ``columnar.batch.ColumnBatch`` + tombstone bitmap,
which *is* the component's primary on-disk representation; ``merge()`` is
a column-wise k-way merge whose take-indices come from the vectorized
``kernels.columnar_ops.sorted_merge_take`` kernel (newest-wins dedup +
tombstone collapse), so no row dict is ever materialized on the merge
path; ``recover()`` keeps surviving columnar components as-is and replays
the WAL tail into the memtable, which re-shreds at its next flush.  Row
dicts are a *derived, lazy* view (``Component.rows``) built only for
legacy row-at-a-time callers.  Indexes whose values are not records
keep the classic row-array storage (``columnar=False`` forces it, e.g.
for benchmarking the old row path).  Secondary index structures are not
separate LSM trees at all: components carry per-field columnar CSR
postings (``gram_postings`` for ngram, ``sec_postings`` for
btree/rtree/keyword) as derived data built beside the batch.

Transaction model (paper §2.4/§4.4 — "transaction support akin to that
of a NoSQL store", serving reads while feeds ingest):

  * **Writes are serialized per index** — every mutation (``insert`` /
    ``delete`` / ``insert_batch`` / ``flush`` / ``merge``) runs under the
    index's reentrant ``_lock``, so the WAL append, the memtable update,
    and any flush/merge the update triggers are one atomic step with
    respect to other writers and to snapshot pins.  Different partitions
    of a dataset hold different LSMIndex objects, so partitioned writes
    stay concurrent across partitions.
  * **Reads get snapshot isolation via component-set pinning** —
    ``pin()`` returns a refcounted :class:`LSMView`: a frozen
    (memtable-copy, valid-component-tuple) pair stamped with the index's
    monotone ``version``.  Components are immutable and the view's
    memtable is a private copy, so a pinned reader sees one consistent
    LSM state end to end with zero further coordination (no lock on the
    read path).
  * **Flush/merge install new component lists copy-on-write** — the
    ``components`` list is never mutated in place; a new list is built
    and rebound in one assignment, so any concurrently-grabbed reference
    (a pinned view's tuple, an in-flight iteration) stays valid.
  * **Deferred physical retirement** — a merge that replaces components
    cannot drop them while a pinned view still references them: each
    pin takes a per-component refcount, and replaced components with a
    nonzero pincount park in ``_deferred`` until their last ``unpin``
    (then ``Component.retired`` flips and the ``lsm.deferred_retires``
    counter ticks).  ``pinned_versions()`` exposes the live pin set so
    the dataset's scan cache can key (and GC) entries by snapshot
    version.
"""

from __future__ import annotations

import bisect
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs as _obs
from ..columnar.batch import ColumnBatch
from ..columnar.schema import ColumnSchema

__all__ = ["Component", "LSMIndex", "LSMView", "TieredMergePolicy",
           "WALRecord", "TOMBSTONE", "key_array", "recover",
           "component_nbytes"]

# process-wide storage metrics (see obs.__init__ for the name registry);
# handles resolved once so flush/merge pay dict-free increments
_FLUSH_S = _obs.histogram("lsm.flush_seconds")
_MERGE_S = _obs.histogram("lsm.merge_seconds")
_POSTINGS_S = _obs.histogram("lsm.postings_build_seconds")
_COMP_ROWS = _obs.histogram("lsm.component_rows")
_COMP_BYTES = _obs.histogram("lsm.component_bytes")
_FLUSHES = _obs.counter("lsm.flushes")
_MERGES = _obs.counter("lsm.merges")
_ROWS_INGESTED = _obs.counter("lsm.rows_ingested")
_ROWS_FLUSHED = _obs.counter("lsm.rows_flushed")
_ROWS_MERGED = _obs.counter("lsm.rows_merged")
_BYTES_FLUSHED = _obs.counter("lsm.bytes_flushed")
_BYTES_MERGED = _obs.counter("lsm.bytes_merged")
_COMPONENTS = _obs.gauge("lsm.components")
_PINS = _obs.counter("lsm.pins")
_DEFERRED = _obs.counter("lsm.deferred_retires")
_PINNED_G = _obs.gauge("lsm.pinned_snapshots")


def _pool_release(comp: "Component") -> None:
    """Device-pool eviction hook: a component's device buffers are freed
    at the exact moment its ``retired`` flag flips — immediately at merge
    when unpinned, or deferred until the last snapshot pin drops (lazy
    import: the storage layer works without the kernel stack loaded)."""
    from ..kernels.device_pool import pool
    pool.release_component(comp)


def _arr_nbytes(a: Optional[np.ndarray]) -> int:
    if a is None:
        return 0
    if a.dtype == object:
        return 8 * int(a.shape[0])      # pointer-width estimate
    return int(a.nbytes)


def component_nbytes(comp: "Component") -> int:
    """Estimated storage footprint of one component: column data arrays +
    validity bitmaps + string dictionaries + key array + tombstone
    bitmap (row-mode components estimate pointer width per row)."""
    total = _arr_nbytes(comp.keys) + _arr_nbytes(comp.tomb)
    if comp.batch is not None:
        for col in comp.batch.columns.values():
            total += _arr_nbytes(col.data) + _arr_nbytes(col.valid)
            if col.values:
                total += sum(len(v) if isinstance(v, str) else 8
                             for v in col.values)
    elif comp._rows is not None:
        total += 8 * len(comp._rows)
    return total


class _Tombstone:
    def __repr__(self) -> str:
        return "<TOMBSTONE>"


TOMBSTONE = _Tombstone()

_component_ids = itertools.count()


def _obj_array(items: Sequence[Any]) -> np.ndarray:
    """1-D object array even for uniform tuples (np.asarray would build a
    2-D array out of a list of equal-length tuples, breaking searchsorted)."""
    arr = np.empty(len(items), dtype=object)
    for i, x in enumerate(items):
        arr[i] = x
    return arr


def key_array(ks: Sequence[Any]) -> np.ndarray:
    """Sorted-run key-array conversion, shared by flush sorting and the
    dataset's live-row selection: a numeric ndarray when the key domain
    converts losslessly, else a 1-D object array of python scalars.
    Numpy scalar inputs normalize to python first — their cross-dtype
    comparisons promote lossily — and the tolist round-trip rejects lossy
    unification (e.g. an int beyond 2**53 coerced to float64 by a mixed
    int/float domain)."""
    ks = [k.item() if isinstance(k, np.generic) else k for k in ks]
    try:
        arr = np.asarray(ks)
        if arr.dtype == object or arr.dtype.kind not in "biuf" \
                or arr.tolist() != ks:
            raise TypeError("non-numeric keys")
        return arr
    except (TypeError, ValueError, OverflowError):
        return _obj_array(ks)


def _sorted_kv(mem: Dict[Any, Any]) -> Tuple[np.ndarray, List[Any]]:
    """(sorted key array, aligned values).  Numeric key domains sort via
    numpy argsort and stay numeric arrays (so downstream kernels — merge
    take-indices, candidate bitmaps — run vectorized); anything else
    falls back to python sort over an object array."""
    arr = key_array(list(mem))
    vals = list(mem.values())           # aligned with list(mem)
    if arr.dtype != object:
        order = np.argsort(arr, kind="stable")
        return arr[order], [vals[j] for j in order.tolist()]
    order = sorted(range(arr.shape[0]), key=arr.__getitem__)
    idx = np.asarray(order, dtype=np.int64) if order \
        else np.zeros(0, dtype=np.int64)
    return arr[idx], [vals[j] for j in order]


@dataclass
class Component:
    """An immutable sorted run.  ``valid`` is the paper's validity bit: set
    atomically as the final action of the flush/merge that created it.

    Record components store a ``batch`` (ColumnBatch, shredded at flush/
    merge) plus a ``tomb`` bitmap as primary data; the row-dict view is
    derived lazily.  Row-mode components (non-record values, or a forced
    row path) store the object array directly and can derive a batch view
    on demand (``as_batch``).

    ``gram_postings`` holds the fuzzy subsystem's per-field ngram(k) CSR
    postings (fuzzy/ngram.GramPostings), built at flush/merge right next
    to the batch — from the batch's string dictionary, never from row
    dicts — for every field the owning index registers in
    ``ngram_fields``.  ``sec_postings`` is the same calculus generalized
    to the btree/rtree/keyword secondary kinds
    (columnar/postings.FieldPostings): per-field CSR candidate structures
    keyed by their index spec, derived from the batch exactly like ngram
    postings and adopted as-is by recovery."""

    keys: np.ndarray                      # sorted; numeric or object dtype
    batch: Optional[ColumnBatch] = None   # columnar primary data
    tomb: Optional[np.ndarray] = None     # bool bitmap: entry is a delete
    valid: bool = False
    retired: bool = False                 # physically retired (replaced by a
    #                                       merge and no longer pinned)
    comp_id: int = field(default_factory=lambda: next(_component_ids))
    gram_postings: Dict[str, Any] = field(default_factory=dict, repr=False)
    sec_postings: Dict[str, Any] = field(default_factory=dict, repr=False)
    _rows: Optional[np.ndarray] = field(default=None, repr=False)

    @classmethod
    def build(cls, keys: np.ndarray, vals: Sequence[Any],
              schema: Optional[Any] = None,
              columnar: Optional[bool] = None,
              ngram_fields: Optional[Dict[str, int]] = None,
              sec_fields: Optional[Dict[str, Tuple[str, Any]]] = None
              ) -> "Component":
        """Shred sorted (key, value) pairs into a component.  Values that
        are all records (dicts) or tombstones shred columnar (unless
        ``columnar=False``); anything else keeps row storage.
        ``ngram_fields`` (field -> gram length) and ``sec_fields``
        (field -> (kind, param) secondary spec) name fields that get
        postings built alongside the batch."""
        tomb = np.fromiter((v is TOMBSTONE for v in vals), dtype=bool,
                           count=len(vals))
        shred = columnar is not False and all(
            v is TOMBSTONE or isinstance(v, dict) for v in vals)
        if not shred:
            c = cls(keys=keys, tomb=tomb)
            c._rows = _obj_array(vals)
            c._build_postings(ngram_fields, sec_fields)
            return c
        rows = [{} if t else v for t, v in zip(tomb.tolist(), vals)]
        sch = schema() if callable(schema) else schema
        if sch is not None:
            extra: Optional[ColumnSchema] = None
            for r in rows:          # never drop fields the schema missed
                for k, v in r.items():
                    if k not in sch:
                        extra = extra or ColumnSchema()
                        extra.observe_value(k, v)
            if extra is not None:
                sch = sch.union(extra)
        c = cls(keys=keys, batch=ColumnBatch.from_rows(rows, sch),
                tomb=tomb)
        c._build_postings(ngram_fields, sec_fields)
        return c

    def _build_ngrams(self, ngram_fields: Optional[Dict[str, int]]) -> None:
        for fld, k in (ngram_fields or {}).items():
            self.ensure_gram_postings(fld, k)

    def _build_postings(self, ngram_fields: Optional[Dict[str, int]],
                        sec_fields: Optional[Dict[str, Tuple[str, Any]]]
                        ) -> None:
        self._build_ngrams(ngram_fields)
        for fld, spec in (sec_fields or {}).items():
            self.ensure_sec_postings(fld, spec)

    def ensure_sec_postings(self, fld: str, spec: Tuple[str, Any]) -> Any:
        """The field's secondary (btree/rtree/keyword) CSR postings, built
        once per component and per spec (a changed spec — e.g. a new
        rtree cell size — rebuilds).  Columnar components shred from the
        batch column; row-mode components fall back to the value list."""
        p = self.sec_postings.get(fld)
        if p is not None and p.spec == spec:
            return p
        from ..columnar.postings import FieldPostings
        t0 = time.perf_counter()
        with _obs.span("lsm.postings_build", field=fld):
            if self.batch is not None:
                p = FieldPostings.from_batch(self.batch, fld, spec,
                                             self.size)
            else:
                vals = [r.get(fld) if isinstance(r, dict) else None
                        for r in (self._rows
                                  if self._rows is not None else ())]
                p = FieldPostings.from_values(vals, spec)
        _POSTINGS_S.observe(time.perf_counter() - t0)
        self.sec_postings[fld] = p
        return p

    def ensure_gram_postings(self, fld: str, k: int) -> Any:
        """The field's ngram(k) postings, built once per component (it is
        immutable).  Columnar components shred from the batch column
        (gram hashing per dictionary value); row-mode components fall
        back to the value list."""
        p = self.gram_postings.get(fld)
        if p is not None and p.k == k:
            return p
        from ..fuzzy.ngram import GramPostings
        t0 = time.perf_counter()
        with _obs.span("lsm.postings_build", field=fld):
            if self.batch is not None:
                p = GramPostings.from_batch(self.batch, fld, k, self.size)
            else:
                vals = [r.get(fld) if isinstance(r, dict) else None
                        for r in (self._rows
                                  if self._rows is not None else ())]
                p = GramPostings.from_values(vals, k)
        _POSTINGS_S.observe(time.perf_counter() - t0)
        self.gram_postings[fld] = p
        return p

    @property
    def size(self) -> int:
        return int(self.keys.shape[0])

    @property
    def key_range(self) -> Tuple[Any, Any]:
        return (self.keys[0], self.keys[-1]) if self.size else (None, None)

    @property
    def is_columnar(self) -> bool:
        return self.batch is not None

    @property
    def rows(self) -> np.ndarray:
        """Derived row-dict view (lazy, cached): TOMBSTONE sentinels where
        ``tomb`` is set, reassembled records elsewhere.  Only legacy
        row-at-a-time callers force this; flush/merge/scan never do."""
        if self._rows is None:
            decoded = self.batch.to_rows()
            out = np.empty(len(decoded), dtype=object)
            tomb = self.tomb
            for i, r in enumerate(decoded):
                out[i] = TOMBSTONE if tomb[i] else r
            self._rows = out
        return self._rows

    def as_batch(self, schema: Optional[Any] = None) -> ColumnBatch:
        """Columnar view: primary storage when shredded at flush/merge;
        shredded once (and cached) for row-mode record components."""
        if self.batch is None:
            sch = schema() if callable(schema) else schema
            self.batch = ColumnBatch.from_rows(
                [r if isinstance(r, dict) else {} for r in self._rows], sch)
        return self.batch

    def row_at(self, i: int) -> Any:
        """Value at position ``i`` without forcing the full row view."""
        if self._rows is not None:
            return self._rows[i]
        if self.tomb[i]:
            return TOMBSTONE
        return self.batch.row_at(i)

    def lookup(self, key: Any) -> Optional[Any]:
        # bisect (not np.searchsorted): tuple keys must stay scalar probes
        i = bisect.bisect_left(self.keys, key)
        if i < self.size and self.keys[i] == key:
            return self.row_at(i)
        return None

    def range(self, lo: Any, hi: Any) -> Tuple[np.ndarray, np.ndarray]:
        i = bisect.bisect_left(self.keys, lo)
        j = bisect.bisect_right(self.keys, hi)
        return self.keys[i:j], self.rows[i:j]


@dataclass(frozen=True)
class WALRecord:
    """One *logical* log record per index update (paper §4.4)."""

    lsn: int
    op: str          # "insert" | "delete"
    key: Any
    row: Any = None


@dataclass(frozen=True)
class TieredMergePolicy:
    """Merge when >= ``k`` components sit within ``ratio`` of each other in
    size (a standard tiered/size-ratio policy; AsterixDB ships constant +
    prefix policies — tiered subsumes the behavior we benchmark)."""

    k: int = 4
    ratio: float = 1.5

    def pick(self, comps: Sequence[Component]) -> Optional[List[int]]:
        if len(comps) < self.k:
            return None
        # components ordered newest->oldest; scan windows of k
        for start in range(0, len(comps) - self.k + 1):
            window = comps[start:start + self.k]
            sizes = [max(c.size, 1) for c in window]
            if max(sizes) <= self.ratio * min(sizes):
                return list(range(start, start + self.k))
        if len(comps) >= 2 * self.k:   # backstop: merge everything old
            return list(range(len(comps) - self.k, len(comps)))
        return None


class LSMView:
    """A point-in-time (memtable, component-set) view of an LSMIndex.

    Two flavours share one read surface:

      * ``LSMIndex.current_view()`` — *unfrozen*: references the live
        memtable (single-threaded read paths; concurrent readers must
        pin instead).
      * ``LSMIndex.pin()`` — *frozen*: the memtable is a private copy,
        the component tuple is refcount-pinned, and ``release()`` (or
        ``LSMIndex.unpin``) must be called exactly once to let replaced
        components physically retire.

    ``version`` is the owning index's monotone mutation counter at view
    time — the snapshot-isolation key the dataset scan cache uses.
    """

    __slots__ = ("version", "memtable", "components", "frozen",
                 "_owner", "_released")

    def __init__(self, version: int, memtable: Dict[Any, Any],
                 components: Tuple[Component, ...], frozen: bool,
                 owner: Optional["LSMIndex"] = None):
        self.version = version
        self.memtable = memtable
        self.components = components      # valid components, newest first
        self.frozen = frozen
        self._owner = owner
        self._released = False

    def release(self) -> None:
        """Drop this view's component pins (frozen views only; idempotent
        no-op for unfrozen ones)."""
        if self.frozen and not self._released and self._owner is not None:
            self._owner.unpin(self)

    def __enter__(self) -> "LSMView":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    # -- read surface (never takes the index lock) --------------------------
    def lookup(self, key: Any) -> Optional[Any]:
        if key in self.memtable:
            r = self.memtable[key]
            return None if r is TOMBSTONE else r
        for c in self.components:
            r = c.lookup(key)
            if r is not None:
                return None if r is TOMBSTONE else r
        return None

    def items(self) -> Iterator[Tuple[Any, Any]]:
        """Live (key, row) pairs, newest-wins, sorted by key."""
        seen: Dict[Any, Any] = {}
        for c in reversed(self.components):
            for k, r in zip(c.keys, c.rows):
                seen[k] = r
        seen.update(self.memtable)
        for k in sorted(seen):
            if seen[k] is not TOMBSTONE:
                yield k, seen[k]


class LSMIndex:
    """LSM-ified ordered index: dict memtable + sorted-run components.

    ``schema`` (a ColumnSchema or a zero-arg callable returning one, e.g.
    ``PartitionedDataset.columnar_schema``) steers flush-time shredding;
    ``ngram_fields`` (a dict field -> gram length, or a zero-arg callable
    returning one) names fields whose flush/merge output carries ngram
    postings; ``sec_fields`` (a dict field -> (kind, param) secondary
    spec, or a zero-arg callable) does the same for btree/rtree/keyword
    CSR postings; ``columnar=False`` forces classic row-array components
    (the benchmarked legacy path)."""

    def __init__(self, flush_threshold: int = 1024,
                 merge_policy: Optional[TieredMergePolicy] = None,
                 wal: Optional[List[WALRecord]] = None,
                 schema: Optional[Any] = None,
                 columnar: Optional[bool] = None,
                 ngram_fields: Optional[Any] = None,
                 sec_fields: Optional[Any] = None):
        self.flush_threshold = int(flush_threshold)
        self.merge_policy = merge_policy or TieredMergePolicy()
        self.memtable: Dict[Any, Any] = {}
        self.components: List[Component] = []   # newest first
        self.wal: List[WALRecord] = wal if wal is not None else []
        self._lsn = itertools.count(len(self.wal))
        self.schema = schema
        self.columnar = columnar
        self.ngram_fields = ngram_fields
        self.sec_fields = sec_fields
        self.stats = {"flushes": 0, "merges": 0, "inserts": 0, "deletes": 0,
                      "merged_rows": 0, "flushed_rows": 0,
                      "flushed_bytes": 0, "merged_bytes": 0,
                      "pins": 0, "deferred_retires": 0}
        self._ingest_counted = 0    # inserts+deletes already counted into
        #                             the process-wide lsm.rows_ingested
        # -- concurrency: per-index write serialization + snapshot pins ----
        self._lock = threading.RLock()   # WAL + memtable + flush/merge path
        self._version = 0                # monotone, bumped on any mutation
        self._comp_pins: Dict[int, int] = {}       # comp_id -> pin count
        self._deferred: Dict[int, Component] = {}  # replaced but still pinned
        self._pin_versions: Dict[int, int] = {}    # version -> live pin count

    def write_amplification(self) -> float:
        """(rows flushed + rows re-written by merges) / rows ingested.
        1.0 means every ingested row was written once and never
        rewritten; tiered merging pushes it up with every rewrite.  0.0
        until the first flush."""
        ingested = self.stats["inserts"] + self.stats["deletes"]
        if not ingested:
            return 0.0
        return (self.stats["flushed_rows"]
                + self.stats["merged_rows"]) / ingested

    # -- snapshot pinning (read-side transaction surface) -------------------
    @property
    def version(self) -> int:
        """Monotone mutation counter: bumps on every insert/delete batch,
        flush, and merge.  Equal versions imply identical visible state,
        so snapshot readers and the dataset scan cache key on it."""
        return self._version

    def current_view(self) -> LSMView:
        """Unfrozen point-in-time view sharing the live memtable (the
        single-threaded read path; concurrent readers must ``pin()``)."""
        return LSMView(self._version, self.memtable,
                       tuple(c for c in self.components if c.valid),
                       frozen=False, owner=self)

    def pin(self) -> LSMView:
        """Refcounted snapshot handle: a frozen (memtable-copy,
        component-tuple) pair.  Components it references cannot be
        physically retired until the matching ``unpin``/``release``."""
        with self._lock:
            comps = tuple(c for c in self.components if c.valid)
            for c in comps:
                self._comp_pins[c.comp_id] = \
                    self._comp_pins.get(c.comp_id, 0) + 1
            self._pin_versions[self._version] = \
                self._pin_versions.get(self._version, 0) + 1
            self.stats["pins"] += 1
            view = LSMView(self._version, dict(self.memtable), comps,
                           frozen=True, owner=self)
        _PINS.inc()
        _PINNED_G.inc()
        return view

    def unpin(self, view: LSMView) -> None:
        """Release one pinned view: drop its component refcounts and
        physically retire any replaced component whose pin count reached
        zero (the deferred half of the merge's copy-on-write swap)."""
        retire: List[Component] = []
        with self._lock:
            if view._released:
                return
            view._released = True
            n = self._pin_versions.get(view.version, 0) - 1
            if n > 0:
                self._pin_versions[view.version] = n
            else:
                self._pin_versions.pop(view.version, None)
            for c in view.components:
                left = self._comp_pins.get(c.comp_id, 0) - 1
                if left > 0:
                    self._comp_pins[c.comp_id] = left
                else:
                    self._comp_pins.pop(c.comp_id, None)
                    dead = self._deferred.pop(c.comp_id, None)
                    if dead is not None:
                        retire.append(dead)
            for dead in retire:
                dead.retired = True
                self.stats["deferred_retires"] += 1
        for dead in retire:
            # deferred half of the device-pool eviction discipline: the
            # buffers outlived the merge exactly as long as the pins did
            _pool_release(dead)
        if retire:
            _DEFERRED.inc(len(retire))
        _PINNED_G.dec()

    def pinned_versions(self) -> Tuple[int, ...]:
        """Versions with at least one live pin (scan-cache GC keeps
        entries for exactly these plus the current version)."""
        return tuple(self._pin_versions)

    def _retire_replaced(self, replaced: Sequence[Component]) -> None:
        """Called under ``_lock`` by merge after the copy-on-write swap:
        unpinned components retire immediately, pinned ones defer."""
        for c in replaced:
            if self._comp_pins.get(c.comp_id, 0) > 0:
                self._deferred[c.comp_id] = c
            else:
                c.retired = True
                self.stats["deferred_retires"] += 1
                _DEFERRED.inc()
                _pool_release(c)       # merged away, unpinned: free now

    # -- update path (record-level "transactions": WAL then apply) ---------
    def insert(self, key: Any, row: Any) -> None:
        with self._lock:
            self.wal.append(WALRecord(next(self._lsn), "insert", key, row))
            self.memtable[key] = row
            self.stats["inserts"] += 1
            self._version += 1
            if len(self.memtable) >= self.flush_threshold:
                self.flush()

    def delete(self, key: Any) -> None:
        with self._lock:
            self.wal.append(WALRecord(next(self._lsn), "delete", key))
            self.memtable[key] = TOMBSTONE
            self.stats["deletes"] += 1
            self._version += 1
            if len(self.memtable) >= self.flush_threshold:
                self.flush()

    def insert_batch(self, keys: Sequence[Any], rows: Sequence[Any]) -> None:
        """Paper Table 4: batching amortizes per-statement overhead — one
        WAL/memtable pass per chunk and one flush-threshold check per
        chunk instead of per record (flushes still fire at the same
        thresholds, so component sizes match the per-record path)."""
        with self._lock:
            mem, wal, lsn = self.memtable, self.wal, self._lsn
            i, n = 0, len(keys)
            while i < n:
                take = max(self.flush_threshold - len(mem), 1)
                for k, r in zip(keys[i:i + take], rows[i:i + take]):
                    wal.append(WALRecord(next(lsn), "insert", k, r))
                    mem[k] = r
                done = min(i + take, n) - i
                self.stats["inserts"] += done
                i += take
                self._version += 1
                if len(mem) >= self.flush_threshold:
                    self.flush()
                    mem = self.memtable     # flush installed a fresh dict

    # -- flush / merge ------------------------------------------------------
    def _ngram(self) -> Dict[str, int]:
        nf = self.ngram_fields
        return nf() if callable(nf) else (nf or {})

    def _sec(self) -> Dict[str, Tuple[str, Any]]:
        sf = self.sec_fields
        return sf() if callable(sf) else (sf or {})

    def flush(self, *, crash_before_validity: bool = False) -> Optional[Component]:
        """Shadow-install the memtable as a new immutable component,
        shredding record values straight into the component's primary
        ColumnBatch (sorted by key) — rows are never re-materialized.
        With ``crash_before_validity`` the validity bit is never set,
        simulating a crash mid-flush: recovery must ignore the component
        (paper §4.4)."""
        with self._lock:
            if not self.memtable:
                return None
            t0 = time.perf_counter()
            with _obs.span("lsm.flush") as sp:
                keys, vals = _sorted_kv(self.memtable)
                comp = Component.build(keys, vals, schema=self.schema,
                                       columnar=self.columnar,
                                       ngram_fields=self._ngram(),
                                       sec_fields=self._sec())
                # copy-on-write shadow install: present but invalid; the
                # list object pinned views / in-flight readers grabbed is
                # never mutated, only rebound
                self.components = [comp] + self.components
                if crash_before_validity:
                    return comp
                comp.valid = True              # atomic install
                self.memtable = {}
                self._version += 1
                self.stats["flushes"] += 1
                nbytes = component_nbytes(comp)
                self.stats["flushed_rows"] += comp.size
                self.stats["flushed_bytes"] += nbytes
                sp.set("rows", comp.size)
                sp.set("bytes", nbytes)
            _FLUSH_S.observe(time.perf_counter() - t0)
            _FLUSHES.inc()
            _ROWS_FLUSHED.inc(comp.size)
            _BYTES_FLUSHED.inc(nbytes)
            _COMP_ROWS.observe(comp.size)
            _COMP_BYTES.observe(nbytes)
            # ingest accounting at flush granularity (never per-row): the
            # delta of this index's insert+delete counters since last flush
            ingested = self.stats["inserts"] + self.stats["deletes"]
            _ROWS_INGESTED.inc(ingested - self._ingest_counted)
            self._ingest_counted = ingested
            _COMPONENTS.set(sum(1 for c in self.components if c.valid))
            self._maybe_merge()
            return comp

    def _maybe_merge(self) -> None:
        while True:
            valid = [c for c in self.components if c.valid]
            pick = self.merge_policy.pick(valid)
            if pick is None:
                return
            self.merge([valid[i] for i in pick])

    def merge(self, comps: Sequence[Component],
              *, crash_before_validity: bool = False) -> Component:
        """Column-wise k-way merge: the ``sorted_merge_take`` kernel
        computes newest-wins take-indices over the per-component sorted
        key arrays once, then every column — merged string dictionaries
        included — is gathered without materializing a single row dict.
        Tombstones survive the merge unless it includes the oldest
        component (then they collapse).  Row-mode inputs (secondary
        indexes, forced row path) merge via the classic dict pass."""
        comps = list(comps)                    # newest -> oldest
        self._lock.acquire()
        try:
            return self._merge_locked(comps, crash_before_validity)
        finally:
            self._lock.release()

    def _merge_locked(self, comps: List[Component],
                      crash_before_validity: bool) -> Component:
        t0 = time.perf_counter()
        with _obs.span("lsm.merge", components=len(comps)) as sp:
            includes_oldest = self.components and comps[-1] is [
                c for c in self.components if c.valid][-1]
            if self.columnar is not False \
                    and all(c.batch is not None for c in comps):
                merged, keys, tomb = ColumnBatch.merge_sorted(
                    [c.batch for c in comps], [c.keys for c in comps],
                    [c.tomb for c in comps],
                    drop_tombstones=bool(includes_oldest))
                out = Component(keys=keys, batch=merged, tomb=tomb)
                # postings (ngram + secondary CSR) ride the merge too
                out._build_postings(self._ngram(), self._sec())
            else:
                seen: Dict[Any, Any] = {}
                for c in reversed(comps):      # oldest first; newer overwrite
                    for k, r in zip(c.keys, c.rows):
                        seen[k] = r
                if includes_oldest:
                    seen = {k: r for k, r in seen.items()
                            if r is not TOMBSTONE}
                keys, vals = _sorted_kv(seen)
                out = Component.build(keys, vals, schema=self.schema,
                                      columnar=self.columnar,
                                      ngram_fields=self._ngram(),
                                      sec_fields=self._sec())
            ids = {c.comp_id for c in comps}
            pos = min(i for i, c in enumerate(self.components)
                      if c.comp_id in ids)
            # copy-on-write shadow install next to the inputs
            shadowed = list(self.components)
            shadowed.insert(pos, out)
            self.components = shadowed
            if crash_before_validity:
                return out
            out.valid = True                   # atomic swap: install + retire
            self.components = [c for c in self.components
                               if c.comp_id not in ids]
            self._version += 1
            # replaced components physically retire now unless a pinned
            # snapshot still references them (then: deferred to unpin)
            self._retire_replaced(comps)
            self.stats["merges"] += 1
            self.stats["merged_rows"] += out.size
            nbytes = component_nbytes(out)
            self.stats["merged_bytes"] += nbytes
            sp.set("rows", out.size)
            sp.set("bytes", nbytes)
        _MERGE_S.observe(time.perf_counter() - t0)
        _MERGES.inc()
        _ROWS_MERGED.inc(out.size)
        _BYTES_MERGED.inc(nbytes)
        _COMP_ROWS.observe(out.size)
        _COMP_BYTES.observe(nbytes)
        _COMPONENTS.set(sum(1 for c in self.components if c.valid))
        return out

    # -- read path ----------------------------------------------------------
    def lookup(self, key: Any) -> Optional[Any]:
        if key in self.memtable:
            r = self.memtable[key]
            return None if r is TOMBSTONE else r
        for c in self.components:
            if not c.valid:
                continue
            r = c.lookup(key)
            if r is not None:
                return None if r is TOMBSTONE else r
        return None

    def range(self, lo: Any, hi: Any) -> List[Tuple[Any, Any]]:
        """Merged range scan across memtable + all valid components."""
        seen = self._range_merged(lo, hi)
        return [(k, seen[k]) for k in sorted(seen) if seen[k] is not TOMBSTONE]

    def range_values(self, lo: Any, hi: Any) -> List[Any]:
        """Live row values in [lo, hi], newest-wins, without sorting by key
        or materializing (key, row) pairs.  This is the candidate read path
        for secondary indexes, whose rows are primary keys: the caller gets
        a flat PK list to sort/intersect columnar-side (vectorized index
        access), never decoded records."""
        seen = self._range_merged(lo, hi)
        return [r for r in seen.values() if r is not TOMBSTONE]

    def _range_merged(self, lo: Any, hi: Any) -> Dict[Any, Any]:
        seen: Dict[Any, Any] = {}
        for c in reversed([c for c in self.components if c.valid]):
            ks, rs = c.range(lo, hi)
            for k, r in zip(ks, rs):
                seen[k] = r
        for k, r in self.memtable.items():
            if lo <= k <= hi:
                seen[k] = r
        return seen

    def __len__(self) -> int:
        return sum(1 for _ in self.items())

    def items(self) -> Iterator[Tuple[Any, Any]]:
        seen: Dict[Any, Any] = {}
        for c in reversed([c for c in self.components if c.valid]):
            for k, r in zip(c.keys, c.rows):
                seen[k] = r
        seen.update(self.memtable)
        for k in sorted(seen):
            if seen[k] is not TOMBSTONE:
                yield k, seen[k]


def recover(components: Sequence[Component], wal: Sequence[WALRecord],
            *, replay_from_lsn: int = 0, flush_threshold: int = 1024,
            schema: Optional[Any] = None,
            columnar: Optional[bool] = None,
            ngram_fields: Optional[Any] = None,
            sec_fields: Optional[Any] = None) -> LSMIndex:
    """Crash recovery (paper §4.4): drop components without the validity bit,
    then replay the committed WAL tail into a fresh memtable.  Surviving
    columnar components are adopted as-is (their batches *are* the data,
    ngram and secondary postings included); the replayed memtable
    re-shreds into the same form at its next flush."""
    idx = LSMIndex(flush_threshold=flush_threshold, schema=schema,
                   columnar=columnar, ngram_fields=ngram_fields,
                   sec_fields=sec_fields)
    idx.components = [c for c in components if c.valid]
    idx.wal = list(wal)
    idx._lsn = itertools.count(len(idx.wal))
    for rec in wal:
        if rec.lsn < replay_from_lsn:
            continue
        if rec.op == "insert":
            idx.memtable[rec.key] = rec.row
        elif rec.op == "delete":
            idx.memtable[rec.key] = TOMBSTONE
    return idx
