"""Generic LSM index framework (paper §4.3–4.4).

AsterixDB "wholly embraced" LSM trees: every index is a mutable *in-memory
component* plus immutable *disk components*; flush on memory threshold, merge
under a policy; recovery uses LSM-index-level **logical logging** (no-steal/
no-force WAL, one log record per index update) plus **component shadowing**
(a new component becomes real only when its *validity bit* is set — invalid
components are deleted at recovery).

This module is the host-side framework: it "LSM-ifies" a sorted-array index
(our B+-tree stand-in: binary search over sorted keys).  It backs the
partitioned storage engine (storage/) and the same component/validity/merge
calculus is reused device-side by the LSM-tiered KV cache (kvcache/) and by
the checkpoint manager (checkpoint/).

Because components are immutable, each one carries a lazily-filled
``col_cache`` of shredded columns (columnar/batch.Column keyed by field
name): the columnar engine (columnar/, used by storage/dataset
``scan_partition_batch``) shreds a component's records at most once per
column, and flush/merge naturally invalidate by creating new components.
"""

from __future__ import annotations

import bisect
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Component", "LSMIndex", "TieredMergePolicy", "WALRecord",
           "TOMBSTONE", "recover"]


class _Tombstone:
    def __repr__(self) -> str:
        return "<TOMBSTONE>"


TOMBSTONE = _Tombstone()

_component_ids = itertools.count()


def _obj_array(items: Sequence[Any]) -> np.ndarray:
    """1-D object array even for uniform tuples (np.asarray would build a
    2-D array out of a list of equal-length tuples, breaking searchsorted)."""
    arr = np.empty(len(items), dtype=object)
    for i, x in enumerate(items):
        arr[i] = x
    return arr


@dataclass
class Component:
    """An immutable sorted run.  ``valid`` is the paper's validity bit: set
    atomically as the final action of the flush/merge that created it."""

    keys: np.ndarray                 # sorted
    rows: np.ndarray                 # object array of dict | TOMBSTONE
    valid: bool = False
    comp_id: int = field(default_factory=lambda: next(_component_ids))
    # columnar engine's per-component shredded columns (name -> Column);
    # immutability makes this cache trivially coherent
    col_cache: Dict[str, Any] = field(default_factory=dict, repr=False)

    @property
    def size(self) -> int:
        return int(self.keys.shape[0])

    @property
    def key_range(self) -> Tuple[Any, Any]:
        return (self.keys[0], self.keys[-1]) if self.size else (None, None)

    def lookup(self, key: Any) -> Optional[Any]:
        # bisect (not np.searchsorted): tuple keys must stay scalar probes
        i = bisect.bisect_left(self.keys, key)
        if i < self.size and self.keys[i] == key:
            return self.rows[i]
        return None

    def range(self, lo: Any, hi: Any) -> Tuple[np.ndarray, np.ndarray]:
        i = bisect.bisect_left(self.keys, lo)
        j = bisect.bisect_right(self.keys, hi)
        return self.keys[i:j], self.rows[i:j]


@dataclass(frozen=True)
class WALRecord:
    """One *logical* log record per index update (paper §4.4)."""

    lsn: int
    op: str          # "insert" | "delete"
    key: Any
    row: Any = None


@dataclass(frozen=True)
class TieredMergePolicy:
    """Merge when >= ``k`` components sit within ``ratio`` of each other in
    size (a standard tiered/size-ratio policy; AsterixDB ships constant +
    prefix policies — tiered subsumes the behavior we benchmark)."""

    k: int = 4
    ratio: float = 1.5

    def pick(self, comps: Sequence[Component]) -> Optional[List[int]]:
        if len(comps) < self.k:
            return None
        # components ordered newest->oldest; scan windows of k
        for start in range(0, len(comps) - self.k + 1):
            window = comps[start:start + self.k]
            sizes = [max(c.size, 1) for c in window]
            if max(sizes) <= self.ratio * min(sizes):
                return list(range(start, start + self.k))
        if len(comps) >= 2 * self.k:   # backstop: merge everything old
            return list(range(len(comps) - self.k, len(comps)))
        return None


class LSMIndex:
    """LSM-ified ordered index: dict memtable + sorted-run components."""

    def __init__(self, flush_threshold: int = 1024,
                 merge_policy: Optional[TieredMergePolicy] = None,
                 wal: Optional[List[WALRecord]] = None):
        self.flush_threshold = int(flush_threshold)
        self.merge_policy = merge_policy or TieredMergePolicy()
        self.memtable: Dict[Any, Any] = {}
        self.components: List[Component] = []   # newest first
        self.wal: List[WALRecord] = wal if wal is not None else []
        self._lsn = itertools.count(len(self.wal))
        self.stats = {"flushes": 0, "merges": 0, "inserts": 0, "deletes": 0,
                      "merged_rows": 0}

    # -- update path (record-level "transactions": WAL then apply) ---------
    def insert(self, key: Any, row: Any) -> None:
        self.wal.append(WALRecord(next(self._lsn), "insert", key, row))
        self.memtable[key] = row
        self.stats["inserts"] += 1
        if len(self.memtable) >= self.flush_threshold:
            self.flush()

    def delete(self, key: Any) -> None:
        self.wal.append(WALRecord(next(self._lsn), "delete", key))
        self.memtable[key] = TOMBSTONE
        self.stats["deletes"] += 1
        if len(self.memtable) >= self.flush_threshold:
            self.flush()

    def insert_batch(self, keys: Sequence[Any], rows: Sequence[Any]) -> None:
        """Paper Table 4: batching amortizes per-statement overhead."""
        for k, r in zip(keys, rows):
            self.insert(k, r)

    # -- flush / merge ------------------------------------------------------
    def flush(self, *, crash_before_validity: bool = False) -> Optional[Component]:
        """Shadow-install the memtable as a new immutable component.  With
        ``crash_before_validity`` the validity bit is never set, simulating a
        crash mid-flush: recovery must ignore the component (paper §4.4)."""
        if not self.memtable:
            return None
        keys = sorted(self.memtable)
        comp = Component(
            keys=_obj_array(keys),
            rows=_obj_array([self.memtable[k] for k in keys]))
        self.components.insert(0, comp)        # shadow: present but invalid
        if crash_before_validity:
            return comp
        comp.valid = True                      # atomic install
        self.memtable = {}
        self.stats["flushes"] += 1
        self._maybe_merge()
        return comp

    def _maybe_merge(self) -> None:
        while True:
            valid = [c for c in self.components if c.valid]
            pick = self.merge_policy.pick(valid)
            if pick is None:
                return
            self.merge([valid[i] for i in pick])

    def merge(self, comps: Sequence[Component],
              *, crash_before_validity: bool = False) -> Component:
        """k-way merge: newest component wins per key; tombstones survive the
        merge unless it includes the oldest component (then they collapse)."""
        includes_oldest = self.components and comps[-1] is [
            c for c in self.components if c.valid][-1]
        merged: Dict[Any, Any] = {}
        for c in reversed(list(comps)):        # oldest first; newer overwrite
            for k, r in zip(c.keys, c.rows):
                merged[k] = r
        if includes_oldest:
            merged = {k: r for k, r in merged.items() if r is not TOMBSTONE}
        keys = sorted(merged)
        out = Component(
            keys=_obj_array(keys),
            rows=_obj_array([merged[k] for k in keys]))
        ids = {c.comp_id for c in comps}
        pos = min(i for i, c in enumerate(self.components) if c.comp_id in ids)
        self.components.insert(pos + 0, out)   # shadow next to its inputs
        if crash_before_validity:
            return out
        out.valid = True                       # atomic swap: install + retire
        self.components = [c for c in self.components
                           if c.comp_id not in ids]
        self.stats["merges"] += 1
        self.stats["merged_rows"] += out.size
        return out

    # -- read path ----------------------------------------------------------
    def lookup(self, key: Any) -> Optional[Any]:
        if key in self.memtable:
            r = self.memtable[key]
            return None if r is TOMBSTONE else r
        for c in self.components:
            if not c.valid:
                continue
            r = c.lookup(key)
            if r is not None:
                return None if r is TOMBSTONE else r
        return None

    def range(self, lo: Any, hi: Any) -> List[Tuple[Any, Any]]:
        """Merged range scan across memtable + all valid components."""
        seen = self._range_merged(lo, hi)
        return [(k, seen[k]) for k in sorted(seen) if seen[k] is not TOMBSTONE]

    def range_values(self, lo: Any, hi: Any) -> List[Any]:
        """Live row values in [lo, hi], newest-wins, without sorting by key
        or materializing (key, row) pairs.  This is the candidate read path
        for secondary indexes, whose rows are primary keys: the caller gets
        a flat PK list to sort/intersect columnar-side (vectorized index
        access), never decoded records."""
        seen = self._range_merged(lo, hi)
        return [r for r in seen.values() if r is not TOMBSTONE]

    def _range_merged(self, lo: Any, hi: Any) -> Dict[Any, Any]:
        seen: Dict[Any, Any] = {}
        for c in reversed([c for c in self.components if c.valid]):
            ks, rs = c.range(lo, hi)
            for k, r in zip(ks, rs):
                seen[k] = r
        for k, r in self.memtable.items():
            if lo <= k <= hi:
                seen[k] = r
        return seen

    def __len__(self) -> int:
        return sum(1 for _ in self.items())

    def items(self) -> Iterator[Tuple[Any, Any]]:
        seen: Dict[Any, Any] = {}
        for c in reversed([c for c in self.components if c.valid]):
            for k, r in zip(c.keys, c.rows):
                seen[k] = r
        seen.update(self.memtable)
        for k in sorted(seen):
            if seen[k] is not TOMBSTONE:
                yield k, seen[k]


def recover(components: Sequence[Component], wal: Sequence[WALRecord],
            *, replay_from_lsn: int = 0, flush_threshold: int = 1024) -> LSMIndex:
    """Crash recovery (paper §4.4): drop components without the validity bit,
    then replay the committed WAL tail into a fresh memtable."""
    idx = LSMIndex(flush_threshold=flush_threshold)
    idx.components = [c for c in components if c.valid]
    idx.wal = list(wal)
    idx._lsn = itertools.count(len(idx.wal))
    for rec in wal:
        if rec.lsn < replay_from_lsn:
            continue
        if rec.op == "insert":
            idx.memtable[rec.key] = rec.row
        elif rec.op == "delete":
            idx.memtable[rec.key] = TOMBSTONE
    return idx
