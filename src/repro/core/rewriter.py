"""Rule-based plan rewriter (paper §4.2 Algebricks, §5.1 "safe rules").

The paper: "it has a set of fairly sophisticated but 'safe' rules to determine
the general shape of a physical query plan and its parallelization and data
movement.  The optimizer keeps track of data partitioning and only moves data
as changes in parallelism or partitioning require.  (a) AsterixDB always
chooses index-based access for selections if an index is available and (b) it
always chooses parallel hash-joins for equijoins", with hints to override.

Implemented rules (applied in order, single pass — the rule set is confluent
by construction like Algebricks' rule collections):

  R1 select-pushdown        push SELECT below JOIN when one-sided
  R2 index-access-path      SELECT(sargable) over SCAN -> secondary-index
                            search + SORT(pk) + primary lookup + POST-VALIDATE
                            (Figure 6's plan, incl. the post-validation select
                            required by LSM secondary-index consistency §4.4).
                            Fuzzy selects (edit-distance / Jaccard specs) take
                            the ngram variant: NGRAM_INDEX_SEARCH ->
                            T_OCCURRENCE -> the same SORT/LOOKUP/VALIDATE tail
  R3 join-method            equijoin -> HYBRID_HASH_JOIN with hash-partition
                            connectors; hint "indexnl" -> INDEX_NL_JOIN
  R4 agg-split              AGG -> LOCAL_AGG ->ReplicateToOne-> GLOBAL_AGG
                            GROUPBY -> LOCAL_PREAGG ->HashPartition(keys)->
                            GLOBAL_GROUP  (Figure 6's local/global split)
  R5 limit-into-sort        ORDERBY+LIMIT -> per-partition TOPK + merge.
                            *Beyond paper*: §5.3.2 notes "AsterixDB does not
                            push limits into sort operations yet"; we do,
                            guarded by `push_limit_into_sort` (default on).
  R6 exchange-insertion     insert the minimal Connector wherever required
                            partitioning != delivered partitioning
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .algebra import (
    Connector, LogicalOp, MToNHashPartition, MToNHashPartitionMerge,
    MToNReplicate, ONE_TO_ONE, Partitioning, PhysicalOp, RANDOM,
    ReplicateToOne, SINGLETON, hash_partitioned, ReplicateToOne,
)

__all__ = ["Catalog", "IndexInfo", "RewriteConfig", "optimize", "explain"]


@dataclass(frozen=True)
class IndexInfo:
    name: str
    dataset: str
    field: str
    kind: str = "btree"   # btree | rtree | keyword | ngram
    gram_length: int = 3  # ngram(k) only: the k the postings were built with


@dataclass
class Catalog:
    """What the optimizer knows: datasets, their primary keys, partition
    counts, and secondary indexes (paper §2.2)."""

    primary_keys: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    indexes: List[IndexInfo] = field(default_factory=list)
    num_partitions: int = 1

    def index_on(self, dataset: str, fld: str) -> Optional[IndexInfo]:
        for ix in self.indexes:
            if ix.dataset == dataset and ix.field == fld:
                return ix
        return None


@dataclass(frozen=True)
class RewriteConfig:
    use_indexes: bool = True            # paper rule (a)
    hash_join: bool = True              # paper rule (b)
    push_limit_into_sort: bool = True   # beyond-paper (paper §5.3.2 lacks it)
    split_aggregation: bool = True      # Figure 6 local/global split


# ---------------------------------------------------------------------------
# R1: select pushdown through joins
# ---------------------------------------------------------------------------

def _r1_select_pushdown(op: LogicalOp) -> LogicalOp:
    op = op.replace_children([_r1_select_pushdown(c) for c in op.children]) \
        if op.children else op
    if op.kind == "SELECT" and op.children[0].kind == "JOIN":
        jn = op.children[0]
        fields = set(op.attrs["fields"])
        lcols = _visible_columns(jn.children[0])
        rcols = _visible_columns(jn.children[1])
        if lcols is not None and fields <= lcols:
            newl = LogicalOp("SELECT", (jn.children[0],), dict(op.attrs))
            return jn.replace_children([newl, jn.children[1]])
        if rcols is not None and fields <= rcols:
            newr = LogicalOp("SELECT", (jn.children[1],), dict(op.attrs))
            return jn.replace_children([jn.children[0], newr])
    return op


def _visible_columns(op: LogicalOp) -> Optional[set]:
    if op.kind == "SCAN":
        return set(op.attrs.get("columns", ())) or None
    if op.kind == "PROJECT":
        return set(op.attrs["cols"])
    if op.kind in ("SELECT",):
        return _visible_columns(op.children[0])
    return None


# ---------------------------------------------------------------------------
# Logical -> physical translation with R2-R5 inline
# ---------------------------------------------------------------------------

def _to_physical(op: LogicalOp, cat: Catalog, cfg: RewriteConfig) -> PhysicalOp:
    k = op.kind

    if k == "SCAN":
        ds = op.attrs["dataset"]
        pk = cat.primary_keys.get(ds, ())
        return PhysicalOp("DATASET_SCAN", (), (), dict(op.attrs),
                          hash_partitioned(*pk, local_order=pk))

    if k == "SELECT":
        child_l = op.children[0]
        hints = op.attrs.get("hints", ())
        # R2: index access path — paper: ALWAYS take the index when available,
        # unless hinted off ("skip-index" is AsterixDB's real hint name).
        if (cfg.use_indexes and "skip-index" not in hints
                and child_l.kind == "SCAN"):
            ds = child_l.attrs["dataset"]
            pk = cat.primary_keys.get(ds, ())
            # fuzzy (ngram rule): whole-field similarity predicates lower
            # to the Figure-6 skeleton with a T-occurrence filter between
            # the gram search and the PK sort:
            #   NGRAM_INDEX_SEARCH -> T_OCCURRENCE -> SORT_PK ->
            #   PRIMARY_INDEX_LOOKUP -> POST_VALIDATE_SELECT (verify)
            fz = op.attrs.get("fuzzy")
            if fz is not None:
                ix = cat.index_on(ds, fz[0])
                if ix is not None and ix.kind == "ngram":
                    sec = PhysicalOp(
                        "NGRAM_INDEX_SEARCH", (), (),
                        {"index": ix.name, "dataset": ds, "field": fz[0],
                         "spec": fz, "gram_length": ix.gram_length},
                        hash_partitioned(*pk))
                    tocc = PhysicalOp(
                        "T_OCCURRENCE", (sec,), (ONE_TO_ONE,),
                        {"spec": fz, "gram_length": ix.gram_length},
                        sec.delivered)
                    sort = PhysicalOp("SORT_PK", (tocc,), (ONE_TO_ONE,),
                                      {"keys": pk},
                                      hash_partitioned(*pk, local_order=pk))
                    lookup = PhysicalOp(
                        "PRIMARY_INDEX_LOOKUP", (sort,), (ONE_TO_ONE,),
                        {"dataset": ds},
                        hash_partitioned(*pk, local_order=pk))
                    return PhysicalOp(
                        "POST_VALIDATE_SELECT", (lookup,), (ONE_TO_ONE,),
                        {"pred": op.attrs["pred"],
                         "fields": op.attrs["fields"],
                         "ranges": op.attrs.get("ranges", {}),
                         "ranges_exact": bool(op.attrs.get("ranges_exact",
                                                           False)),
                         "fuzzy": fz, "gram_length": ix.gram_length},
                        lookup.delivered)
            # rtree (paper Q5) and keyword (paper Q6) access paths share the
            # Figure-6 skeleton: index search -> SORT_PK -> primary lookup
            # -> post-validate.
            for attr_name, op_kind in (("spatial", "SPATIAL_INDEX_SEARCH"),
                                       ("keyword", "KEYWORD_INDEX_SEARCH")):
                spec = op.attrs.get(attr_name)
                if spec is None:
                    continue
                ix = cat.index_on(ds, spec[0])
                if ix is None or ix.kind != {"spatial": "rtree",
                                             "keyword": "keyword"}[attr_name]:
                    continue
                sec = PhysicalOp(op_kind, (), (),
                                 {"index": ix.name, "dataset": ds,
                                  "field": spec[0], "args": spec[1:]},
                                 hash_partitioned(*pk))
                sort = PhysicalOp("SORT_PK", (sec,), (ONE_TO_ONE,),
                                  {"keys": pk},
                                  hash_partitioned(*pk, local_order=pk))
                lookup = PhysicalOp(
                    "PRIMARY_INDEX_LOOKUP", (sort,), (ONE_TO_ONE,),
                    {"dataset": ds},
                    hash_partitioned(*pk, local_order=pk))
                return PhysicalOp(
                    "POST_VALIDATE_SELECT", (lookup,), (ONE_TO_ONE,),
                    {"pred": op.attrs["pred"], "fields": op.attrs["fields"],
                     "ranges": op.attrs.get("ranges", {}),
                     "ranges_exact": bool(op.attrs.get("ranges_exact",
                                                       False))},
                    lookup.delivered)
        if (cfg.use_indexes and "skip-index" not in hints
                and child_l.kind == "SCAN" and op.attrs.get("ranges")):
            ds = child_l.attrs["dataset"]
            for fld, (lo, hi) in op.attrs["ranges"].items():
                ix = cat.index_on(ds, fld)
                if ix is not None and ix.kind == "btree":
                    pk = cat.primary_keys.get(ds, ())
                    sec = PhysicalOp(
                        "SECONDARY_INDEX_SEARCH", (), (),
                        {"index": ix.name, "dataset": ds, "field": fld,
                         "lo": lo, "hi": hi},
                        hash_partitioned(*pk))
                    sort = PhysicalOp("SORT_PK", (sec,), (ONE_TO_ONE,),
                                      {"keys": pk},
                                      hash_partitioned(*pk, local_order=pk))
                    lookup = PhysicalOp(
                        "PRIMARY_INDEX_LOOKUP", (sort,), (ONE_TO_ONE,),
                        {"dataset": ds},
                        hash_partitioned(*pk, local_order=pk))
                    # §4.4: secondary lookups are post-validated against the
                    # primary record under proper locks (Figure 6's extra
                    # select) — without this, concurrently-merged LSM
                    # components could surface stale entries.
                    return PhysicalOp(
                        "POST_VALIDATE_SELECT", (lookup,), (ONE_TO_ONE,),
                        {"pred": op.attrs["pred"], "fields": op.attrs["fields"],
                         "ranges": op.attrs["ranges"],
                         "ranges_exact": bool(op.attrs.get("ranges_exact",
                                                           False))},
                        lookup.delivered)
        child = _to_physical(child_l, cat, cfg)
        return PhysicalOp("STREAM_SELECT", (child,), (ONE_TO_ONE,),
                          dict(op.attrs), child.delivered)

    if k == "PROJECT":
        child = _to_physical(op.children[0], cat, cfg)
        return PhysicalOp("STREAM_PROJECT", (child,), (ONE_TO_ONE,),
                          dict(op.attrs), child.delivered)

    if k == "JOIN":
        left = _to_physical(op.children[0], cat, cfg)
        right = _to_physical(op.children[1], cat, cfg)
        lk, rk = op.attrs["lkeys"], op.attrs["rkeys"]
        hints = op.attrs.get("hints", ())
        if "indexnl" in hints and op.children[1].kind == "SCAN":
            # paper Query 14: index nested-loop join hint — probe the right
            # side's primary index per left row (right side must be a base
            # dataset scan; otherwise fall through to the hash join).
            rds = op.children[1].attrs["dataset"]
            if tuple(rk) == tuple(cat.primary_keys.get(rds, ())):
                return PhysicalOp(
                    "INDEX_NL_JOIN",
                    (left,),
                    (_exchange(left.delivered, hash_partitioned(*lk)),),
                    {**op.attrs, "right_dataset": rds},
                    hash_partitioned(*lk))
        # R3 + R6: hybrid hash join; repartition each side iff needed
        lconn = _exchange(left.delivered, hash_partitioned(*lk))
        rconn = _exchange(right.delivered, hash_partitioned(*rk))
        return PhysicalOp("HYBRID_HASH_JOIN", (left, right), (lconn, rconn),
                          dict(op.attrs), hash_partitioned(*lk))

    if k == "AGG":
        child = _to_physical(op.children[0], cat, cfg)
        if not cfg.split_aggregation:
            return PhysicalOp("GLOBAL_AGG", (child,), (ReplicateToOne(),),
                              dict(op.attrs), SINGLETON)
        # R4 (Figure 6): local agg on each partition, replicate to the one
        # global instance, combine.
        local = PhysicalOp("LOCAL_AGG", (child,), (ONE_TO_ONE,),
                           dict(op.attrs), child.delivered)
        return PhysicalOp("GLOBAL_AGG", (local,), (ReplicateToOne(),),
                          dict(op.attrs), SINGLETON)

    if k == "GROUPBY":
        child = _to_physical(op.children[0], cat, cfg)
        keys = op.attrs["keys"]
        if not cfg.split_aggregation:
            conn = _exchange(child.delivered, hash_partitioned(*keys))
            return PhysicalOp("HASH_GROUP", (child,), (conn,), dict(op.attrs),
                              hash_partitioned(*keys))
        local = PhysicalOp("LOCAL_PREAGG", (child,), (ONE_TO_ONE,),
                           dict(op.attrs), child.delivered)
        conn = _exchange(local.delivered, hash_partitioned(*keys))
        return PhysicalOp("GLOBAL_GROUP", (local,), (conn,), dict(op.attrs),
                          hash_partitioned(*keys))

    if k == "ORDERBY":
        child = _to_physical(op.children[0], cat, cfg)
        local = PhysicalOp("LOCAL_SORT", (child,), (ONE_TO_ONE,),
                           dict(op.attrs),
                           Partitioning(child.delivered.kind,
                                        child.delivered.keys,
                                        tuple(op.attrs["keys"])))
        return PhysicalOp("SORT_MERGE_GATHER", (local,), (ReplicateToOne(),),
                          dict(op.attrs), SINGLETON)

    if k == "LIMIT":
        child_l = op.children[0]
        # R5: fuse LIMIT into the sort as a per-partition TopK (beyond-paper)
        if cfg.push_limit_into_sort and child_l.kind == "ORDERBY":
            inner = _to_physical(child_l.children[0], cat, cfg)
            attrs = {**child_l.attrs, "n": op.attrs["n"]}
            topk = PhysicalOp("LOCAL_TOPK", (inner,), (ONE_TO_ONE,), attrs,
                              inner.delivered)
            return PhysicalOp("TOPK_MERGE", (topk,), (ReplicateToOne(),),
                              attrs, SINGLETON)
        child = _to_physical(child_l, cat, cfg)
        return PhysicalOp("STREAM_LIMIT", (child,), (ONE_TO_ONE,),
                          dict(op.attrs), child.delivered)

    raise ValueError(f"unknown logical operator {k}")


def _exchange(delivered: Partitioning, required: Partitioning) -> Connector:
    """R6: the minimal connector turning `delivered` into `required`."""
    if delivered.satisfies(required):
        return ONE_TO_ONE
    if required.kind == "hash":
        if required.local_order:
            return MToNHashPartitionMerge(required.keys, required.local_order)
        return MToNHashPartition(*required.keys)
    if required.kind == "broadcast":
        return MToNReplicate()
    if required.kind == "singleton":
        return ReplicateToOne()
    return ONE_TO_ONE


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def optimize(plan: LogicalOp, catalog: Catalog,
             config: RewriteConfig = RewriteConfig()) -> PhysicalOp:
    plan = _r1_select_pushdown(plan)
    return _to_physical(plan, catalog, config)


def explain(plan: LogicalOp, catalog: Catalog,
            config: RewriteConfig = RewriteConfig()) -> str:
    phys = optimize(plan, catalog, config)
    return phys.pretty()
