"""Fuzzy joins: Jaccard set-similarity self-join (paper Q13) as a partitioned
MinHash-LSH pipeline, used for near-duplicate detection of training docs.

The paper supports "ad hoc parallel fuzzy joins as well as indexed fuzzy
joins" [23].  We implement the parallel form:

  1. per record: token set -> MinHash signature (k hashes);
  2. LSH banding: records sharing any band hash land in the same bucket —
     this is the MToNHashPartition exchange keyed on band hashes, i.e. the
     candidate-pair generation is a *hash repartition*, exactly the
     paper's parallel set-similarity join skeleton;
  3. verify: exact Jaccard within each bucket, batched — candidate pairs'
     token sets are dictionary-coded and scored by the vectorized set-
     intersection kernel in one pass (post-validation — the same
     validate-after-index discipline as §4.4; ``batch_verify=False``
     keeps the per-pair python loop addressable for benchmarking).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, Iterable, List, Sequence, Set, Tuple

import numpy as np

__all__ = ["minhash_signature", "jaccard", "FuzzyJoin"]

_MERSENNE = (1 << 61) - 1


def _hash_family(k: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    a = rng.integers(1, _MERSENNE, k, dtype=np.int64)
    b = rng.integers(0, _MERSENNE, k, dtype=np.int64)
    return a, b


def _token_hash(tok: str) -> int:
    """Scalar FNV-1a-64 mod the Mersenne prime (the oracle the vectorized
    ``kernels.fuzzy_ops.fnv1a_hash`` path must match bit-for-bit)."""
    h = 14695981039346656037
    for byte in tok.encode():
        h = ((h ^ byte) * 1099511628211) % (1 << 64)
    return h % _MERSENNE


def _token_hashes(tokens: Sequence[str]) -> np.ndarray:
    """Vectorized token hashing: one numpy FNV pass over a padded byte
    matrix (shared with the ngram index's gram hashing) instead of the
    per-token python byte loop."""
    from ..kernels.fuzzy_ops import fnv1a_hash
    h = fnv1a_hash(tokens) % np.uint64(_MERSENNE)
    return h.astype(np.int64)


def minhash_signature(tokens: Iterable[str], k: int = 32, seed: int = 0
                      ) -> np.ndarray:
    a, b = _hash_family(k, seed)
    hs = _token_hashes(sorted(set(tokens)))
    if hs.size == 0:
        return np.full(k, _MERSENNE, dtype=np.int64)
    # (a*h + b) mod p for all k functions x all tokens
    vals = (a[:, None] * hs[None, :] + b[:, None]) % _MERSENNE
    return vals.min(axis=1)


def jaccard(s1: Set[str], s2: Set[str]) -> float:
    if not s1 and not s2:
        return 1.0
    return len(s1 & s2) / len(s1 | s2)


@dataclass
class FuzzyJoin:
    """Self-join: find all pairs with Jaccard(tokens) >= threshold."""

    threshold: float = 0.3
    num_hashes: int = 32
    bands: int = 8
    seed: int = 0
    batch_verify: bool = True   # False: legacy per-pair python verify

    def __post_init__(self):
        assert self.num_hashes % self.bands == 0
        self.rows_per_band = self.num_hashes // self.bands

    def band_keys(self, sig: np.ndarray) -> List[Tuple[int, int]]:
        r = self.rows_per_band
        return [(bi, hash(tuple(sig[bi * r:(bi + 1) * r].tolist())))
                for bi in range(self.bands)]

    def verify(self, candidates: Sequence[Tuple[Any, Any]],
               toks: Dict[Any, Set[str]]) -> List[Tuple[Any, Any, float]]:
        """Stage 3 (post-validation): exact Jaccard over the candidate
        pairs.  Batched by default — one shared token dictionary, one
        vectorized set-intersection pass over every pair (fuzzy/verify) —
        with the per-pair python loop kept for comparison."""
        candidates = list(candidates)
        if self.batch_verify:
            from ..fuzzy.verify import jaccard_pair_sims
            sims = jaccard_pair_sims(candidates, toks)
            return [(a, b, float(j))
                    for (a, b), j in zip(candidates, sims.tolist())
                    if j >= self.threshold]
        pairs = []
        for a, b in candidates:
            j = jaccard(toks[a], toks[b])
            if j >= self.threshold:
                pairs.append((a, b, j))
        return pairs

    def run(self, records: Sequence[Tuple[Any, Set[str]]],
            num_partitions: int = 4
            ) -> Tuple[List[Tuple[Any, Any, float]], Dict[str, int]]:
        """records: (id, token_set).  Returns (pairs, stats)."""
        sigs = {rid: minhash_signature(toks, self.num_hashes, self.seed)
                for rid, toks in records}
        toks = dict(records)
        # stage 2: hash repartition on band keys (candidate generation)
        buckets: Dict[Tuple[int, int], List[Any]] = {}
        for rid, sig in sigs.items():
            for key in self.band_keys(sig):
                buckets.setdefault(key, []).append(rid)
        candidates: Set[Tuple[Any, Any]] = set()
        for key, rids in buckets.items():
            for a, b in itertools.combinations(sorted(rids, key=str), 2):
                candidates.add((a, b))
        # stage 3: verify (post-validation), batched by default
        pairs = self.verify(sorted(candidates, key=str), toks)
        stats = {"records": len(records), "buckets": len(buckets),
                 "candidates": len(candidates), "pairs": len(pairs)}
        return pairs, stats

    def brute_force(self, records: Sequence[Tuple[Any, Set[str]]]
                    ) -> List[Tuple[Any, Any, float]]:
        """Oracle for tests (recall measurement)."""
        out = []
        for (a, ta), (b, tb) in itertools.combinations(records, 2):
            j = jaccard(ta, tb)
            if j >= self.threshold:
                key = (a, b) if str(a) <= str(b) else (b, a)
                out.append((key[0], key[1], j))
        return out
