"""Data feeds — continuous ingestion pipelines (paper §2.4, §4.5).

The paper's feed = intake -> compute (UDF) -> store stages with *feed joints*
(buffered taps with a subscription mechanism) so cascading feeds share one
upstream.  Adapted to the training substrate:

  intake   — an adaptor pulls records from a source (socket/file/synthetic
             token stream); primary feeds own an adaptor, secondary feeds
             subscribe to a joint of another feed.
  compute  — per-record UDFs (tokenize/pack/augment), applied in order.
  store    — terminal sink: a ``DatasetSink`` accumulating per-dataset
             micro-batches delivered via ``PartitionedDataset
             .insert_batch`` (the BDMS path: batches flow into memory
             components and flush columnar, never touching a per-record
             code path) or a device-batch assembler for the trainer
             (the LM path).

Fault tolerance (paper [15]): every joint keeps a monotone *cursor* (records
emitted) and a bounded replay buffer; a cursor is checkpointed with the model
so training resumes deterministically mid-stream.  Straggler mitigation:
``RedundantIntake`` races two adaptors and keeps the first answer per batch
(speculative retry at the data layer).
"""

from __future__ import annotations

import collections
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from .. import obs as _obs

__all__ = ["Adaptor", "SyntheticTokenAdaptor", "FileAdaptor", "SocketAdaptor",
           "FeedJoint", "FeedOverflow", "Feed", "RedundantIntake",
           "BatchAssembler", "DatasetSink"]


class FeedOverflow(RuntimeError):
    """Raised by ``FeedJoint.publish`` under the ``overflow='raise'``
    policy when buffering the new records would evict records a live
    subscriber has not consumed yet.  The joint is left unchanged, so
    the publisher can apply backpressure and retry after consumers
    catch up."""


# ---------------------------------------------------------------------------
# Adaptors (paper: socket_adaptor + built-ins + custom)
# ---------------------------------------------------------------------------

class Adaptor:
    """Pull-based record source.  next_batch(n) returns < n records only at
    end-of-stream."""

    def next_batch(self, n: int) -> List[Any]:  # pragma: no cover - interface
        raise NotImplementedError

    def seek(self, cursor: int) -> None:
        """Reposition to absolute record offset (deterministic replay)."""
        raise NotImplementedError


class SyntheticTokenAdaptor(Adaptor):
    """Deterministic synthetic LM token stream: record = dict with tokens /
    labels (next-token shift), seeded per document id so any cursor is
    reproducible without state."""

    def __init__(self, seq_len: int, vocab_size: int, seed: int = 0):
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.seed = seed
        self.cursor = 0

    def _record(self, i: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ i)
        toks = rng.integers(0, self.vocab_size, self.seq_len + 1,
                            dtype=np.int32)
        return {"doc_id": i, "tokens": toks[:-1], "labels": toks[1:]}

    def next_batch(self, n: int) -> List[Any]:
        out = [self._record(self.cursor + j) for j in range(n)]
        self.cursor += n
        return out

    def seek(self, cursor: int) -> None:
        self.cursor = cursor


class FileAdaptor(Adaptor):
    """Local-file adaptor (paper Data definition 3): one record per line,
    parsed by ``parse`` (e.g. the CSV web-log schema of Figure 3)."""

    def __init__(self, path: str, parse: Callable[[str], Any]):
        self.lines = open(path).read().splitlines()
        self.parse = parse
        self.cursor = 0

    def next_batch(self, n: int) -> List[Any]:
        out = [self.parse(l) for l in
               self.lines[self.cursor:self.cursor + n]]
        self.cursor += len(out)
        return out

    def seek(self, cursor: int) -> None:
        self.cursor = cursor


class SocketAdaptor(Adaptor):
    """Push-source stand-in: records are .push()ed by a producer and pulled
    by the feed (models the paper's TCP socket_adaptor without real I/O)."""

    def __init__(self):
        self.queue: collections.deque = collections.deque()
        self.cursor = 0

    def push(self, records: Iterable[Any]) -> None:
        self.queue.extend(records)

    def next_batch(self, n: int) -> List[Any]:
        out = []
        while self.queue and len(out) < n:
            out.append(self.queue.popleft())
        self.cursor += len(out)
        return out

    def seek(self, cursor: int) -> None:  # push sources replay via producer
        self.cursor = cursor


class RedundantIntake(Adaptor):
    """Straggler mitigation: race N equivalent adaptors, first-wins per batch.

    On a real cluster the replicas would be raced over RPC with a timeout;
    here the race is simulated via per-adaptor ``latency`` callables so tests
    can inject stragglers deterministically.  Records must be deterministic
    per cursor (true for seekable adaptors), so whichever replica answers
    first yields identical data — the feed stays exactly-once.
    """

    def __init__(self, adaptors: Sequence[Adaptor],
                 latency: Optional[Callable[[int, int], float]] = None):
        assert adaptors
        self.adaptors = list(adaptors)
        self.latency = latency or (lambda replica, batch: 0.0)
        self.cursor = 0
        self.stats = {"wins": [0] * len(adaptors)}

    def next_batch(self, n: int) -> List[Any]:
        lat = [self.latency(i, self.cursor) for i in range(len(self.adaptors))]
        winner = int(np.argmin(lat))
        self.stats["wins"][winner] += 1
        ad = self.adaptors[winner]
        ad.seek(self.cursor)
        out = ad.next_batch(n)
        self.cursor += len(out)
        return out

    def seek(self, cursor: int) -> None:
        self.cursor = cursor


# ---------------------------------------------------------------------------
# Feed joints + feeds
# ---------------------------------------------------------------------------

class FeedJoint:
    """A tap on a feed's dataflow: buffers records and lets any number of
    subscribers consume at their own pace (bounded replay window).

    ``overflow`` selects what happens when a publish would push records a
    live subscriber has not consumed yet out of the window:

    * ``"drop"`` (default) — evict them anyway but count every unconsumed
      record lost in the ``feed.joint.<name>.dropped`` obs counter; the
      lagging subscriber's next ``consume`` raises as before.
    * ``"raise"`` — refuse the publish with :class:`FeedOverflow` and
      leave the joint untouched, so the publisher can block/retry
      (backpressure — the serving harness uses this).

    Records every subscriber has consumed always retire silently.
    """

    def __init__(self, window: int = 4096, name: Optional[str] = None,
                 overflow: str = "drop"):
        assert overflow in ("drop", "raise"), overflow
        self.window = window
        self.name = name
        self.overflow = overflow
        self.buffer: collections.deque = collections.deque()
        self.base = 0                      # cursor of buffer[0]
        self.subscribers: Dict[str, int] = {}
        self.published = 0
        self.dropped = 0                   # unconsumed records evicted
        self._first_publish_t: Optional[float] = None
        self._last_publish_t: Optional[float] = None
        self._lock = threading.RLock()     # concurrent pump/consume safety

    @property
    def head(self) -> int:
        return self.base + len(self.buffer)

    def rate(self) -> float:
        """Ingest rate in records/sec over the joint's publish lifetime
        (first publish to last publish); 0.0 until two publish instants."""
        if self._first_publish_t is None or self._last_publish_t is None:
            return 0.0
        elapsed = self._last_publish_t - self._first_publish_t
        return self.published / elapsed if elapsed > 0 else 0.0

    def publish(self, records: Sequence[Any]) -> None:
        with self._lock:
            floor = min(self.subscribers.values(), default=self.head)
            if self.overflow == "raise":
                retirable = max(0, floor - self.base)
                if len(self.buffer) - retirable + len(records) > self.window:
                    raise FeedOverflow(
                        f"joint {self.name or 'joint'}: publishing "
                        f"{len(records)} records would evict unconsumed "
                        f"records (floor={floor}, window={self.window})")
            now = time.perf_counter()
            if self._first_publish_t is None:
                self._first_publish_t = now
            self._last_publish_t = now
            self.published += len(records)
            _obs.counter(f"feed.joint.{self.name or 'joint'}.published").inc(
                len(records))
            self.buffer.extend(records)
            # retire records every subscriber has consumed; past the
            # subscriber floor evict only on window overflow, and count
            # each unconsumed record lost
            dropped = 0
            while len(self.buffer) > self.window or self.base < floor:
                if self.base >= floor and len(self.buffer) <= self.window:
                    break
                self.buffer.popleft()
                if self.base >= floor:
                    dropped += 1
                self.base += 1
            if dropped:
                self.dropped += dropped
                _obs.counter(
                    f"feed.joint.{self.name or 'joint'}.dropped").inc(dropped)

    def subscribe(self, name: str, cursor: Optional[int] = None) -> None:
        with self._lock:
            self.subscribers[name] = self.head if cursor is None else cursor

    def consume(self, name: str, n: int) -> List[Any]:
        with self._lock:
            cur = self.subscribers[name]
            if cur < self.base:
                raise RuntimeError(
                    f"subscriber {name} fell behind the replay window "
                    f"({cur} < {self.base}); re-seed from checkpoint")
            start = cur - self.base
            out = list(itertools.islice(self.buffer, start, start + n))
            self.subscribers[name] = cur + len(out)
            _obs.gauge(f"feed.joint.{self.name or 'joint'}.lag.{name}").set(
                self.head - self.subscribers[name])
            return out


@dataclass
class Feed:
    """intake -> compute(UDFs) -> store, with a joint after compute.

    ``store`` is optional: a callable sink (e.g. PartitionedDataset.insert or
    a BatchAssembler).  Secondary feeds pass ``source_joint`` instead of an
    adaptor (paper §2.4 'Secondary Feeds ... fed from other feeds')."""

    name: str
    adaptor: Optional[Adaptor] = None
    udfs: List[Callable[[Any], Any]] = field(default_factory=list)
    store: Optional[Callable[[Sequence[Any]], None]] = None
    source_joint: Optional[FeedJoint] = None
    joint: FeedJoint = field(default_factory=FeedJoint)
    cursor: int = 0            # records *taken in* from the source
    last_intake: int = 0       # intake size of the most recent pump

    def __post_init__(self):
        assert (self.adaptor is None) != (self.source_joint is None), \
            "exactly one of adaptor / source_joint"
        if self.joint.name is None:
            self.joint.name = self.name
        if self.source_joint is not None:
            self.source_joint.subscribe(self.name)

    def pump(self, n: int) -> int:
        """Run one intake->compute->store cycle of up to n records.
        Returns the *post-filter* record count delivered downstream; the
        checkpoint ``cursor`` advances by the *pre-filter* intake count
        (also exposed as ``last_intake``) so a ``restore()`` seeks the
        adaptor to the true source offset even when UDFs filter records
        — otherwise replay would re-deliver already-processed records."""
        with _obs.span("feed.pump." + self.name) as sp:
            if self.adaptor is not None:
                recs = self.adaptor.next_batch(n)
            else:
                recs = self.source_joint.consume(self.name, n)
            intake = len(recs)
            for udf in self.udfs:
                recs = [udf(r) for r in recs]
                recs = [r for r in recs if r is not None]  # UDFs may filter
            self.joint.publish(recs)
            if self.store is not None:
                self.store(recs)
            self.cursor += intake
            self.last_intake = intake
            sp.set("records", len(recs))
        _obs.counter(f"feed.{self.name}.records").inc(len(recs))
        _obs.histogram(f"feed.{self.name}.batch_records").observe(len(recs))
        return len(recs)

    # -- checkpointable state (exact-resume deliverable) -------------------
    def state(self) -> Dict[str, Any]:
        st = {"name": self.name, "cursor": self.cursor,
              "subscribers": dict(self.joint.subscribers)}
        if self.source_joint is not None:
            # a secondary feed's own consume position lives in the
            # *source* joint's subscriber table, not in self.joint
            st["source_cursor"] = self.source_joint.subscribers[self.name]
        return st

    def restore(self, state: Dict[str, Any]) -> None:
        self.cursor = state["cursor"]
        if self.adaptor is not None:
            self.adaptor.seek(self.cursor)
        self.joint.subscribers.update(state.get("subscribers", {}))
        if self.source_joint is not None and "source_cursor" in state:
            self.source_joint.subscribe(self.name, state["source_cursor"])


class DatasetSink:
    """Store-stage sink for a PartitionedDataset: accumulates records into
    micro-batches and delivers them via ``insert_batch``, so the feed ->
    memory component -> flush pipeline ingests batch-wise end to end
    (paper [15]'s fault-tolerant feeds meet the columnar-native storage:
    a full micro-batch becomes one WAL+memtable pass per partition and
    flushes shred straight into component ColumnBatches).

    ``flush()`` pushes a partial tail batch (call it at end-of-stream or
    before a checkpoint); ``(feed cursor, len(backlog))`` is the
    deterministic ingestion checkpoint, mirroring ``BatchAssembler``.
    """

    def __init__(self, dataset: Any, batch_size: int = 256):
        self.dataset = dataset
        self.batch_size = int(batch_size)
        self.backlog: List[Any] = []
        self.stats = {"batches": 0, "records": 0}
        self._ds_name = getattr(dataset, "name", "dataset")

    def _record_batch(self, n: int) -> None:
        self.stats["batches"] += 1
        self.stats["records"] += n
        _obs.counter(f"feed.sink.{self._ds_name}.records").inc(n)
        _obs.histogram(f"feed.sink.{self._ds_name}.batch_records").observe(n)

    def __call__(self, records: Sequence[Any]) -> None:
        self.backlog.extend(records)
        # drain by index in one pass — re-slicing the backlog per chunk
        # is O(n^2) on large pumps
        pos = 0
        while len(self.backlog) - pos >= self.batch_size:
            chunk = self.backlog[pos:pos + self.batch_size]
            pos += self.batch_size
            self.dataset.insert_batch(chunk)
            self._record_batch(len(chunk))
        if pos:
            del self.backlog[:pos]
        _obs.gauge(f"feed.sink.{self._ds_name}.backlog").set(
            len(self.backlog))

    def flush(self) -> int:
        """Deliver any buffered tail; returns the number of records
        pushed."""
        n = len(self.backlog)
        if n:
            self.dataset.insert_batch(self.backlog)
            self.backlog = []
            self._record_batch(n)
        _obs.gauge(f"feed.sink.{self._ds_name}.backlog").set(0)
        return n


class BatchAssembler:
    """Store-stage sink assembling fixed-size global batches for the trainer.

    Call ``take()`` to pop a [global_batch, seq] numpy batch; returns None
    until enough records buffered.  The (feed cursor, assembler backlog) pair
    is the deterministic data-position checkpoint.
    """

    def __init__(self, global_batch: int):
        self.global_batch = global_batch
        self.backlog: List[Any] = []

    def __call__(self, records: Sequence[Any]) -> None:
        self.backlog.extend(records)

    def take(self) -> Optional[Dict[str, np.ndarray]]:
        if len(self.backlog) < self.global_batch:
            return None
        recs, self.backlog = (self.backlog[:self.global_batch],
                              self.backlog[self.global_batch:])
        return {
            "tokens": np.stack([r["tokens"] for r in recs]),
            "labels": np.stack([r["labels"] for r in recs]),
        }
