"""Fuzzy query subsystem (paper §2.1/§5: "support for fuzzy ... types and
queries", the ngram(k) index kind and T-occurrence candidate generation).

  ngram.py   — GramPostings: per-LSM-component columnar CSR postings
               (sorted gram-hash dictionary + offsets + row positions),
               query planning (gram hashing, T-occurrence thresholds),
               and the scalar oracle predicates
  verify.py  — batched candidate verification: banded edit-distance DP
               and dictionary-coded Jaccard over whole candidate sets

The counting/DP/set-intersection hot paths live in
``kernels/fuzzy_ops.py`` (Pallas on TPU, pow2-padded jitted-jnp x64
elsewhere, same dispatch pattern as ``kernels/columnar_ops.py``).
"""

from .ngram import (GRAM_K, FuzzySpec, GramPostings, fuzzy_predicate,
                    query_grams, spec_gram_length, value_gram_hashes)
from .verify import (encode_token_sets, jaccard_pair_sims, verify_mask,
                     verify_values)

__all__ = ["GRAM_K", "FuzzySpec", "GramPostings", "fuzzy_predicate",
           "query_grams", "spec_gram_length", "value_gram_hashes",
           "encode_token_sets", "jaccard_pair_sims", "verify_mask",
           "verify_values"]
