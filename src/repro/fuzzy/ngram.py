"""ngram(k) postings: the columnar secondary-index structure behind the
fuzzy query paths (the ``"ngram"`` index kind ``core/rewriter`` reserved).

Ngram postings are not an LSMIndex of (key, pk) pairs: each *primary*
component carries a ``GramPostings`` per indexed field, built at
flush/merge alongside the component's ColumnBatch (and from the batch's
string dictionary, not by re-tokenizing rows).  This per-component
derived-columnar-data calculus now covers every secondary kind — the
btree/rtree/keyword structures are the same pattern generalized
(``columnar/postings.FieldPostings``, which also hosts the shared CSR
builders this module uses).  The structure is a columnar CSR:

  grams      sorted distinct uint64 FNV-1a gram hashes
  offsets    int64 [G+1] segment bounds into ``positions``
  positions  int64 component-local row positions, one entry per
             (distinct gram, row) pair
  has_value  bool bitmap: row holds an indexable string at all (the
             T <= 0 fallback candidate set)

Candidate generation is T-occurrence: a query's gram-hit posting
segments concatenate into one position array and a single fused count
kernel (``kernels/fuzzy_ops.t_occurrence_mask``) keeps positions with
>= T hits.  The thresholds are the classic lower bounds, adjusted for
hashing so collisions can only add false positives (verification removes
them), never false negatives:

  edit distance d    T = |H(set G(q))| - k*d      (an edit destroys at
                     most k gram occurrences, hence at most k distinct
                     gram types)
  jaccard >= t       T = ceil(t * |set G(q)|) - (|set G(q)| - |H(...)|)
                     (J >= t implies |A∩B| >= t*|A∪B| >= t*|A|; the
                     subtrahend discounts in-query hash collisions)
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Sequence, Tuple

import numpy as np

from ..columnar.batch import pow2_len
from ..columnar.postings import csr_from_pairs, segment_gather
from ..core.functions import (edit_distance_check, gram_tokens,
                              similarity_jaccard_check)
from ..kernels.fuzzy_ops import fnv1a_hash

__all__ = ["GRAM_K", "GramPostings", "FuzzySpec", "spec_gram_length",
           "value_gram_hashes", "query_grams", "fuzzy_predicate"]

GRAM_K = 3                      # default gram length (AsterixDB's ngram(3))

# (field, kind, target, param[, k]): kind "ed" ->
# edit_distance_check(value, target, param); kind "jaccard" ->
# similarity_jaccard_check over gram_tokens(value, k) vs
# gram_tokens(target, k) at threshold param.  The optional 5th element
# pins the gram length the *predicate* is defined over (default GRAM_K);
# the index's own gram length only shapes the candidate postings.
FuzzySpec = Tuple[str, str, str, Any]


def spec_gram_length(spec: FuzzySpec) -> int:
    """The gram length the spec's predicate semantics are defined over."""
    return int(spec[4]) if len(spec) > 4 else GRAM_K

_EMPTY_U64 = np.zeros(0, dtype=np.uint64)
_EMPTY_I64 = np.zeros(0, dtype=np.int64)


def value_gram_hashes(s: str, k: int) -> np.ndarray:
    """Sorted distinct gram hashes of one string (set semantics: the
    T-occurrence bounds above are stated over distinct grams)."""
    return np.unique(fnv1a_hash(gram_tokens(s, k)))


# CSR segment expansion and assembly are shared with the generalized
# secondary postings (columnar/postings.py): one copy of the pattern for
# ngram, btree, rtree and keyword structures.
_segment_gather = segment_gather


@dataclass
class GramPostings:
    """Per-component columnar CSR gram postings (immutable, like the
    component batch it sits beside)."""

    k: int
    grams: np.ndarray       # sorted distinct uint64 hashes
    offsets: np.ndarray     # int64 [G+1]
    positions: np.ndarray   # int64 row positions, grouped by gram
    has_value: np.ndarray   # bool [n_rows]
    n_rows: int
    # pow2-padded positions view, built once per immutable postings
    # (Column.padded idiom): stable identity == stable device-pool key
    _padded: Any = field(default=None, repr=False, compare=False)

    def padded_positions(self) -> np.ndarray:
        """Pow2-padded positions array, built once (zero fill; padding
        lanes must be masked by the caller's CSR offset bounds).  Stable
        identity makes it a device-pool key for the component lifetime."""
        if self._padded is None:
            n = int(self.positions.shape[0])
            np2 = pow2_len(n)
            if np2 == n and n > 0:
                self._padded = self.positions
            else:
                pad = np.zeros(max(np2, 1), dtype=np.int64)
                pad[:n] = self.positions
                self._padded = pad
        return self._padded

    @classmethod
    def _empty(cls, k: int, has_value: np.ndarray) -> "GramPostings":
        return cls(k, _EMPTY_U64, np.zeros(1, dtype=np.int64), _EMPTY_I64,
                   has_value, int(has_value.shape[0]))

    @classmethod
    def _from_pairs(cls, k: int, all_h: np.ndarray, all_pos: np.ndarray,
                    has_value: np.ndarray) -> "GramPostings":
        n = int(has_value.shape[0])
        if all_h.shape[0] == 0:
            return cls._empty(k, has_value)
        grams, offsets, positions = csr_from_pairs(all_h, all_pos)
        return cls(k, grams, offsets, positions, has_value, n)

    @classmethod
    def from_values(cls, vals: Sequence[Any], k: int) -> "GramPostings":
        """Build from python values (memtable rows, obj-kind columns):
        tokenization runs once per *distinct* string via a host cache;
        CSR assembly is pure numpy."""
        n = len(vals)
        cache: Dict[str, np.ndarray] = {}
        per_row: List[np.ndarray] = []
        has = np.zeros(n, dtype=bool)
        for i, v in enumerate(vals):
            if isinstance(v, str):
                hs = cache.get(v)
                if hs is None:
                    cache[v] = hs = value_gram_hashes(v, k)
                per_row.append(hs)
                has[i] = True
            else:
                per_row.append(_EMPTY_U64)
        counts = np.fromiter((h.shape[0] for h in per_row), np.int64,
                             count=n)
        if n == 0 or counts.sum() == 0:
            return cls._empty(k, has)
        all_h = np.concatenate(per_row)
        all_pos = np.repeat(np.arange(n, dtype=np.int64), counts)
        return cls._from_pairs(k, all_h, all_pos, has)

    @classmethod
    def from_column(cls, col: Any, k: int) -> "GramPostings":
        """Build from a dictionary-coded string column: grams are hashed
        once per dictionary value and expanded to rows by gathering code
        segments — no per-row tokenization."""
        if col.kind != "str":
            return cls.from_values(
                [v if isinstance(v, str) else None for v in col.decode()],
                k)
        n = len(col)
        vals = col.values or []
        per_val = [value_gram_hashes(v, k) for v in vals]
        vcounts = np.fromiter((h.shape[0] for h in per_val), np.int64,
                              count=len(vals))
        voffs = np.zeros(len(vals) + 1, dtype=np.int64)
        np.cumsum(vcounts, out=voffs[1:])
        flat = np.concatenate(per_val) if per_val else _EMPTY_U64
        has = col.valid.copy()
        pos = np.nonzero(col.valid)[0].astype(np.int64)
        if pos.shape[0] == 0:
            return cls._empty(k, has)
        codes = col.data[pos].astype(np.int64)
        counts = vcounts[codes]
        if int(counts.sum()) == 0:
            return cls._empty(k, has)
        return cls._from_pairs(k, _segment_gather(flat, voffs[codes],
                                                  counts),
                               np.repeat(pos, counts), has)

    @classmethod
    def from_batch(cls, batch: Any, fld: str, k: int, n_rows: int
                   ) -> "GramPostings":
        col = batch.columns.get(fld)
        if col is None:
            return cls._empty(k, np.zeros(n_rows, dtype=bool))
        return cls.from_column(col, k)

    def hit_positions(self, query_hashes: np.ndarray) -> np.ndarray:
        """Concatenated posting segments of the query grams present in
        this component: one int64 position per (query gram, row) hit,
        assembled by vectorized segment gathering (no python lists)."""
        if self.grams.shape[0] == 0 or query_hashes.shape[0] == 0:
            return _EMPTY_I64
        lo = np.searchsorted(self.grams, query_hashes, side="left")
        hi = np.searchsorted(self.grams, query_hashes, side="right")
        found = hi > lo
        if not found.any():
            return _EMPTY_I64
        starts = self.offsets[lo[found]]
        counts = self.offsets[lo[found] + 1] - starts
        return _segment_gather(self.positions, starts, counts)


def query_grams(spec: FuzzySpec, index_k: int) -> Tuple[np.ndarray, int]:
    """(sorted distinct query gram hashes, T-occurrence threshold) for a
    fuzzy spec against an ngram(``index_k``) index.  T <= 0 means the
    index cannot prune: every row with an indexable value is a candidate
    (the caller's ``has_value`` path).  Edit distance bounds hold for any
    gram length; a Jaccard spec whose own gram length differs from the
    index's gets no pruning (the bound would not be sound), only the
    batched verify."""
    _fld, kind, target, param = spec[:4]
    if kind == "jaccard" and spec_gram_length(spec) != index_k:
        return np.zeros(0, dtype=np.uint64), 0
    grams = sorted(set(gram_tokens(target, index_k)))
    qh = np.unique(fnv1a_hash(grams))
    if kind == "ed":
        return qh, int(qh.shape[0]) - index_k * int(param)
    if kind == "jaccard":
        deficit = len(grams) - int(qh.shape[0])
        return qh, int(math.ceil(float(param) * len(grams) - 1e-9)) - deficit
    raise ValueError(f"unknown fuzzy predicate kind {kind!r}")


def fuzzy_predicate(spec: FuzzySpec) -> Callable:
    """The row-engine oracle for a fuzzy spec — exactly the predicate the
    batched verification kernels reproduce, so plans can pass
    ``pred=fuzzy_predicate(spec), fuzzy=spec`` and both engines agree.
    Jaccard gram length comes from the spec (5th element, default
    GRAM_K).  Non-string / absent values never match."""
    fld, kind, target, param = spec[:4]
    if kind == "ed":
        return lambda r: isinstance(r.get(fld), str) \
            and edit_distance_check(r[fld], target, param)
    if kind == "jaccard":
        k = spec_gram_length(spec)
        tg = gram_tokens(target, k)
        return lambda r: isinstance(r.get(fld), str) \
            and similarity_jaccard_check(gram_tokens(r[fld], k), tg, param)
    raise ValueError(f"unknown fuzzy predicate kind {kind!r}")
