"""Batched fuzzy-candidate verification (the VERIFY stage).

Replaces the per-pair python loops on both fuzzy paths:

  * ``verify_mask`` refines an ngram-candidate position bitmap over a
    partition's ColumnBatch.  String columns are dictionary-coded, so
    verification runs once per *distinct* candidate value — banded DP
    (``kernels/fuzzy_ops.edit_distances``) for edit distance, the
    sorted-set intersection kernel for gram-set Jaccard — and the
    per-row answer is a code-indexed lookup.
  * ``jaccard_pair_sims`` verifies FuzzyJoin candidate pairs: token sets
    are encoded against one shared sorted dictionary and the batched
    intersection kernel scores every pair in one pass.

Decisions match the scalar oracles exactly: the DP's <= d decision is
exact (saturation only caps values beyond the band), and Jaccard
divides exact integer counts in float64 — the same arithmetic as
``len(a & b) / len(a | b)``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Set, Tuple

import numpy as np

from ..core.functions import gram_tokens
from ..kernels import fuzzy_ops as F
from .ngram import FuzzySpec

__all__ = ["verify_values", "verify_mask", "encode_token_sets",
           "jaccard_pair_sims"]


def _jaccard_values(values: Sequence[str], target: str, t: float,
                    k: int) -> np.ndarray:
    """Gram-set Jaccard of each value vs the target, decided on exact
    gram *strings* (dictionary coding — hashes never touch the verify
    stage, so collisions cannot flip a decision)."""
    coded = encode_token_sets([set(gram_tokens(v, k)) for v in values]
                              + [set(gram_tokens(target, k))])
    sims = F.jaccard_sims(coded[:-1], [coded[-1]] * len(values))
    return sims >= t


def verify_values(values: Sequence[str], spec: FuzzySpec, k: int
                  ) -> np.ndarray:
    """Bool per distinct candidate string: does it satisfy the fuzzy
    predicate?  One batched kernel call for the whole value set."""
    if not values:
        return np.zeros(0, dtype=bool)
    _fld, kind, target, param = spec[:4]
    if kind == "ed":
        return np.asarray(F.edit_distances(values, target, int(param))
                          <= int(param))
    return _jaccard_values(values, target, float(param), k)


def verify_mask(batch: Any, mask: np.ndarray, spec: FuzzySpec, k: int
                ) -> np.ndarray:
    """Refine a candidate position bitmap: keep only positions whose
    field value passes the batched verifier.  Dictionary-coded columns
    verify per distinct code; ``obj`` columns (open-type drift) verify
    per distinct string via a host dictionary; non-string values never
    match (the predicate contract)."""
    fld = spec[0]
    out = np.zeros(mask.shape[0], dtype=bool)
    if not mask.any():
        return out
    col = batch.columns.get(fld)
    if col is None:
        return out
    pos = np.nonzero(mask)[0]
    if col.kind == "str":
        vals = col.values or []
        valid = col.valid[pos]
        if not valid.any():
            return out
        cpos = pos[valid]
        codes = col.data[cpos].astype(np.int64)
        used = np.unique(codes)
        ok_used = verify_values([vals[c] for c in used.tolist()], spec, k)
        lut = np.zeros(max(len(vals), 1), dtype=bool)
        lut[used] = ok_used
        out[cpos[lut[codes]]] = True
        return out
    # obj column: distinct-string verification through a host dictionary
    raw = [col.data[p] if col.valid[p] else None for p in pos.tolist()]
    distinct = sorted({v for v in raw if isinstance(v, str)})
    if not distinct:
        return out
    ok = dict(zip(distinct, verify_values(distinct, spec, k).tolist()))
    for p, v in zip(pos.tolist(), raw):
        if isinstance(v, str) and ok[v]:
            out[p] = True
    return out


def encode_token_sets(token_sets: Sequence[Set[str]]
                      ) -> List[np.ndarray]:
    """Dictionary-code token sets against one shared vocabulary (codes
    assigned first-seen — any bijection preserves intersections): each
    set becomes a sorted distinct int64 code array, ready for the
    batched intersection kernel."""
    vocab: Dict[str, int] = {}
    out: List[np.ndarray] = []
    for s in token_sets:
        if s:
            arr = np.fromiter((vocab.setdefault(t, len(vocab))
                               for t in s), np.int64, count=len(s))
            arr.sort()
        else:
            arr = np.zeros(0, dtype=np.int64)
        out.append(arr)
    return out


def _pair_indices(pairs: Sequence[Tuple[Any, Any]]):
    """(distinct record ids, left row index per pair, right row index per
    pair).  Uniform scalar ids (the common case) dedup and index through
    numpy; anything else falls back to a python dictionary."""
    import itertools
    P = len(pairs)
    try:
        # one float64 pass, then an exactness gate: non-integral ids,
        # or ids beyond float64's exact-integer range, take the generic
        # dictionary path instead of being silently truncated
        flatf = np.fromiter(itertools.chain.from_iterable(pairs),
                            np.float64, count=2 * P).reshape(P, 2)
        if not (np.abs(flatf) < 2.0 ** 53).all():   # also rejects inf/nan
            raise TypeError("pair ids beyond exact-int float range")
        flat = flatf.astype(np.int64)
        if not (flat == flatf).all():               # non-integral ids
            raise TypeError("non-integral pair ids")
        uniq = np.unique(flat)
        pos = np.searchsorted(uniq, flat)
        return list(uniq.tolist()), \
            np.ascontiguousarray(pos[:, 0]), np.ascontiguousarray(pos[:, 1])
    except (TypeError, ValueError, OverflowError):
        ids = sorted({r for p in pairs for r in p}, key=str)
        id_pos = {rid: i for i, rid in enumerate(ids)}
        ai = np.fromiter((id_pos[a] for a, _ in pairs), np.int64,
                         count=len(pairs))
        bi = np.fromiter((id_pos[b] for _, b in pairs), np.int64,
                         count=len(pairs))
        return ids, ai, bi


def jaccard_pair_sims(pairs: Sequence[Tuple[Any, Any]],
                      toks: Dict[Any, Set[str]]) -> np.ndarray:
    """Exact float64 Jaccard per candidate pair (the FuzzyJoin verify
    stage): each record is dictionary-coded *once*, every candidate pair
    gathers its two encoded rows by index, and one batched intersection
    pass scores them all — per-pair work is a fancy-index, not python
    set algebra.  Small vocabularies (the common dedup case) ride the
    bitset/popcount kernel — a record is a few uint32 words; larger ones
    fall back to the sentinel-padded sorted-codes kernel."""
    if not pairs:
        return np.zeros(0, dtype=np.float64)
    ids, ai, bi = _pair_indices(pairs)
    R = len(ids)
    sizes = np.fromiter((len(toks[r]) for r in ids), np.int64, count=R)
    total = int(sizes.sum())
    vocab: Dict[str, int] = {}
    codes = np.fromiter((vocab.setdefault(t, len(vocab))
                         for r in ids for t in toks[r]),
                        np.int64, count=total)
    seg = np.repeat(np.arange(R, dtype=np.int64), sizes)
    if len(vocab) <= (1 << 15):
        bits = F.encode_bitsets(codes, seg, R, len(vocab))
        inter = F.bitset_intersect_counts(bits, ai, bi)
    else:
        # wide vocabulary: sorted-code rows in one sentinel-padded matrix
        order = np.lexsort((codes, seg))
        codes_sorted = codes[order]
        offs = np.zeros(R + 1, dtype=np.int64)
        np.cumsum(sizes, out=offs[1:])
        width = max(int(sizes.max()) if R else 0, 1)
        mat = np.full((R, width), F._SENTINEL, dtype=np.int64)
        mat[seg, np.arange(total) - np.repeat(offs[:-1], sizes)] = \
            codes_sorted
        inter = F.set_intersect_counts_padded(
            mat[ai], sizes[ai], mat[bi], sizes[bi])
    return F.jaccard_from_counts(inter, sizes[ai].astype(np.float64),
                                 sizes[bi].astype(np.float64))
