"""Fused columnar predicate + reduction kernels (the columnar engine's
hot path).

Four entry points, numpy in / python out, mirroring the ``ops.py``
backend-dispatch idiom:

  range_mask(preds)              conjunctive [lo, hi] range predicate over
                                 K columns -> bool mask
  fused_filter_aggregate(...)    the same mask fused with count/sum/min/max
                                 reductions over M aggregate columns in one
                                 pass (no materialized mask, no gather)
  sorted_intersect_mask(...)     sorted PK candidate set vs a partition's
                                 sorted live-pk array -> position bitmap
                                 (the columnar index access path: bitmaps
                                 intersect before any record is gathered)
  sorted_merge_take(...)         k-way sorted-PK merge/dedup/tombstone-drop
                                 -> take indices into the concatenated
                                 inputs (the LSM merge path: every column
                                 of the merged component is one gather)

On TPU these run as compiled Pallas kernels: predicate columns are stacked
into one [K, N] f32 operand, reductions accumulate across the row-block
grid in VMEM (f32 — documented precision caveat for int64-domain columns).
Elsewhere the pure-jnp oracle runs under ``jax.experimental.enable_x64``
so int64 epoch-microsecond and dictionary-code columns evaluate exactly.

All jnp-oracle entry points pad their operands to the next power of two
(invalid rows, so results are unchanged) before hitting the jitted cores:
repeated scans/merges over growing or gathered batches land on a bounded
set of traced shapes instead of retracing per length.  ``trace_count()``
exposes the cumulative number of traces so callers (ExecStats) can assert
zero retraces on repeated queries.
"""

from __future__ import annotations

import functools
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

from .ops import use_pallas
from . import device_pool as _pool
from ..obs import record_dispatch as _record_dispatch
from ..obs import record_retrace as _record_retrace
# the canonical pow2 helper lives with the padded-column storage it
# shapes (no cycle: columnar/__init__ pulls batch+schema only, and
# batch.py imports this module lazily)
from ..columnar.batch import pow2_len as _pow2_len
from ..columnar.batch import promotes_lossless as _promotes_lossless

__all__ = ["range_mask", "fused_filter_aggregate", "sorted_intersect_mask",
           "sorted_merge_take", "trace_count"]

# Cumulative number of jit traces of the columnar cores.  The increments
# below run at *trace* time only (python side effects inside jitted
# functions), so ``trace_count()`` deltas expose retraces: a repeated
# query over pow2-padded operands must not move this counter.
_TRACES = {"n": 0}


def trace_count() -> int:
    return _TRACES["n"]

# (data [N], valid [N] bool, lo, hi) — already in the column's physical
# (numeric) domain; None bound means unbounded on that side.
Pred = Tuple[np.ndarray, np.ndarray, Any, Any]

_BIG = 3.0e38   # f32-safe infinity stand-in for min/max identities


def _bounds(lo: Any, hi: Any) -> Tuple[float, float]:
    return (-np.inf if lo is None else lo, np.inf if hi is None else hi)


# ---------------------------------------------------------------------------
# jnp oracle (exact: runs in the column's native dtype under x64; jitted so
# one query costs one dispatch per partition, not one per column op)
# ---------------------------------------------------------------------------

def _prep_bounds(data: np.ndarray, lo: Any, hi: Any
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Same-dtype 0-d bound arrays (unbounded -> dtype extremes) so the
    jitted core never mixes int64 with float infinities."""
    if np.issubdtype(data.dtype, np.integer):
        info = np.iinfo(data.dtype)
        return (np.asarray(info.min if lo is None else lo, data.dtype),
                np.asarray(info.max if hi is None else hi, data.dtype))
    return (np.asarray(-np.inf if lo is None else lo, data.dtype),
            np.asarray(np.inf if hi is None else hi, data.dtype))


@jax.jit
def _mask_core(datas, valids, los, his):
    _TRACES["n"] += 1
    _record_retrace()
    m = None
    for x, v, lo, hi in zip(datas, valids, los, his):
        mm = v & (x >= lo) & (x <= hi)
        m = mm if m is None else (m & mm)
    return m


def _ident(dtype, is_min: bool):
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.asarray(info.max if is_min else info.min, dtype)
    return jnp.asarray(jnp.inf if is_min else -jnp.inf, dtype)


@jax.jit
def _agg_core(datas, valids, los, his, agg_datas, agg_valids):
    _TRACES["n"] += 1
    _record_retrace()
    if datas:
        mask = _mask_core(datas, valids, los, his)
    else:
        mask = jnp.ones(agg_datas[0].shape, dtype=bool)
    total = jnp.sum(mask)
    per_col = []
    for x, v in zip(agg_datas, agg_valids):
        ok = mask & v
        cnt = jnp.sum(ok)
        s = jnp.sum(jnp.where(ok, x, jnp.asarray(0, x.dtype)))
        mn = jnp.min(jnp.where(ok, x, _ident(x.dtype, True)))
        mx = jnp.max(jnp.where(ok, x, _ident(x.dtype, False)))
        per_col.append((s, mn, mx, cnt))
    return total, tuple(per_col)


def _split_preds(preds: Sequence[Pred]):
    datas = tuple(p[0] for p in preds)
    valids = tuple(p[1] for p in preds)
    bounds = [_prep_bounds(p[0], p[2], p[3]) for p in preds]
    los = tuple(b[0] for b in bounds)
    his = tuple(b[1] for b in bounds)
    return datas, valids, los, his


def _pad_pred(p: Pred, np2: int) -> Pred:
    """Length-pad one predicate column to ``np2`` with invalid rows: the
    row-validity conjunct keeps padding out of the mask, so results are
    identical while the jitted cores see a bounded set of shapes."""
    data, valid, lo, hi = p
    pad = np2 - data.shape[0]
    if pad <= 0:
        return p
    return (np.concatenate([data, np.zeros(pad, dtype=data.dtype)]),
            np.concatenate([valid, np.zeros(pad, dtype=bool)]), lo, hi)


@functools.lru_cache(maxsize=64)
def _live_pred(n: int, np2: int) -> Pred:
    """Unbounded predicate whose validity bitmap is the row-liveness flag
    (True for the first ``n`` rows): ANDing it in masks padding out.
    Memoized per (n, np2) bucket so repeated no-predicate aggregates hand
    the buffer pool the same arrays instead of re-allocating per query."""
    live = np.zeros(np2, dtype=bool)
    live[:n] = True
    return (np.zeros(np2, dtype=np.float64), live, None, None)


def _mask_jnp(preds: Sequence[Pred], n: int) -> np.ndarray:
    """Operand arrays may already carry a pow2-padded tail of invalid
    rows (``columnar.batch.Column.padded``); anything shorter is padded
    here so the jitted core only ever sees pow2 shapes."""
    np2 = max(_pow2_len(n),
              max(int(p[0].shape[0]) for p in preds))
    preds = [_pad_pred(p, np2) for p in preds]
    datas, valids, los, his = _split_preds(preds)
    # already-resident operands (pooled component views) ship nothing;
    # only this call's actual uploads count as h2d
    k = len(datas)
    ops, missed = _pool.fetch(list(datas) + list(valids))
    with enable_x64():
        out = np.asarray(_mask_core(tuple(ops[:k]), tuple(ops[k:]),
                                    los, his))
    _record_dispatch("range_mask", h2d=missed, d2h=[out])
    return out[:n]


def _agg_jnp(preds: Sequence[Pred],
             aggs: Sequence[Tuple[np.ndarray, np.ndarray]],
             n: int) -> Dict[str, Any]:
    with enable_x64():
        if not aggs:
            mask = _mask_jnp(preds, n) if preds else np.ones(n, dtype=bool)
            return {"count": int(mask.sum()), "sums": [], "mins": [],
                    "maxs": [], "cnts": []}
        np2 = max([_pow2_len(n)] + [int(a[0].shape[0]) for a in aggs]
                  + [int(p[0].shape[0]) for p in preds])
        if preds:              # padded rows are invalid in every conjunct
            preds = [_pad_pred(p, np2) for p in preds]
        elif np2 != n:         # no predicate: mask out padding explicitly
            preds = [_live_pred(n, np2)]
        padded_aggs = []
        for data, valid in aggs:
            pad = np2 - data.shape[0]
            if pad > 0:
                data = np.concatenate(
                    [data, np.zeros(pad, dtype=data.dtype)])
                valid = np.concatenate(
                    [valid, np.zeros(pad, dtype=bool)])
            padded_aggs.append((data, valid))
        datas, valids, los, his = _split_preds(preds)
        k, m = len(datas), len(padded_aggs)
        ops, missed = _pool.fetch(
            list(datas) + list(valids)
            + [a[0] for a in padded_aggs] + [a[1] for a in padded_aggs])
        total, per_col = _agg_core(
            tuple(ops[:k]), tuple(ops[k:2 * k]), los, his,
            tuple(ops[2 * k:2 * k + m]), tuple(ops[2 * k + m:]))
        _record_dispatch("fused_filter_aggregate", h2d=missed)
        out: Dict[str, Any] = {"count": int(total), "sums": [], "mins": [],
                               "maxs": [], "cnts": []}
        for s, mn, mx, cnt in per_col:
            c = int(cnt)
            out["cnts"].append(c)
            out["sums"].append(s.item())
            out["mins"].append(mn.item() if c else None)
            out["maxs"].append(mx.item() if c else None)
        return out


# ---------------------------------------------------------------------------
# Pallas kernels (TPU): stacked [K, N] operands, grid-accumulated output
# ---------------------------------------------------------------------------

def _mask_kernel(p_ref, lo_ref, hi_ref, o_ref):
    p = p_ref[...]                                  # [K8, bn]
    lo = lo_ref[:, 0:1]
    hi = hi_ref[:, 0:1]
    m = jnp.all((p >= lo) & (p <= hi), axis=0)      # [bn]
    o_ref[...] = jnp.broadcast_to(m.astype(jnp.float32)[None, :],
                                  o_ref.shape)


def _agg_kernel(p_ref, lo_ref, hi_ref, a_ref, av_ref, o_ref):
    i = pl.program_id(0)
    p = p_ref[...]                                  # [K8, bn]
    lo = lo_ref[:, 0:1]
    hi = hi_ref[:, 0:1]
    m = jnp.all((p >= lo) & (p <= hi), axis=0)      # [bn]
    a = a_ref[...]                                  # [M8, bn]
    ok = m[None, :] & (av_ref[...] > 0.5)           # [M8, bn]
    okf = ok.astype(jnp.float32)
    m8 = a.shape[0]
    pad = 128 - m8

    def row(v, fill):
        return jnp.pad(v, (0, pad), constant_values=fill)[None, :]

    sums = row(jnp.sum(a * okf, axis=1), 0.0)
    mins = row(jnp.min(jnp.where(ok, a, _BIG), axis=1), _BIG)
    maxs = row(jnp.max(jnp.where(ok, a, -_BIG), axis=1), -_BIG)
    cnts = row(jnp.sum(okf, axis=1), 0.0)
    total = jnp.full((1, 128), 0.0, jnp.float32) \
        .at[0, 0].set(jnp.sum(m.astype(jnp.float32)))
    pad_rows = jnp.zeros((o_ref.shape[0] - 5, 128), jnp.float32)
    upd = jnp.concatenate([sums, mins, maxs, cnts, total, pad_rows], axis=0)

    @pl.when(i == 0)
    def _init():
        ident = jnp.concatenate([
            jnp.zeros((1, 128), jnp.float32),
            jnp.full((1, 128), _BIG, jnp.float32),
            jnp.full((1, 128), -_BIG, jnp.float32),
            jnp.zeros((2, 128), jnp.float32),
            pad_rows], axis=0)
        o_ref[...] = ident

    s = o_ref[...]
    o_ref[...] = jnp.concatenate([
        s[0:1] + upd[0:1],
        jnp.minimum(s[1:2], upd[1:2]),
        jnp.maximum(s[2:3], upd[2:3]),
        s[3:4] + upd[3:4],
        s[4:5] + upd[4:5],
        s[5:]], axis=0)


def _stack_preds(preds: Sequence[Pred], n: int, block_n: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """[K8, Np] f32 value matrix + [K8, 128] lo/hi bound columns.  Row K is
    the row-validity predicate (1 for real rows, 0 for padding), so padded
    lanes never contribute."""
    k8 = max(8, ((len(preds) + 1 + 7) // 8) * 8)
    np_pad = ((n + block_n - 1) // block_n) * block_n if n else block_n
    vals = np.zeros((k8, np_pad), dtype=np.float32)
    lo = np.full((k8, 128), -_BIG, dtype=np.float32)
    hi = np.full((k8, 128), _BIG, dtype=np.float32)
    for j, (data, valid, l, h) in enumerate(preds):
        l, h = _bounds(l, h)
        x = data[:n].astype(np.float32)     # operands may be pow2-padded
        x = np.where(valid[:n], x, _BIG)    # invalid fails the hi bound
        vals[j, :n] = x
        lo[j, :] = np.float32(max(l, -_BIG))
        hi[j, :] = np.float32(min(h, _BIG - 1))
    j = len(preds)
    vals[j, :n] = 1.0                       # row-validity predicate
    lo[j, :] = 0.5
    hi[j, :] = 1.5
    return vals, lo, hi, np_pad


def _mask_pallas(preds: Sequence[Pred], n: int, *, block_n: int = 512,
                 interpret: bool = False) -> np.ndarray:
    vals, lo, hi, np_pad = _stack_preds(preds, n, block_n)
    k8 = vals.shape[0]
    out = pl.pallas_call(
        _mask_kernel,
        grid=(np_pad // block_n,),
        in_specs=[
            pl.BlockSpec((k8, block_n), lambda i: (0, i)),
            pl.BlockSpec((k8, 128), lambda i: (0, 0)),
            pl.BlockSpec((k8, 128), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((8, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, np_pad), jnp.float32),
        interpret=interpret,
    )(vals, lo, hi)
    out = np.asarray(out)
    _record_dispatch("range_mask", h2d=[vals, lo, hi], d2h=[out])
    return out[0, :n] > 0.5


def _agg_pallas(preds: Sequence[Pred],
                aggs: Sequence[Tuple[np.ndarray, np.ndarray]], n: int,
                *, block_n: int = 512,
                interpret: bool = False) -> Dict[str, Any]:
    vals, lo, hi, np_pad = _stack_preds(preds, n, block_n)
    k8 = vals.shape[0]
    m8 = max(8, ((len(aggs) + 7) // 8) * 8)
    if m8 > 128:
        raise ValueError("fused kernel supports at most 128 agg columns")
    a = np.zeros((m8, np_pad), dtype=np.float32)
    av = np.zeros((m8, np_pad), dtype=np.float32)
    for j, (data, valid) in enumerate(aggs):
        a[j, :n] = data[:n].astype(np.float32)
        av[j, :n] = valid[:n].astype(np.float32)
    out = pl.pallas_call(
        _agg_kernel,
        grid=(np_pad // block_n,),
        in_specs=[
            pl.BlockSpec((k8, block_n), lambda i: (0, i)),
            pl.BlockSpec((k8, 128), lambda i: (0, 0)),
            pl.BlockSpec((k8, 128), lambda i: (0, 0)),
            pl.BlockSpec((m8, block_n), lambda i: (0, i)),
            pl.BlockSpec((m8, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        interpret=interpret,
    )(vals, lo, hi, a, av)
    out = np.asarray(out, dtype=np.float64)
    _record_dispatch("fused_filter_aggregate",
                     h2d=[vals, lo, hi, a, av], d2h=[out])
    m = len(aggs)
    cnts = [int(round(c)) for c in out[3, :m]]
    return {
        "count": int(round(out[4, 0])),
        "sums": [float(s) for s in out[0, :m]],
        "mins": [None if c == 0 else float(v)
                 for c, v in zip(cnts, out[1, :m])],
        "maxs": [None if c == 0 else float(v)
                 for c, v in zip(cnts, out[2, :m])],
        "cnts": cnts,
    }


# ---------------------------------------------------------------------------
# sorted intersection (columnar index access path)
# ---------------------------------------------------------------------------

@jax.jit
def _intersect_core(keys, cands):
    """Sorted merge via binary search: for each candidate, its insertion
    point in ``keys``; a hit scatters into the position bitmap."""
    _TRACES["n"] += 1
    _record_retrace()
    n = keys.shape[0]
    pos = jnp.searchsorted(keys, cands)
    posc = jnp.clip(pos, 0, n - 1)
    hit = (pos < n) & (keys[posc] == cands)
    mask = jnp.zeros(n, dtype=jnp.int32)
    return mask.at[posc].add(hit.astype(jnp.int32)) > 0


def _sorted_merge_mask(keys: np.ndarray, cands: np.ndarray) -> np.ndarray:
    """Host (numpy) sorted merge: the one shared membership algorithm for
    the below-dispatch-floor branch and the object-dtype pk fallback in
    columnar/operators."""
    n = keys.shape[0]
    pos = np.searchsorted(keys, cands)
    posc = np.clip(pos, 0, n - 1)
    hit = (pos < n) & (keys[posc] == cands)
    mask = np.zeros(n, dtype=bool)
    mask[posc[hit]] = True
    return mask


def _pow2_pad(arr: np.ndarray) -> np.ndarray:
    """Pad a sorted array to the next power of two by duplicating its last
    element (stays sorted; duplicates never flip membership), bounding the
    jit retrace count to O(log n * log m) shape pairs.  Memoized by array
    identity in the device pool, so repeated probes over the same sorted
    keys reuse one padded view — which is itself a stable pool key."""
    return _pool.padded(arr, fill="edge")


@jax.jit
def _intersect_rank_core(keys, cands):
    """Membership plus its exclusive-cumsum rank fused into one dispatch:
    the merge path consumes the device mask on-device instead of round-
    tripping it to host between the bitmap and the rank pass."""
    _TRACES["n"] += 1
    _record_retrace()
    n = keys.shape[0]
    pos = jnp.searchsorted(keys, cands)
    posc = jnp.clip(pos, 0, n - 1)
    hit = (pos < n) & (keys[posc] == cands)
    mask = jnp.zeros(n, dtype=jnp.int32)
    mem = mask.at[posc].add(hit.astype(jnp.int32)) > 0
    memi = mem.astype(jnp.int64)
    return mem, jnp.cumsum(memi) - memi


def _intersect_jnp(keys: np.ndarray, cands: np.ndarray) -> np.ndarray:
    n = keys.shape[0]
    ops, missed = _pool.fetch([_pow2_pad(keys), _pow2_pad(cands)])
    with enable_x64():
        mask = np.asarray(_intersect_core(ops[0], ops[1]))
    _record_dispatch("sorted_intersect_mask", h2d=missed, d2h=[mask])
    return mask[:n]


def _intersect_rank_jnp(keys: np.ndarray, cands: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """(membership, exclusive-cumsum rank) over ``keys`` in one dispatch
    (see ``_intersect_rank_core``)."""
    n = keys.shape[0]
    ops, missed = _pool.fetch([_pow2_pad(keys), _pow2_pad(cands)])
    with enable_x64():
        mem_d, rank_d = _intersect_rank_core(ops[0], ops[1])
        mem, rank = np.asarray(mem_d), np.asarray(rank_d)
    _record_dispatch("sorted_intersect_mask", h2d=missed, d2h=[mem, rank])
    return mem[:n], rank[:n]


def _intersect_kernel(k_ref, c_ref, o_ref, *, m):
    """Membership of a key block in the (VMEM-resident) candidate set.
    The rolled loop reads one candidate scalar per step and ORs a full
    vector compare — no gather, no host round-trip; the bitmap comes out
    fused with the row-validity flag so padded lanes never match."""
    k = k_ref[...]                               # [8, bn]
    keys = k[0:1, :]
    live = k[1:2, :]

    def body(j, acc):
        c = c_ref[0, j]
        return jnp.maximum(acc, (keys == c).astype(jnp.float32))

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros_like(keys))
    o_ref[...] = jnp.broadcast_to(acc * live, o_ref.shape)


def _intersect_pallas(keys: np.ndarray, cands: np.ndarray, n: int,
                      *, block_n: int = 512,
                      interpret: bool = False) -> np.ndarray:
    m = int(cands.shape[0])
    np_pad = ((n + block_n - 1) // block_n) * block_n
    vals = np.zeros((8, np_pad), dtype=np.float32)
    vals[0, :n] = keys.astype(np.float32)
    vals[1, :n] = 1.0                            # row-validity flag
    mp = max(128, ((m + 127) // 128) * 128)
    cv = np.zeros((8, mp), dtype=np.float32)
    cv[0, :m] = cands.astype(np.float32)
    out = pl.pallas_call(
        functools.partial(_intersect_kernel, m=m),
        grid=(np_pad // block_n,),
        in_specs=[
            pl.BlockSpec((8, block_n), lambda i: (0, i)),
            pl.BlockSpec((8, mp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((8, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, np_pad), jnp.float32),
        interpret=interpret,
    )(vals, cv)
    out = np.asarray(out)
    _record_dispatch("sorted_intersect_mask", h2d=[vals, cv], d2h=[out])
    return out[0, :n] > 0.5


def _f32_exact_ints(arr: np.ndarray) -> bool:
    """f32 compares keys exactly only below 2**24; larger pks (or float
    pks) stay on the exact x64 oracle."""
    return np.issubdtype(arr.dtype, np.integer) \
        and bool((np.abs(arr) < 2 ** 24).all())


# ---------------------------------------------------------------------------
# dispatching wrappers
# ---------------------------------------------------------------------------

def range_mask(preds: Sequence[Pred], n: int,
               *, force_pallas: Optional[bool] = None,
               interpret: bool = False) -> np.ndarray:
    """Conjunctive range mask over K predicate columns -> bool [n]."""
    if n == 0:
        return np.zeros(0, dtype=bool)
    if not preds:
        return np.ones(n, dtype=bool)
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas:
        return _mask_pallas(preds, n, interpret=interpret)
    return _mask_jnp(preds, n)


def fused_filter_aggregate(preds: Sequence[Pred],
                           aggs: Sequence[Tuple[np.ndarray, np.ndarray]],
                           n: int, *, force_pallas: Optional[bool] = None,
                           interpret: bool = False) -> Dict[str, Any]:
    """Filter + reduce in one pass.

    Returns ``{"count", "sums", "mins", "maxs", "cnts"}`` where ``count``
    is the number of mask survivors and per-aggregate lists are aligned
    with ``aggs`` (``cnts`` = valid survivors per column; ``mins``/
    ``maxs`` are None when that is 0).
    """
    if n == 0:
        return {"count": 0, "sums": [0] * len(aggs),
                "mins": [None] * len(aggs), "maxs": [None] * len(aggs),
                "cnts": [0] * len(aggs)}
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas:
        return _agg_pallas(preds, aggs, n, interpret=interpret)
    return _agg_jnp(preds, aggs, n)


def sorted_intersect_mask(keys: np.ndarray, cands: np.ndarray,
                          *, force_pallas: Optional[bool] = None,
                          interpret: bool = False) -> np.ndarray:
    """Position bitmap of a sorted candidate-PK array over a partition's
    sorted live-pk array: ``mask[i] == (keys[i] in cands)``.

    Empty inputs short-circuit (no zero-length kernel launch).  On TPU the
    Pallas membership kernel runs when both sides are f32-exact ints
    (|pk| < 2**24); otherwise the jitted x64 searchsorted oracle keeps
    int64 pks exact.
    """
    n, m = int(keys.shape[0]), int(cands.shape[0])
    if n == 0 or m == 0:
        return np.zeros(n, dtype=bool)
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas and (force_pallas
                   or (_f32_exact_ints(keys) and _f32_exact_ints(cands))):
        return _intersect_pallas(keys, cands, n, interpret=interpret)
    if n + m <= 4096:
        # below the jax dispatch floor the host sorted merge wins outright
        return _sorted_merge_mask(keys, cands)
    return _intersect_jnp(keys, cands)


# ---------------------------------------------------------------------------
# sorted k-way merge (columnar LSM merge path)
# ---------------------------------------------------------------------------

def _py_scalar_array(a: np.ndarray) -> np.ndarray:
    """Numeric array -> object array of python scalars, whose arbitrary-
    precision comparisons are exact across int/float domains (numpy
    cross-dtype scalar comparisons promote lossily)."""
    out = np.empty(a.shape[0], dtype=object)
    for j, v in enumerate(a.tolist()):
        out[j] = v
    return out


def _merge_take_object(arrays: Sequence[np.ndarray],
                       offs: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
    """Host fallback for object-dtype keys (string / tuple pks): heapq
    merge of the per-component sorted runs; ties sort by component rank,
    so the first entry of each key group is the newest version."""
    def entries(i: int):
        a, off = arrays[i], offs[i]
        return ((a[j], i, off + j) for j in range(a.shape[0]))

    keys_l: List[Any] = []
    take_l: List[int] = []
    prev = sentinel = object()
    for key, _rank, pos in heapq.merge(
            *(entries(i) for i in range(len(arrays)) if arrays[i].shape[0])):
        if prev is sentinel or key != prev:
            keys_l.append(key)
            take_l.append(pos)
            prev = key
    out = np.empty(len(keys_l), dtype=object)
    for j, k in enumerate(keys_l):
        out[j] = k
    return out, np.asarray(take_l, dtype=np.int64)


def sorted_merge_take(key_arrays: Sequence[np.ndarray],
                      tombs: Optional[Sequence[np.ndarray]] = None,
                      *, drop_tombstones: bool = False,
                      force_pallas: Optional[bool] = None,
                      interpret: bool = False
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized k-way sorted-PK merge/dedup/tombstone-drop: the LSM
    merge kernel.

    ``key_arrays`` are per-component sorted, per-component-unique key
    arrays ordered newest -> oldest.  Returns ``(keys, take, tomb)``
    where ``keys`` is the sorted key union with the newest component
    winning each duplicate, ``take`` indexes the winning entries in the
    concatenation of ``key_arrays`` (in the given order) so every column
    of the merged output is a single gather, and ``tomb`` marks entries
    whose winner is a tombstone (``tombs`` aligned with ``key_arrays``).
    With ``drop_tombstones`` those entries are removed (the paper's
    merge-includes-oldest collapse).

    Numeric keys reuse the ``sorted_intersect_mask`` dispatch stack
    (Pallas membership kernel on TPU for f32-exact ints, the jitted
    pow2-padded x64 searchsorted oracle elsewhere, host merge below the
    dispatch floor): each component's membership bitmap over the sorted
    key union doubles as its position map via an exclusive cumsum —
    because every component key appears in the union, the number of
    union entries before position j that belong to component c *is*
    the rank of union[j] within component c.  Take-indices therefore
    come out of K vectorized membership passes with no per-row python
    loop.  Object keys fall back to a host heapq merge.
    """
    arrays = [np.asarray(a) for a in key_arrays]
    lens = [int(a.shape[0]) for a in arrays]
    offs = [0] * len(arrays)
    for i in range(1, len(arrays)):
        offs[i] = offs[i - 1] + lens[i - 1]
    nonempty = [i for i, l in enumerate(lens) if l]
    if not nonempty:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy(), np.zeros(0, dtype=bool)
    # mixed dtypes promote on concat: require a lossless round-trip
    # into the promoted dtype or fall back to the exact object merge
    numeric = all(arrays[i].dtype != object
                  and arrays[i].dtype.kind in "biuf" for i in nonempty) \
        and _promotes_lossless([arrays[i] for i in nonempty])
    if numeric:
        union = np.unique(np.concatenate([arrays[i] for i in nonempty]))
        pallas = use_pallas() if force_pallas is None else force_pallas
        take = np.full(union.shape[0], -1, dtype=np.int64)
        for i in nonempty:                  # newest first: first hit wins
            if pallas:
                mem = sorted_intersect_mask(union, arrays[i],
                                            force_pallas=force_pallas,
                                            interpret=interpret)
                pos = np.cumsum(mem) - mem  # exclusive cumsum == rank in c
            elif union.shape[0] + arrays[i].shape[0] <= 1 << 20:
                # merges see each (union, component) shape pair once, so
                # the jitted oracle's trace never amortizes off-TPU: the
                # host sorted merge gets a much higher floor than the
                # (repeatedly-hit) intersect kernel's
                mem = _sorted_merge_mask(union, arrays[i])
                pos = np.cumsum(mem) - mem
            else:
                # membership + rank fused on-device: the mask never
                # round-trips to host just to feed the cumsum
                mem, pos = _intersect_rank_jnp(union, arrays[i])
            sel = (take < 0) & mem
            take[sel] = offs[i] + pos[sel]
    else:
        arrays = [a if a.dtype == object else _py_scalar_array(a)
                  for a in arrays]
        union, take = _merge_take_object(arrays, offs)
    if tombs is None:
        return union, take, np.zeros(take.shape[0], dtype=bool)
    tomb_all = np.concatenate(
        [np.asarray(t, dtype=bool) for t, l in zip(tombs, lens) if l]) \
        if any(lens) else np.zeros(0, dtype=bool)
    tomb = tomb_all[take]
    if drop_tombstones and tomb.any():
        keep = ~tomb
        union, take, tomb = union[keep], take[keep], tomb[keep]
    return union, take, tomb
