"""Fused columnar predicate + reduction kernels (the columnar engine's
hot path).

Three entry points, numpy in / python out, mirroring the ``ops.py``
backend-dispatch idiom:

  range_mask(preds)              conjunctive [lo, hi] range predicate over
                                 K columns -> bool mask
  fused_filter_aggregate(...)    the same mask fused with count/sum/min/max
                                 reductions over M aggregate columns in one
                                 pass (no materialized mask, no gather)
  sorted_intersect_mask(...)     sorted PK candidate set vs a partition's
                                 sorted live-pk array -> position bitmap
                                 (the columnar index access path: bitmaps
                                 intersect before any record is gathered)

On TPU both run as compiled Pallas kernels: predicate columns are stacked
into one [K, N] f32 operand, reductions accumulate across the row-block
grid in VMEM (f32 — documented precision caveat for int64-domain columns).
Elsewhere the pure-jnp oracle runs under ``jax.experimental.enable_x64``
so int64 epoch-microsecond and dictionary-code columns evaluate exactly.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

from .ops import use_pallas

__all__ = ["range_mask", "fused_filter_aggregate", "sorted_intersect_mask"]

# (data [N], valid [N] bool, lo, hi) — already in the column's physical
# (numeric) domain; None bound means unbounded on that side.
Pred = Tuple[np.ndarray, np.ndarray, Any, Any]

_BIG = 3.0e38   # f32-safe infinity stand-in for min/max identities


def _bounds(lo: Any, hi: Any) -> Tuple[float, float]:
    return (-np.inf if lo is None else lo, np.inf if hi is None else hi)


# ---------------------------------------------------------------------------
# jnp oracle (exact: runs in the column's native dtype under x64; jitted so
# one query costs one dispatch per partition, not one per column op)
# ---------------------------------------------------------------------------

def _prep_bounds(data: np.ndarray, lo: Any, hi: Any
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Same-dtype 0-d bound arrays (unbounded -> dtype extremes) so the
    jitted core never mixes int64 with float infinities."""
    if np.issubdtype(data.dtype, np.integer):
        info = np.iinfo(data.dtype)
        return (np.asarray(info.min if lo is None else lo, data.dtype),
                np.asarray(info.max if hi is None else hi, data.dtype))
    return (np.asarray(-np.inf if lo is None else lo, data.dtype),
            np.asarray(np.inf if hi is None else hi, data.dtype))


@jax.jit
def _mask_core(datas, valids, los, his):
    m = None
    for x, v, lo, hi in zip(datas, valids, los, his):
        mm = v & (x >= lo) & (x <= hi)
        m = mm if m is None else (m & mm)
    return m


def _ident(dtype, is_min: bool):
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.asarray(info.max if is_min else info.min, dtype)
    return jnp.asarray(jnp.inf if is_min else -jnp.inf, dtype)


@jax.jit
def _agg_core(datas, valids, los, his, agg_datas, agg_valids):
    if datas:
        mask = _mask_core(datas, valids, los, his)
    else:
        mask = jnp.ones(agg_datas[0].shape, dtype=bool)
    total = jnp.sum(mask)
    per_col = []
    for x, v in zip(agg_datas, agg_valids):
        ok = mask & v
        cnt = jnp.sum(ok)
        s = jnp.sum(jnp.where(ok, x, jnp.asarray(0, x.dtype)))
        mn = jnp.min(jnp.where(ok, x, _ident(x.dtype, True)))
        mx = jnp.max(jnp.where(ok, x, _ident(x.dtype, False)))
        per_col.append((s, mn, mx, cnt))
    return total, tuple(per_col)


def _split_preds(preds: Sequence[Pred]):
    datas = tuple(p[0] for p in preds)
    valids = tuple(p[1] for p in preds)
    bounds = [_prep_bounds(p[0], p[2], p[3]) for p in preds]
    los = tuple(b[0] for b in bounds)
    his = tuple(b[1] for b in bounds)
    return datas, valids, los, his


def _mask_jnp(preds: Sequence[Pred]) -> np.ndarray:
    with enable_x64():
        return np.asarray(_mask_core(*_split_preds(preds)))


def _agg_jnp(preds: Sequence[Pred],
             aggs: Sequence[Tuple[np.ndarray, np.ndarray]],
             n: int) -> Dict[str, Any]:
    with enable_x64():
        if not aggs:
            mask = _mask_jnp(preds) if preds else np.ones(n, dtype=bool)
            return {"count": int(mask.sum()), "sums": [], "mins": [],
                    "maxs": [], "cnts": []}
        datas, valids, los, his = _split_preds(preds)
        total, per_col = _agg_core(
            datas, valids, los, his,
            tuple(a[0] for a in aggs), tuple(a[1] for a in aggs))
        out: Dict[str, Any] = {"count": int(total), "sums": [], "mins": [],
                               "maxs": [], "cnts": []}
        for s, mn, mx, cnt in per_col:
            c = int(cnt)
            out["cnts"].append(c)
            out["sums"].append(s.item())
            out["mins"].append(mn.item() if c else None)
            out["maxs"].append(mx.item() if c else None)
        return out


# ---------------------------------------------------------------------------
# Pallas kernels (TPU): stacked [K, N] operands, grid-accumulated output
# ---------------------------------------------------------------------------

def _mask_kernel(p_ref, lo_ref, hi_ref, o_ref):
    p = p_ref[...]                                  # [K8, bn]
    lo = lo_ref[:, 0:1]
    hi = hi_ref[:, 0:1]
    m = jnp.all((p >= lo) & (p <= hi), axis=0)      # [bn]
    o_ref[...] = jnp.broadcast_to(m.astype(jnp.float32)[None, :],
                                  o_ref.shape)


def _agg_kernel(p_ref, lo_ref, hi_ref, a_ref, av_ref, o_ref):
    i = pl.program_id(0)
    p = p_ref[...]                                  # [K8, bn]
    lo = lo_ref[:, 0:1]
    hi = hi_ref[:, 0:1]
    m = jnp.all((p >= lo) & (p <= hi), axis=0)      # [bn]
    a = a_ref[...]                                  # [M8, bn]
    ok = m[None, :] & (av_ref[...] > 0.5)           # [M8, bn]
    okf = ok.astype(jnp.float32)
    m8 = a.shape[0]
    pad = 128 - m8

    def row(v, fill):
        return jnp.pad(v, (0, pad), constant_values=fill)[None, :]

    sums = row(jnp.sum(a * okf, axis=1), 0.0)
    mins = row(jnp.min(jnp.where(ok, a, _BIG), axis=1), _BIG)
    maxs = row(jnp.max(jnp.where(ok, a, -_BIG), axis=1), -_BIG)
    cnts = row(jnp.sum(okf, axis=1), 0.0)
    total = jnp.full((1, 128), 0.0, jnp.float32) \
        .at[0, 0].set(jnp.sum(m.astype(jnp.float32)))
    pad_rows = jnp.zeros((o_ref.shape[0] - 5, 128), jnp.float32)
    upd = jnp.concatenate([sums, mins, maxs, cnts, total, pad_rows], axis=0)

    @pl.when(i == 0)
    def _init():
        ident = jnp.concatenate([
            jnp.zeros((1, 128), jnp.float32),
            jnp.full((1, 128), _BIG, jnp.float32),
            jnp.full((1, 128), -_BIG, jnp.float32),
            jnp.zeros((2, 128), jnp.float32),
            pad_rows], axis=0)
        o_ref[...] = ident

    s = o_ref[...]
    o_ref[...] = jnp.concatenate([
        s[0:1] + upd[0:1],
        jnp.minimum(s[1:2], upd[1:2]),
        jnp.maximum(s[2:3], upd[2:3]),
        s[3:4] + upd[3:4],
        s[4:5] + upd[4:5],
        s[5:]], axis=0)


def _stack_preds(preds: Sequence[Pred], n: int, block_n: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """[K8, Np] f32 value matrix + [K8, 128] lo/hi bound columns.  Row K is
    the row-validity predicate (1 for real rows, 0 for padding), so padded
    lanes never contribute."""
    k8 = max(8, ((len(preds) + 1 + 7) // 8) * 8)
    np_pad = ((n + block_n - 1) // block_n) * block_n if n else block_n
    vals = np.zeros((k8, np_pad), dtype=np.float32)
    lo = np.full((k8, 128), -_BIG, dtype=np.float32)
    hi = np.full((k8, 128), _BIG, dtype=np.float32)
    for j, (data, valid, l, h) in enumerate(preds):
        l, h = _bounds(l, h)
        x = data.astype(np.float32)
        x = np.where(valid, x, _BIG)        # invalid fails the hi bound
        vals[j, :n] = x
        lo[j, :] = np.float32(max(l, -_BIG))
        hi[j, :] = np.float32(min(h, _BIG - 1))
    j = len(preds)
    vals[j, :n] = 1.0                       # row-validity predicate
    lo[j, :] = 0.5
    hi[j, :] = 1.5
    return vals, lo, hi, np_pad


def _mask_pallas(preds: Sequence[Pred], n: int, *, block_n: int = 512,
                 interpret: bool = False) -> np.ndarray:
    vals, lo, hi, np_pad = _stack_preds(preds, n, block_n)
    k8 = vals.shape[0]
    out = pl.pallas_call(
        _mask_kernel,
        grid=(np_pad // block_n,),
        in_specs=[
            pl.BlockSpec((k8, block_n), lambda i: (0, i)),
            pl.BlockSpec((k8, 128), lambda i: (0, 0)),
            pl.BlockSpec((k8, 128), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((8, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, np_pad), jnp.float32),
        interpret=interpret,
    )(vals, lo, hi)
    return np.asarray(out)[0, :n] > 0.5


def _agg_pallas(preds: Sequence[Pred],
                aggs: Sequence[Tuple[np.ndarray, np.ndarray]], n: int,
                *, block_n: int = 512,
                interpret: bool = False) -> Dict[str, Any]:
    vals, lo, hi, np_pad = _stack_preds(preds, n, block_n)
    k8 = vals.shape[0]
    m8 = max(8, ((len(aggs) + 7) // 8) * 8)
    if m8 > 128:
        raise ValueError("fused kernel supports at most 128 agg columns")
    a = np.zeros((m8, np_pad), dtype=np.float32)
    av = np.zeros((m8, np_pad), dtype=np.float32)
    for j, (data, valid) in enumerate(aggs):
        a[j, :n] = data.astype(np.float32)
        av[j, :n] = valid.astype(np.float32)
    out = pl.pallas_call(
        _agg_kernel,
        grid=(np_pad // block_n,),
        in_specs=[
            pl.BlockSpec((k8, block_n), lambda i: (0, i)),
            pl.BlockSpec((k8, 128), lambda i: (0, 0)),
            pl.BlockSpec((k8, 128), lambda i: (0, 0)),
            pl.BlockSpec((m8, block_n), lambda i: (0, i)),
            pl.BlockSpec((m8, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        interpret=interpret,
    )(vals, lo, hi, a, av)
    out = np.asarray(out, dtype=np.float64)
    m = len(aggs)
    cnts = [int(round(c)) for c in out[3, :m]]
    return {
        "count": int(round(out[4, 0])),
        "sums": [float(s) for s in out[0, :m]],
        "mins": [None if c == 0 else float(v)
                 for c, v in zip(cnts, out[1, :m])],
        "maxs": [None if c == 0 else float(v)
                 for c, v in zip(cnts, out[2, :m])],
        "cnts": cnts,
    }


# ---------------------------------------------------------------------------
# sorted intersection (columnar index access path)
# ---------------------------------------------------------------------------

@jax.jit
def _intersect_core(keys, cands):
    """Sorted merge via binary search: for each candidate, its insertion
    point in ``keys``; a hit scatters into the position bitmap."""
    n = keys.shape[0]
    pos = jnp.searchsorted(keys, cands)
    posc = jnp.clip(pos, 0, n - 1)
    hit = (pos < n) & (keys[posc] == cands)
    mask = jnp.zeros(n, dtype=jnp.int32)
    return mask.at[posc].add(hit.astype(jnp.int32)) > 0


def _sorted_merge_mask(keys: np.ndarray, cands: np.ndarray) -> np.ndarray:
    """Host (numpy) sorted merge: the one shared membership algorithm for
    the below-dispatch-floor branch and the object-dtype pk fallback in
    columnar/operators."""
    n = keys.shape[0]
    pos = np.searchsorted(keys, cands)
    posc = np.clip(pos, 0, n - 1)
    hit = (pos < n) & (keys[posc] == cands)
    mask = np.zeros(n, dtype=bool)
    mask[posc[hit]] = True
    return mask


def _pow2_pad(arr: np.ndarray) -> np.ndarray:
    """Pad a sorted array to the next power of two by duplicating its last
    element (stays sorted; duplicates never flip membership), bounding the
    jit retrace count to O(log n * log m) shape pairs."""
    n = arr.shape[0]
    np2 = 1 << (n - 1).bit_length()
    if np2 == n:
        return arr
    return np.concatenate([arr, np.full(np2 - n, arr[-1],
                                        dtype=arr.dtype)])


def _intersect_jnp(keys: np.ndarray, cands: np.ndarray) -> np.ndarray:
    n = keys.shape[0]
    with enable_x64():
        mask = np.asarray(_intersect_core(jnp.asarray(_pow2_pad(keys)),
                                          jnp.asarray(_pow2_pad(cands))))
    return mask[:n]


def _intersect_kernel(k_ref, c_ref, o_ref, *, m):
    """Membership of a key block in the (VMEM-resident) candidate set.
    The rolled loop reads one candidate scalar per step and ORs a full
    vector compare — no gather, no host round-trip; the bitmap comes out
    fused with the row-validity flag so padded lanes never match."""
    k = k_ref[...]                               # [8, bn]
    keys = k[0:1, :]
    live = k[1:2, :]

    def body(j, acc):
        c = c_ref[0, j]
        return jnp.maximum(acc, (keys == c).astype(jnp.float32))

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros_like(keys))
    o_ref[...] = jnp.broadcast_to(acc * live, o_ref.shape)


def _intersect_pallas(keys: np.ndarray, cands: np.ndarray, n: int,
                      *, block_n: int = 512,
                      interpret: bool = False) -> np.ndarray:
    m = int(cands.shape[0])
    np_pad = ((n + block_n - 1) // block_n) * block_n
    vals = np.zeros((8, np_pad), dtype=np.float32)
    vals[0, :n] = keys.astype(np.float32)
    vals[1, :n] = 1.0                            # row-validity flag
    mp = max(128, ((m + 127) // 128) * 128)
    cv = np.zeros((8, mp), dtype=np.float32)
    cv[0, :m] = cands.astype(np.float32)
    out = pl.pallas_call(
        functools.partial(_intersect_kernel, m=m),
        grid=(np_pad // block_n,),
        in_specs=[
            pl.BlockSpec((8, block_n), lambda i: (0, i)),
            pl.BlockSpec((8, mp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((8, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, np_pad), jnp.float32),
        interpret=interpret,
    )(vals, cv)
    return np.asarray(out)[0, :n] > 0.5


def _f32_exact_ints(arr: np.ndarray) -> bool:
    """f32 compares keys exactly only below 2**24; larger pks (or float
    pks) stay on the exact x64 oracle."""
    return np.issubdtype(arr.dtype, np.integer) \
        and bool((np.abs(arr) < 2 ** 24).all())


# ---------------------------------------------------------------------------
# dispatching wrappers
# ---------------------------------------------------------------------------

def range_mask(preds: Sequence[Pred], n: int,
               *, force_pallas: Optional[bool] = None,
               interpret: bool = False) -> np.ndarray:
    """Conjunctive range mask over K predicate columns -> bool [n]."""
    if n == 0:
        return np.zeros(0, dtype=bool)
    if not preds:
        return np.ones(n, dtype=bool)
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas:
        return _mask_pallas(preds, n, interpret=interpret)
    return _mask_jnp(preds)


def fused_filter_aggregate(preds: Sequence[Pred],
                           aggs: Sequence[Tuple[np.ndarray, np.ndarray]],
                           n: int, *, force_pallas: Optional[bool] = None,
                           interpret: bool = False) -> Dict[str, Any]:
    """Filter + reduce in one pass.

    Returns ``{"count", "sums", "mins", "maxs", "cnts"}`` where ``count``
    is the number of mask survivors and per-aggregate lists are aligned
    with ``aggs`` (``cnts`` = valid survivors per column; ``mins``/
    ``maxs`` are None when that is 0).
    """
    if n == 0:
        return {"count": 0, "sums": [0] * len(aggs),
                "mins": [None] * len(aggs), "maxs": [None] * len(aggs),
                "cnts": [0] * len(aggs)}
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas:
        return _agg_pallas(preds, aggs, n, interpret=interpret)
    return _agg_jnp(preds, aggs, n)


def sorted_intersect_mask(keys: np.ndarray, cands: np.ndarray,
                          *, force_pallas: Optional[bool] = None,
                          interpret: bool = False) -> np.ndarray:
    """Position bitmap of a sorted candidate-PK array over a partition's
    sorted live-pk array: ``mask[i] == (keys[i] in cands)``.

    Empty inputs short-circuit (no zero-length kernel launch).  On TPU the
    Pallas membership kernel runs when both sides are f32-exact ints
    (|pk| < 2**24); otherwise the jitted x64 searchsorted oracle keeps
    int64 pks exact.
    """
    n, m = int(keys.shape[0]), int(cands.shape[0])
    if n == 0 or m == 0:
        return np.zeros(n, dtype=bool)
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas and (force_pallas
                   or (_f32_exact_ints(keys) and _f32_exact_ints(cands))):
        return _intersect_pallas(keys, cands, n, interpret=interpret)
    if n + m <= 4096:
        # below the jax dispatch floor the host sorted merge wins outright
        return _sorted_merge_mask(keys, cands)
    return _intersect_jnp(keys, cands)
