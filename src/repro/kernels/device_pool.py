"""Device-resident buffer pool for immutable columnar operands.

LSM components never mutate — they appear at flush/merge and retire when
replaced — so their pow2-padded column arrays and CSR postings arrays
are safe to keep device-side across queries.  The pool maps a *host*
array (by identity) to its device copy: the first touch uploads (the
caller records the host bytes as ``h2d``), every later touch returns the
resident copy for free.  Because kernel wrappers only account
``np.ndarray`` operands as transfer bytes (``obs.record_dispatch``), a
fully-resident dispatch naturally reports ``h2d_bytes == 0``.

Keying is by ``(id(arr), placement)`` guarded with a weak reference: the
pow2-padded views are already shape- and identity-stable per LSM version
(``Column.padded``, ``FieldPostings.padded_positions``, the partition
scan cache), so one component column is one pool entry for the
component's whole lifetime.  ``placement`` is None for the default
single-device copy or a ``NamedSharding`` for mesh-sharded uploads
(``runtime/spmd.fetch_sharded`` — stacked partition operands split over
the partition axis, attributed per shard via ``mesh.shard<k>.h2d_bytes``).
An array lives under at most one placement at a time: uploading it with
a *different* placement evicts the other copies first (reshard eviction,
``buffer_pool.reshard_evictions``), so switching between the loop and a
mesh — or between meshes — never double-holds device memory.  Eviction
is otherwise driven from two sides:

  * ``core/lsm.py`` calls :func:`release_component` at the two places a
    component's ``retired`` flag flips — immediate retirement at merge,
    or deferred retirement once the last snapshot pin drops — the same
    discipline the host arrays already follow;
  * a ``weakref.finalize`` per entry evicts when the host array is
    garbage-collected anyway (dropped scan-cache versions, pre-crash
    memtable postings after ``crash_and_recover``, throwaway operands),
    so the pool cannot leak buffers for arrays nothing references.

Metrics (see the registry docstring in ``obs/__init__``):
``buffer_pool.hits`` / ``buffer_pool.misses`` / ``buffer_pool.evictions``
counters and the ``buffer_pool.resident_bytes`` gauge.

The pool also memoizes *host-side* pow2 padding (:meth:`DevicePool.padded`)
so repeated probes over the same sorted-key arrays reuse one padded view
— which is what makes the padded array a stable pool key in turn.
"""

from __future__ import annotations

import threading
import weakref
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .. import obs
from ..columnar.batch import pow2_len as _pow2_len

__all__ = ["DevicePool", "pool", "fetch", "padded", "release_component",
           "clear", "stats"]

_HITS = obs.counter("buffer_pool.hits")
_MISSES = obs.counter("buffer_pool.misses")
_EVICTIONS = obs.counter("buffer_pool.evictions")
_RESHARDS = obs.counter("buffer_pool.reshard_evictions")
_RESIDENT = obs.gauge("buffer_pool.resident_bytes")


def _poolable(a: Any) -> bool:
    return isinstance(a, np.ndarray) and a.dtype != object


class DevicePool:
    """Identity-keyed host->device buffer cache (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        # (id(host), placement) -> (weakref(host), device, nbytes, finalizer)
        self._entries: Dict[Tuple[int, Any],
                            Tuple[Any, Any, int, Any]] = {}
        # id(host) -> placements currently resident for that id
        self._by_id: Dict[int, set] = {}
        # (id(host), fill) -> (weakref(host), padded host, finalizer)
        self._pads: Dict[Tuple[int, str], Tuple[Any, np.ndarray, Any]] = {}
        self._resident = 0

    # -- residency ----------------------------------------------------------

    def get(self, arr: np.ndarray, placement: Any = None
            ) -> Tuple[Any, bool]:
        """Device copy of ``arr`` under ``placement`` (None: default
        device; a ``NamedSharding``: mesh-sharded) plus whether it was
        already resident.  Uploads happen under ``enable_x64`` so
        int64/float64 operands keep their width (matching the jnp-oracle
        kernel convention).  Uploading under a new placement evicts the
        array's copies under any other placement first (reshard
        eviction) — an operand is resident one way at a time."""
        key = (id(arr), placement)
        with self._lock:
            e = self._entries.get(key)
            if e is not None and e[0]() is arr:
                _HITS.inc()
                return e[1], True
        with enable_x64():
            if placement is None:
                dev = jnp.asarray(arr)
            else:
                dev = jax.device_put(arr, placement)
        nb = int(arr.nbytes)
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                if e[0]() is arr:          # lost an upload race: keep first
                    _HITS.inc()
                    return e[1], True
                self._drop(key, e)         # stale entry under a reused id
            for other in list(self._by_id.get(id(arr), ())):
                if other != placement:     # reshard: drop the other copies
                    oe = self._entries.get((id(arr), other))
                    if oe is not None:
                        self._drop((id(arr), other), oe)
                        _RESHARDS.inc()
            fin = weakref.finalize(arr, self._on_dead, key)
            fin.atexit = False
            self._entries[key] = (weakref.ref(arr), dev, nb, fin)
            self._by_id.setdefault(id(arr), set()).add(placement)
            self._resident += nb
            _RESIDENT.set(self._resident)
        _MISSES.inc()
        return dev, False

    def fetch(self, arrs: Sequence[Any], placement: Any = None
              ) -> Tuple[List[Any], List[Any]]:
        """Map operands to device copies.  Returns ``(operands, missed)``
        where ``missed`` lists the host arrays uploaded by this call —
        exactly what the caller should report as ``h2d`` (pool hits ship
        nothing; non-poolable operands pass through untouched and keep
        their existing accounting)."""
        out: List[Any] = []
        missed: List[Any] = []
        for a in arrs:
            if _poolable(a):
                dev, hit = self.get(a, placement)
                out.append(dev)
                if not hit:
                    missed.append(a)
            else:
                out.append(a)
        return out, missed

    # -- host-side pad memo -------------------------------------------------

    def padded(self, arr: np.ndarray, fill: str = "edge") -> np.ndarray:
        """Pow2-padded host view of a 1-d array, memoized by identity so
        the padded array (the actual pool key) is stable across calls.
        ``fill="edge"`` repeats the last element (keeps sorted arrays
        sorted); ``fill="zero"`` pads with zeros (safe for index arrays
        whose padding lanes are masked out)."""
        n = int(arr.shape[0])
        np2 = _pow2_len(n)
        if np2 == n and n > 0:
            return arr
        key = (id(arr), fill)
        with self._lock:
            m = self._pads.get(key)
            if m is not None and m[0]() is arr:
                return m[1]
        if n == 0:
            pad = np.zeros(max(np2, 1), dtype=arr.dtype)
        elif fill == "edge":
            pad = np.concatenate(
                [arr, np.full(np2 - n, arr[-1], dtype=arr.dtype)])
        else:
            pad = np.concatenate([arr, np.zeros(np2 - n, dtype=arr.dtype)])
        with self._lock:
            m = self._pads.get(key)
            if m is not None and m[0]() is arr:
                return m[1]
            fin = weakref.finalize(arr, self._on_dead_pad, key)
            fin.atexit = False
            self._pads[key] = (weakref.ref(arr), pad, fin)
        return pad

    # -- eviction -----------------------------------------------------------

    def release(self, arr: Any) -> None:
        """Explicitly evict ``arr``'s device copy and any padded views
        derived from it (their own device copies included)."""
        if not isinstance(arr, np.ndarray):
            return
        with self._lock:
            for fill in ("edge", "zero"):
                m = self._pads.pop((id(arr), fill), None)
                if m is not None:
                    m[2].detach()
                    self._release_exact(m[1])
            self._release_exact(arr)

    def release_component(self, comp: Any) -> None:
        """Eviction hook for LSM component retirement: free every device
        buffer backed by the component's arrays (keys, tombstones, batch
        columns + their cached padded/int64 views, secondary and ngram
        postings + their cached padded positions)."""
        arrs: List[Any] = [getattr(comp, "keys", None),
                           getattr(comp, "tomb", None)]
        batch = getattr(comp, "batch", None)
        if batch is not None:
            for col in batch.columns.values():
                arrs.extend((col.data, col.valid))
                for cached in (getattr(col, "_padded", None),
                               getattr(col, "_padded_i64", None)):
                    if cached is not None:
                        arrs.extend(cached)
        posts = list(getattr(comp, "sec_postings", {}).values()) \
            + list(getattr(comp, "gram_postings", {}).values())
        for p in posts:
            if p is None:
                continue
            arrs.extend((getattr(p, "keys", None), p.offsets, p.positions,
                         p.has_value, getattr(p, "_padded", None)))
        with self._lock:
            for a in arrs:
                if a is not None:
                    self.release(a)

    def clear(self) -> int:
        """Evict everything (bench cold-start helper).  Returns the
        number of entries dropped."""
        with self._lock:
            n = len(self._entries)
            for key, e in list(self._entries.items()):
                self._drop(key, e)
            for m in self._pads.values():
                m[2].detach()
            self._pads.clear()
            return n

    # -- introspection ------------------------------------------------------

    def resident_bytes(self) -> int:
        return self._resident

    def entry_count(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries),
                "resident_bytes": self._resident,
                "hits": _HITS.value, "misses": _MISSES.value,
                "evictions": _EVICTIONS.value}

    # -- internals ----------------------------------------------------------

    def _release_exact(self, arr: np.ndarray) -> None:
        for placement in list(self._by_id.get(id(arr), ())):
            key = (id(arr), placement)
            e = self._entries.get(key)
            if e is not None and (e[0]() is arr or e[0]() is None):
                self._drop(key, e)

    def _drop(self, key: Tuple[int, Any],
              e: Tuple[Any, Any, int, Any]) -> None:
        if self._entries.get(key) is not e:
            return
        del self._entries[key]
        placements = self._by_id.get(key[0])
        if placements is not None:
            placements.discard(key[1])
            if not placements:
                del self._by_id[key[0]]
        e[3].detach()
        self._resident -= e[2]
        _RESIDENT.set(self._resident)
        _EVICTIONS.inc()

    def _on_dead(self, key: Tuple[int, Any]) -> None:
        # host array was garbage-collected: drop the device copy (RLock:
        # safe even if the collection triggered under our own lock)
        with self._lock:
            e = self._entries.get(key)
            if e is not None and e[0]() is None:
                self._drop(key, e)

    def _on_dead_pad(self, key: Tuple[int, str]) -> None:
        with self._lock:
            m = self._pads.pop(key, None)
            # the padded host array dies with the memo entry; its own
            # finalizer then evicts its device copy
            if m is not None:
                m[2].detach()


pool = DevicePool()


def fetch(arrs: Sequence[Any], placement: Any = None
          ) -> Tuple[List[Any], List[Any]]:
    return pool.fetch(arrs, placement)


def padded(arr: np.ndarray, fill: str = "edge") -> np.ndarray:
    return pool.padded(arr, fill)


def release_component(comp: Any) -> None:
    pool.release_component(comp)


def clear() -> int:
    return pool.clear()


def stats() -> Dict[str, int]:
    return pool.stats()
