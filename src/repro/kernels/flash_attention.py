"""Flash attention forward kernel (TPU Pallas, GQA-aware).

Blockwise causal attention with streaming (m, l, acc) state — the same
associative merge the LSM-tiered decode uses per component.  VMEM tiling via
BlockSpec: q/out blocks [block_q, hd], k/v blocks [block_k, hd]; the MXU
contraction dims are kept at multiples of 128 by the callers (ops.py pads).

Grid = (B * H, num_q_blocks, num_kv_blocks); the kv dimension is innermost
and sequential — scratch VMEM accumulators persist across kv steps and the
output block is written once on the last step.  GQA avoids materializing
repeated KV heads with an index_map that folds query head h -> kv head h//G.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, causal: bool, block_q: int, block_k: int,
            num_kv_blocks: int, q_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                    # [bk, hd]
    v = v_ref[0].astype(jnp.float32)                    # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]
    if causal:
        q_pos = q_offset + qi * block_q + \
            jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = kj * block_k + \
            jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, block_q: int = 128,
                        block_k: int = 128, q_offset: int = 0,
                        interpret: bool = True) -> jax.Array:
    """q: [BH, Sq, hd] (B*H fused); k/v: [BKV, Skv, hd] with BH = BKV * G.

    Sq % block_q == 0 and Skv % block_k == 0 (ops.py pads); hd should be a
    multiple of 128 on real TPUs (the MXU lane dim) — interpret mode accepts
    anything.
    """
    BH, Sq, hd = q.shape
    BKV, Skv, _ = k.shape
    assert BH % BKV == 0
    G = BH // BKV
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k
    grid = (BH, nq, nk)
    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), causal=causal, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, kj, G=G: (bh // G, kj, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, kj, G=G: (bh // G, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
