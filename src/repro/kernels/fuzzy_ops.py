"""Vectorized similarity kernels for the fuzzy query subsystem.

Four entry points, numpy in / python out, following the
``columnar_ops.py`` dispatch idiom (Pallas kernels on TPU, pow2-padded
jitted-jnp cores under ``enable_x64`` elsewhere, host paths below the
jax dispatch floor):

  fnv1a_hash(tokens)             vectorized FNV-1a-64 over a padded byte
                                 matrix — the one token/gram hash the
                                 ngram postings and MinHash share
  t_occurrence_mask(pos, n, T)   fused segmented-count: gram-hit positions
                                 -> bool bitmap of rows with >= T hits
                                 (the ngram index candidate generator)
  edit_distances(strs, q, d)     batched banded (saturating) Levenshtein
                                 DP over padded char-code matrices ->
                                 min(ed, d+1) per candidate string
  set_intersect_counts(a, b)     per-pair sorted-set intersection sizes
                                 over dictionary-coded token sets (the
                                 batched Jaccard verifier; ``jaccard_sims``
                                 derives float64 similarities)

All jnp cores pad operands to powers of two so repeated fuzzy queries
land on a bounded set of traced shapes; trace-time increments share
``columnar_ops._TRACES`` so ``ExecStats.kernel_retraces`` covers the
fuzzy cores too (repeated queries must show 0).
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

from .ops import use_pallas
from . import device_pool as _pool
from .columnar_ops import _TRACES
from ..obs import record_dispatch as _record_dispatch
from ..obs import record_retrace as _record_retrace
from ..columnar.batch import pow2_len as _pow2_len

__all__ = ["fnv1a_hash", "t_occurrence_mask", "edit_distances",
           "set_intersect_counts", "set_intersect_counts_padded",
           "encode_bitsets", "bitset_intersect_counts",
           "jaccard_from_counts", "jaccard_sims"]

_FNV_OFFSET = np.uint64(14695981039346656037)
_FNV_PRIME = np.uint64(1099511628211)
_BIG = 3.0e38      # f32-safe infinity stand-in (Pallas operand padding)


# ---------------------------------------------------------------------------
# FNV-1a token hashing (vectorized over a padded byte matrix)
# ---------------------------------------------------------------------------

def fnv1a_hash(tokens: Sequence[str]) -> np.ndarray:
    """64-bit FNV-1a of each token, bit-identical to the classic per-byte
    python loop (``data.dedup._token_hash`` before the mod): tokens are
    laid out as one [T, Lmax] byte matrix and the hash state advances one
    *column* (not one token) at a time, so the python-level work is
    O(max token length), not O(total bytes)."""
    n = len(tokens)
    if n == 0:
        return np.zeros(0, dtype=np.uint64)
    bs = [t.encode() for t in tokens]
    lens = np.fromiter((len(b) for b in bs), dtype=np.int64, count=n)
    lmax = int(lens.max()) if n else 0
    mat = np.zeros((n, max(lmax, 1)), dtype=np.uint64)
    for i, b in enumerate(bs):
        if b:
            mat[i, :len(b)] = np.frombuffer(b, dtype=np.uint8)
    h = np.full(n, _FNV_OFFSET, dtype=np.uint64)
    for j in range(lmax):
        live = j < lens
        hj = (h ^ mat[:, j]) * _FNV_PRIME          # uint64 wrap == mod 2**64
        h = np.where(live, hj, h)
    return h


# ---------------------------------------------------------------------------
# T-occurrence segmented count (ngram candidate generation)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(2,))
def _tocc_core(pos, thr, np2):
    """Scatter-count gram hits per row position; padding positions point
    at the extra slot ``np2`` so they never count."""
    _TRACES["n"] += 1
    _record_retrace()
    cnt = jnp.zeros(np2 + 1, dtype=jnp.int32).at[pos].add(1)
    return cnt[:np2] >= thr


def _tocc_jnp(positions: np.ndarray, n: int, threshold: int) -> np.ndarray:
    np2 = _pow2_len(n)
    m = int(positions.shape[0])
    mp = _pow2_len(m)
    pos = np.concatenate([positions.astype(np.int64),
                          np.full(mp - m, np2, dtype=np.int64)])
    ops, missed = _pool.fetch([pos])
    with enable_x64():
        mask = np.asarray(_tocc_core(ops[0],
                                     jnp.asarray(threshold, jnp.int32), np2))
    _record_dispatch("t_occurrence_mask", h2d=missed, d2h=[mask])
    return mask[:n]


def _tocc_kernel(r_ref, p_ref, t_ref, o_ref, *, m):
    """Rolled-loop count: one posting scalar per step, a full vector
    compare-accumulate per row block (the ``_intersect_kernel`` idiom
    with a sum instead of a max)."""
    r = r_ref[...]                               # [8, bn]
    rowid = r[0:1, :]
    live = r[1:2, :]

    def body(j, acc):
        c = p_ref[0, j]
        return acc + (rowid == c).astype(jnp.float32)

    acc = jax.lax.fori_loop(0, m, body, jnp.zeros_like(rowid))
    thr = t_ref[0, 0]
    o_ref[...] = jnp.broadcast_to((acc >= thr).astype(jnp.float32) * live,
                                  o_ref.shape)


def _tocc_pallas(positions: np.ndarray, n: int, threshold: int,
                 *, block_n: int = 512, interpret: bool = False
                 ) -> np.ndarray:
    # pow2-padded operand widths AND loop bound: the kernel recompiles
    # per padded shape only, not per exact posting count / row count
    # (padding positions are -1, which matches no row id)
    m = int(positions.shape[0])
    np_pad = max(block_n, _pow2_len(n))
    vals = np.zeros((8, np_pad), dtype=np.float32)
    vals[0, :n] = np.arange(n, dtype=np.float32)
    vals[1, :n] = 1.0                            # row-validity flag
    mp = max(128, _pow2_len(m))
    pv = np.full((8, mp), -1.0, dtype=np.float32)    # -1 matches no row
    pv[0, :m] = positions.astype(np.float32)
    tv = np.full((8, 128), np.float32(threshold), dtype=np.float32)
    out = pl.pallas_call(
        functools.partial(_tocc_kernel, m=mp),
        grid=(np_pad // block_n,),
        in_specs=[
            pl.BlockSpec((8, block_n), lambda i: (0, i)),
            pl.BlockSpec((8, mp), lambda i: (0, 0)),
            pl.BlockSpec((8, 128), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((8, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, np_pad), jnp.float32),
        interpret=interpret,
    )(vals, pv, tv)
    out = np.asarray(out)
    _record_dispatch("t_occurrence_mask", h2d=[vals, pv, tv], d2h=[out])
    return out[0, :n] > 0.5


def t_occurrence_mask(positions: np.ndarray, n: int, threshold: int,
                      *, force_pallas: Optional[bool] = None,
                      interpret: bool = False) -> np.ndarray:
    """Bool [n]: rows whose gram-hit count reaches ``threshold``.

    ``positions`` is the concatenation of the query grams' posting
    segments (one entry per (gram, row) hit, rows deduped per gram), so
    one fused count pass replaces the per-gram python candidate lists.
    On TPU the Pallas compare-accumulate kernel runs (row ids are f32-
    exact below 2**24); elsewhere the jitted scatter-add core counts
    under x64, with a host bincount below the jax dispatch floor.
    """
    if n == 0:
        return np.zeros(0, dtype=bool)
    if threshold <= 0:
        return np.ones(n, dtype=bool)
    positions = np.asarray(positions, dtype=np.int64)
    m = int(positions.shape[0])
    if m == 0:
        return np.zeros(n, dtype=bool)
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas and (force_pallas or n < 2 ** 24):
        return _tocc_pallas(positions, n, threshold, interpret=interpret)
    if threshold == 1:
        # membership, not counting (the secondary postings candidate
        # bitmaps probe at T=1): a host bool scatter beats a jitted
        # dispatch off-TPU at every size
        mask = np.zeros(n, dtype=bool)
        mask[positions] = True
        return mask
    if n + m <= 4096:
        return np.bincount(positions, minlength=n) >= threshold
    return _tocc_jnp(positions, n, threshold)


# ---------------------------------------------------------------------------
# batched banded edit distance (candidate verification)
# ---------------------------------------------------------------------------

def _cummin_last(t, big):
    """Cumulative min along the last axis via log-step shifts (works in
    both the jnp core and the Pallas kernel body; shapes stay static)."""
    n = t.shape[-1]
    s = 1
    while s < n:
        shifted = jnp.concatenate(
            [jnp.full(t.shape[:-1] + (s,), big, t.dtype), t[..., :-s]],
            axis=-1)
        t = jnp.minimum(t, shifted)
        s *= 2
    return t


@jax.jit
def _ed_core(cand, lens, q, qlen, d):
    """Saturating Levenshtein DP, vectorized over the candidate batch.

    One DP row per query char; the within-row min-plus recurrence
    ``new[j] = min(m[j], new[j-1]+1)`` collapses to a cumulative min of
    ``m[j]-j`` (the +1-per-step factors out), so every step is dense
    [B, L+1] arithmetic.  Values saturate at ``d+1`` (the band): cells
    beyond the band can only produce answers > d, so clamping them keeps
    the <= d decision exact and the final value equal to min(ed, d+1).
    """
    _TRACES["n"] += 1
    _record_retrace()
    B, L = cand.shape
    M = q.shape[0]
    cap = (d + 1).astype(jnp.int64)
    j = jnp.arange(L + 1, dtype=jnp.int64)
    dp = jnp.minimum(j, cap) * jnp.ones((B, 1), dtype=jnp.int64)
    big = jnp.asarray(1 << 30, jnp.int64)

    def body(i, dp):
        qc = q[jnp.minimum(i, M - 1)]
        sub = (cand != qc).astype(jnp.int64)                     # [B, L]
        m_ = jnp.concatenate(
            [jnp.full((B, 1), 1, jnp.int64) + i,
             jnp.minimum(dp[:, 1:] + 1, dp[:, :-1] + sub)], axis=1)
        t = _cummin_last(m_ - j[None, :], big)
        new = jnp.minimum(t + j[None, :], cap)
        return jnp.where(i < qlen, new, dp)

    dp = jax.lax.fori_loop(0, M, body, dp)
    pick = jnp.minimum(lens, L)
    onehot = j[None, :] == pick[:, None]
    return jnp.sum(jnp.where(onehot, dp, 0), axis=1)


def _char_matrix(strings: Sequence[str], width: int, rows: int
                 ) -> np.ndarray:
    mat = np.zeros((rows, width), dtype=np.int32)
    for i, s in enumerate(strings):
        if s:
            mat[i, :len(s)] = np.fromiter(map(ord, s), dtype=np.int32,
                                          count=len(s))
    return mat


def _ed_jnp(strings: Sequence[str], query: str, d: int) -> np.ndarray:
    B = len(strings)
    lens = np.fromiter((len(s) for s in strings), np.int64, count=B)
    bp = _pow2_len(B)
    lp = _pow2_len(max(int(lens.max()) if B else 0, 1))
    mp = _pow2_len(max(len(query), 1))
    cand = _char_matrix(strings, lp, bp)
    lpad = np.concatenate([lens, np.zeros(bp - B, dtype=np.int64)])
    q = np.zeros(mp, dtype=np.int32)
    if query:
        q[:len(query)] = np.fromiter(map(ord, query), dtype=np.int32,
                                     count=len(query))
    ops, missed = _pool.fetch([cand, lpad, q])
    with enable_x64():
        out = np.asarray(_ed_core(
            ops[0], ops[1], ops[2],
            jnp.asarray(len(query), jnp.int64), jnp.asarray(d, jnp.int64)))
    _record_dispatch("edit_distances", h2d=missed, d2h=[out])
    return out[:B]


def _ed_kernel(c_ref, l_ref, q_ref, o_ref, *, m, cap):
    cand = c_ref[...]                            # [bb, Lp]
    lens = l_ref[...][:, 0:1]                    # [bb, 1]
    bb, L = cand.shape
    jrow = jax.lax.broadcasted_iota(jnp.float32, (bb, L + 1), 1)
    dp = jnp.minimum(jrow, cap)
    qlen = q_ref[1, 0]

    def body(i, dp):
        qc = q_ref[0, i]
        sub = (cand != qc).astype(jnp.float32)
        left = jnp.zeros((bb, 1), jnp.float32) + (i + 1).astype(jnp.float32)
        m_ = jnp.concatenate(
            [left, jnp.minimum(dp[:, 1:] + 1.0, dp[:, :-1] + sub)], axis=1)
        t = _cummin_last(m_ - jrow, _BIG)
        new = jnp.minimum(t + jrow, cap)
        return jnp.where(i.astype(jnp.float32) < qlen, new, dp)

    dp = jax.lax.fori_loop(0, m, body, dp)
    onehot = (jrow == jnp.minimum(lens, float(L))).astype(jnp.float32)
    dist = jnp.sum(dp * onehot, axis=1)          # [bb]
    o_ref[...] = jnp.broadcast_to(dist[None, :], o_ref.shape)


def _ed_pallas(strings: Sequence[str], query: str, d: int,
               *, block_b: int = 8, interpret: bool = False) -> np.ndarray:
    # pow2-padded batch AND query-loop bound (the kernel's ``i < qlen``
    # guard skips padded query rows), so distinct queries of similar
    # length share one compilation instead of one per exact length
    B = len(strings)
    lens = np.fromiter((len(s) for s in strings), np.int64, count=B)
    bp = max(block_b, _pow2_len(B))
    lp = _pow2_len(max(int(lens.max()) if B else 0, 1))
    mp = max(128, _pow2_len(max(len(query), 1)))
    cand = _char_matrix(strings, lp, bp).astype(np.float32)
    lv = np.zeros((bp, 128), dtype=np.float32)
    lv[:B, 0] = lens.astype(np.float32)
    qv = np.zeros((8, mp), dtype=np.float32)
    if query:
        qv[0, :len(query)] = np.fromiter(map(ord, query), dtype=np.float32,
                                         count=len(query))
    qv[1, :] = np.float32(len(query))
    out = pl.pallas_call(
        functools.partial(_ed_kernel, m=mp, cap=float(d + 1)),
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, lp), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 128), lambda i: (i, 0)),
            pl.BlockSpec((8, mp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((8, block_b), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, bp), jnp.float32),
        interpret=interpret,
    )(cand, lv, qv)
    out = np.asarray(out)
    _record_dispatch("edit_distances", h2d=[cand, lv, qv], d2h=[out])
    return out[0, :B].astype(np.int64)


def edit_distances(strings: Sequence[str], query: str, d: int,
                   *, force_pallas: Optional[bool] = None,
                   interpret: bool = False) -> np.ndarray:
    """``min(edit_distance(s, query), d+1)`` per candidate: saturated
    (banded) distances whose ``<= d`` decision is exact.  Char codes are
    f32-exact on the Pallas path (max code point 0x10FFFF < 2**24);
    a tiny batch runs the host DP outright (one jax dispatch costs more).
    """
    B = len(strings)
    if B == 0:
        return np.zeros(0, dtype=np.int64)
    d = max(int(d), 0)
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas:
        return _ed_pallas(strings, query, d, interpret=interpret)
    if B <= 32:
        from ..core.functions import edit_distance
        return np.asarray([min(edit_distance(s, query), d + 1)
                           for s in strings], dtype=np.int64)
    return _ed_jnp(strings, query, d)


# ---------------------------------------------------------------------------
# batched sorted-set intersection (Jaccard verification)
# ---------------------------------------------------------------------------

@jax.jit
def _inter_core(a, alens, b):
    """Per-pair |A ∩ B| via a vmapped binary search of each A element in
    the (sorted, sentinel-padded) B row."""
    _TRACES["n"] += 1
    _record_retrace()
    s1 = a.shape[1]

    def row(ar, al, br):
        pos = jnp.searchsorted(br, ar)
        posc = jnp.clip(pos, 0, br.shape[0] - 1)
        hit = (br[posc] == ar) & (jnp.arange(s1) < al)
        return jnp.sum(hit)

    return jax.vmap(row)(a, alens, b)


def _inter_jnp(a_mat, alens, b_mat) -> np.ndarray:
    ops, missed = _pool.fetch([a_mat, alens, b_mat])
    with enable_x64():
        out = np.asarray(_inter_core(ops[0], ops[1], ops[2]))
    _record_dispatch("set_intersect_counts", h2d=missed, d2h=[out])
    return out


def _inter_kernel(a_ref, l_ref, b_ref, o_ref, *, s1):
    a = a_ref[...]                               # [bp, S1]
    b = b_ref[...]                               # [bp, S2]
    al = l_ref[...][:, 0]                        # [bp]
    bp = a.shape[0]
    acc = jnp.zeros((bp,), jnp.float32)
    for j in range(s1):                          # static unroll over S1
        hit = jnp.any(b == a[:, j:j + 1], axis=1) & (j < al)
        acc = acc + hit.astype(jnp.float32)
    o_ref[...] = jnp.broadcast_to(acc[None, :], o_ref.shape)


def _inter_pallas(a_mat, alens, b_mat, *, block_p: int = 8,
                  interpret: bool = False) -> np.ndarray:
    P, s1 = a_mat.shape
    s2 = b_mat.shape[1]
    pp = max(block_p, _pow2_len(P))     # callers pow2-pad; keep it stable
    av = np.zeros((pp, s1), dtype=np.float32)
    av[:P] = a_mat.astype(np.float32)
    bv = np.full((pp, s2), _BIG, dtype=np.float32)
    bv[:P] = np.where(b_mat >= (1 << 24), _BIG, b_mat).astype(np.float32)
    lv = np.zeros((pp, 128), dtype=np.float32)
    lv[:P, 0] = alens.astype(np.float32)
    out = pl.pallas_call(
        functools.partial(_inter_kernel, s1=s1),
        grid=(pp // block_p,),
        in_specs=[
            pl.BlockSpec((block_p, s1), lambda i: (i, 0)),
            pl.BlockSpec((block_p, 128), lambda i: (i, 0)),
            pl.BlockSpec((block_p, s2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((8, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, pp), jnp.float32),
        interpret=interpret,
    )(av, lv, bv)
    out = np.asarray(out)
    _record_dispatch("set_intersect_counts", h2d=[av, lv, bv], d2h=[out])
    return out[0, :P].astype(np.int64)


_SENTINEL = np.int64(np.iinfo(np.int64).max)


def _pad_sets(sets: Sequence[np.ndarray], fill: np.int64
              ) -> tuple:
    P = len(sets)
    lens = np.zeros(_pow2_len(max(P, 1)), dtype=np.int64)
    lens[:P] = np.fromiter((len(s) for s in sets), np.int64, count=P)
    width = _pow2_len(max(int(lens.max()) if P else 0, 1))
    mat = np.full((lens.shape[0], width), fill, dtype=np.int64)
    for i, s in enumerate(sets):
        if len(s):
            mat[i, :len(s)] = s
    return mat, lens, lens.shape[0]


def set_intersect_counts_padded(a_mat: np.ndarray, alens: np.ndarray,
                                b_mat: np.ndarray, blens: np.ndarray,
                                *, force_pallas: Optional[bool] = None,
                                interpret: bool = False) -> np.ndarray:
    """Pre-padded variant of ``set_intersect_counts`` for callers that
    gather pair rows out of one shared record matrix (FuzzyJoin verify:
    pad each record once, then every candidate pair is a fancy-index —
    no per-pair python assembly).  ``b_mat`` rows must be sorted with the
    int64 sentinel as padding; ``a_mat`` rows are masked by ``alens``."""
    P = int(a_mat.shape[0])
    if P == 0:
        return np.zeros(0, dtype=np.int64)
    pp = _pow2_len(P)
    if pp != P:             # pow2 row padding keeps the jit shapes stable
        a2 = np.zeros((pp, a_mat.shape[1]), dtype=np.int64)
        a2[:P] = a_mat
        b2 = np.full((pp, b_mat.shape[1]), _SENTINEL, dtype=np.int64)
        b2[:P] = b_mat
        l2 = np.zeros(pp, dtype=np.int64)
        l2[:P] = alens
        a_mat, b_mat, alens = a2, b2, l2
    pallas = use_pallas() if force_pallas is None else force_pallas
    if pallas and (force_pallas
                   or (int(np.max(alens)) == 0
                       or (a_mat[a_mat != _SENTINEL].max(initial=0)
                           < 2 ** 24))):
        return _inter_pallas(a_mat, alens, b_mat, interpret=interpret)[:P]
    if P <= 16:
        return np.asarray(
            [len(np.intersect1d(a_mat[i][:alens[i]],
                                b_mat[i][:blens[i]], assume_unique=True))
             for i in range(P)], dtype=np.int64)
    return _inter_jnp(a_mat, alens, b_mat)[:P]


def set_intersect_counts(a_sets: Sequence[np.ndarray],
                         b_sets: Sequence[np.ndarray],
                         **kw: Any) -> np.ndarray:
    """``|a_sets[i] & b_sets[i]|`` per pair.  Each set is a sorted array
    of distinct dictionary codes; the b side pads with an int64 sentinel
    (stays sorted) and the a side is masked by its true length, so pads
    never match.  Codes must be < 2**24 for the Pallas path (dictionary
    sizes are), exact int64 on the jnp path."""
    P = len(a_sets)
    if P == 0:
        return np.zeros(0, dtype=np.int64)
    a_mat, alens, _ = _pad_sets(a_sets, np.int64(0))
    b_mat, blens, _ = _pad_sets(b_sets, _SENTINEL)
    return set_intersect_counts_padded(a_mat[:P], alens[:P], b_mat[:P],
                                       blens[:P], **kw)


@jax.jit
def _popcount_inter_core(bits, ai, bi):
    """Per-pair |A ∩ B| over vocabulary bitsets, gather fused in: both
    pair rows are gathered from the one shared record matrix on-device,
    then AND + popcount row-reduce (XLA ``population_count`` vectorizes
    on every backend, TPU included, so this core needs no separate
    Pallas variant)."""
    _TRACES["n"] += 1
    _record_retrace()
    return jnp.sum(jax.lax.population_count(bits[ai] & bits[bi]), axis=1)


def encode_bitsets(codes: np.ndarray, seg: np.ndarray, n_rows: int,
                   vocab_size: int) -> np.ndarray:
    """[n_rows, W] uint32 vocabulary bitsets from (row segment, code)
    pairs — the dense-dictionary fast path for pairwise set intersection
    when the vocabulary is small enough that a record is a few machine
    words.  Build is pure numpy: one argsort + one ``bitwise_or.reduceat``
    over the (row, word) groups, no per-token python loop."""
    W = _pow2_len(max((vocab_size + 31) // 32, 1))
    bits = np.zeros(n_rows * W, dtype=np.uint32)
    if codes.shape[0]:
        keys = seg.astype(np.int64) * W + (codes >> 5)
        vals = np.left_shift(np.uint32(1),
                             (codes & 31).astype(np.uint32))
        order = np.argsort(keys, kind="stable")
        keys, vals = keys[order], vals[order]
        # keys are sorted: group starts come from one diff, not a resort
        starts = np.flatnonzero(np.concatenate(
            [np.ones(1, dtype=bool), keys[1:] != keys[:-1]]))
        bits[keys[starts]] = np.bitwise_or.reduceat(vals, starts)
    return bits.reshape(n_rows, W)


def bitset_intersect_counts(bits: np.ndarray, ai: np.ndarray,
                            bi: np.ndarray) -> np.ndarray:
    """``popcount(bits[ai[p]] & bits[bi[p]])`` per pair (int64): the
    record matrix crosses to the device once; pair gathers happen inside
    the jitted core.  Index arrays pad to pow2 with row 0 (sliced off),
    keeping the traced shapes stable as the candidate count varies."""
    P = int(ai.shape[0])
    if P == 0:
        return np.zeros(0, dtype=np.int64)
    pp = _pow2_len(P)
    if pp != P:
        ai = np.concatenate([ai, np.zeros(pp - P, dtype=np.int64)])
        bi = np.concatenate([bi, np.zeros(pp - P, dtype=np.int64)])
    rp = _pow2_len(max(int(bits.shape[0]), 1))
    if rp != bits.shape[0]:
        bits = np.concatenate(
            [bits, np.zeros((rp - bits.shape[0], bits.shape[1]),
                            dtype=np.uint32)])
    # the record bitset matrix is reused across outer batches of a fuzzy
    # join: pooling it means only the per-batch index arrays re-ship
    ops, missed = _pool.fetch([bits, ai, bi])
    out = np.asarray(_popcount_inter_core(ops[0], ops[1], ops[2]))
    _record_dispatch("bitset_intersect_counts", h2d=missed, d2h=[out])
    return out[:P].astype(np.int64)


def jaccard_from_counts(inter: np.ndarray, a_sizes: np.ndarray,
                        b_sizes: np.ndarray) -> np.ndarray:
    """Finish Jaccard from intersection counts in float64 — the one
    place the division and the two-empty-sets -> 1.0 convention live, so
    every batched path matches the scalar ``similarity_jaccard`` oracle
    bit-for-bit."""
    inter = inter.astype(np.float64)
    union = a_sizes + b_sizes - inter
    return np.where(union > 0, inter / np.maximum(union, 1.0), 1.0)


def jaccard_sims(a_sets: Sequence[np.ndarray], b_sets: Sequence[np.ndarray],
                 **kw: Any) -> np.ndarray:
    """Exact float64 Jaccard similarity per pair of dictionary-coded
    sets (intersection counted by the kernel, the division done host-
    side so decisions match the python ``len(&)/len(|)`` oracle)."""
    inter = set_intersect_counts(a_sets, b_sets, **kw)
    al = np.fromiter((len(s) for s in a_sets), np.float64,
                     count=len(a_sets))
    bl = np.fromiter((len(s) for s in b_sets), np.float64,
                     count=len(b_sets))
    return jaccard_from_counts(inter, al, bl)
