"""LSM-tiered decode attention kernel — the paper's C3 on TPU.

Decode attention over ONE immutable KV component: the kernel streams the
component's KV blocks and emits the un-normalized flash state
(acc, m, l) instead of a normalized output.  Components (the frozen LSM runs
plus the mutable tail) are then merged by the associative logsumexp merge —
exactly how LSM disk components merge under a policy (paper §4.3): any
grouping/order gives the same result.

Layout: q [B, H, hd] (one decode token per sequence); component k/v
[B, S_c, KV, hd]; ``valid_len`` masks the partially-filled tail component.

Grid = (B, KV, num_kv_blocks); kv-block dim innermost/sequential, scratch
accumulators carry across blocks, outputs written on the last block.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_partial"]

NEG_INF = -1e30


def _kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
            acc_ref, m_ref, l_ref,
            *, scale: float, block_k: int, num_kv_blocks: int, G: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)               # [bk, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)               # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, bk]
    k_pos = kj * block_k + \
        jax.lax.broadcasted_iota(jnp.int32, (G, block_k), 1)
    s = jnp.where(k_pos < vl_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(k_pos < vl_ref[0], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...]
        m_out_ref[0, 0] = m_ref[...]
        l_out_ref[0, 0] = l_ref[...]


def decode_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                   valid_len: jax.Array, *, block_k: int = 128,
                   interpret: bool = True):
    """q: [B, H, hd]; k/v: [B, S_c, KV, hd]; valid_len: scalar int32.

    Returns the flash state (acc [B,H,hd] f32, m [B,H] f32, l [B,H] f32).
    """
    B, H, hd = q.shape
    _, Sc, KV, _ = k.shape
    assert H % KV == 0 and Sc % block_k == 0
    G = H // KV
    nk = Sc // block_k
    grid = (B, KV, nk)
    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(hd),
                               block_k=block_k, num_kv_blocks=nk, G=G)
    qg = q.reshape(B, KV, G, hd)
    vl = jnp.asarray(valid_len, jnp.int32).reshape(1)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # valid_len scalar
            pl.BlockSpec((1, 1, G, hd), lambda b, h, kj: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, kj: (b, kj, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, kj: (b, kj, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, kj: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, kj: (b, h, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, kj: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(vl, qg, k, v)
    return acc.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H)
