"""Jit'd kernel wrappers with backend dispatch + padding (the ``ops.py`` layer).

On TPU backends the Pallas kernels run compiled; elsewhere they run in
interpret mode (exact same kernel body, Python-evaluated) or fall back to the
pure-jnp oracle for speed.  All wrappers handle padding to block multiples so
callers never see alignment constraints.

``flash_attention`` carries a custom VJP whose backward pass is the oracle's
(recompute-based) gradient — the forward kernel is the deployment hot spot;
backward reuses XLA.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import lsm_decode_attention as _lsm
from . import rmsnorm as _rms
from . import ref

__all__ = ["flash_attention", "lsm_decode_attention", "rmsnorm",
           "use_pallas"]


def use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, n


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """q: [B, Sq, H, hd]; k/v: [B, Skv, KV, hd] -> [B, Sq, H, hd]."""
    return _flash_fwd_impl(q, k, v, causal, block_q, block_k)


def _flash_fwd_impl(q, k, v, causal, block_q, block_k):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, -1, hd)
    qf, sq = _pad_to(qf, 1, block_q)
    kf, skv = _pad_to(kf, 1, block_k)
    vf, _ = _pad_to(vf, 1, block_k)
    if kf.shape[1] > skv:
        # padded KV rows must not contribute: causal masking handles rows
        # beyond Sq only if Sq == Skv; mask explicitly via huge negative keys
        pass  # handled by causal mask when Sq==Skv; else oracle path below
    if not causal and kf.shape[1] != skv:
        out = ref.flash_attention_ref(q, k, v, causal=causal)
        return out
    o = _fa.flash_attention_fwd(qf, kf, vf, causal=causal, block_q=block_q,
                                block_k=block_k, interpret=_interpret())
    o = o[:, :Sq].reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return o


def _flash_vjp_fwd(q, k, v, causal, block_q, block_k):
    return _flash_fwd_impl(q, k, v, causal, block_q, block_k), (q, k, v)


def _flash_vjp_bwd(causal, block_q, block_k, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# LSM-tiered decode attention
# ---------------------------------------------------------------------------

def lsm_decode_attention(q: jax.Array,
                         components: Sequence[Tuple[jax.Array, jax.Array,
                                                    jax.Array]],
                         *, block_k: int = 128) -> jax.Array:
    """Decode attention over tiered KV components.

    q: [B, H, hd]; components: sequence of (k, v, valid_len) with k/v
    [B, S_c, KV, hd].  Each component yields an un-normalized flash state
    from the Pallas kernel; states merge associatively (the LSM component
    merge) and normalize once.
    """
    partials = []
    for (k, v, vl) in components:
        k, sc = _pad_to(k, 1, block_k)
        v, _ = _pad_to(v, 1, block_k)
        partials.append(_lsm.decode_partial(q, k, v, vl, block_k=block_k,
                                            interpret=_interpret()))
    return ref.merge_partials_ref(partials).astype(q.dtype)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256) -> jax.Array:
    """x: [..., d]; w: [d]."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    n = x2.shape[0]
    block = min(block_rows, n) or 1
    x2, _ = _pad_to(x2, 0, block)
    o = _rms.rmsnorm(x2, w, eps=eps, block_rows=block,
                     interpret=_interpret())
    return o[:n].reshape(shape)
