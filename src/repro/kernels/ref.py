"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These are the ground truth the kernels are validated against (interpret=True
shape/dtype sweeps in tests/test_kernels.py) and the fallback path on
non-TPU backends.
"""

from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "decode_partial_ref", "merge_partials_ref",
           "rmsnorm_ref"]

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True,
                        q_offset: int = 0) -> jax.Array:
    """Plain softmax attention.  q: [B, Sq, H, hd]; k/v: [B, Skv, KV, hd]
    with H = KV * G (GQA: query head h uses kv head h // G)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, hd) / math.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        mask = k.shape[1] and (jnp.arange(k.shape[1])[None, :]
                               <= q_pos[:, None])
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_partial_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                       valid_len: jax.Array | int
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Un-normalized partial attention over ONE KV component.

    q: [B, H, hd]; k/v: [B, S_c, KV, hd]; valid_len: number of valid rows.
    Returns flash state (acc [B,H,hd] un-normalized, m [B,H], l [B,H]) —
    the associative merge state of the LSM component merge.
    """
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, hd) / math.sqrt(hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k.astype(jnp.float32))
    valid = jnp.arange(k.shape[1]) < valid_len
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return (acc.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H))


def merge_partials_ref(partials: Sequence[Tuple[jax.Array, jax.Array,
                                                jax.Array]]) -> jax.Array:
    """Normalize the logsumexp-merge of per-component partials (LSM merge)."""
    acc, m, l = partials[0]
    for a2, m2, l2 in partials[1:]:
        m_new = jnp.maximum(m, m2)
        w1 = jnp.exp(m - m_new)
        w2 = jnp.exp(m2 - m_new)
        acc = acc * w1[..., None] + a2 * w2[..., None]
        l = l * w1 + l2 * w2
        m = m_new
    return acc / jnp.maximum(l, 1e-20)[..., None]


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(x.dtype)
