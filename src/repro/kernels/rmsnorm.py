"""Fused RMSNorm kernel (epilogue fusion; used by every assigned arch).

Row-blocked: each grid step normalizes a [block_rows, d] tile in VMEM with
f32 accumulation — one HBM read + one write per element instead of the
separate square/mean/rsqrt/mul HLO chain.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm"]


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x: [N, d]; w: [d].  N % block_rows == 0 (ops.py pads)."""
    N, d = x.shape
    assert N % block_rows == 0
    grid = (N // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, w)
