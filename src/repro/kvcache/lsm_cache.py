"""LSM-tiered KV cache (paper §4.3 adapted to TPU decode).

The paper's storage rule — mutable in-memory component, immutable flushed
components, deferred merges — maps onto the decode KV cache:

  tail   (memtable)        [B, tail_cap, KV, hd] — per-token appends land
                           here via cheap small dynamic_update_slice writes.
  L1 ring (disk components) [n1, B, tail_cap, KV, hd] — a full tail is
                           *flushed* (copied, then frozen) into the next slot.
  L2     (merged component) [B, max_len, KV, hd] — when the L1 ring fills,
                           its components are *merged* (bulk-appended; KV
                           entries are position-sorted so the merge is a
                           concatenation) into the big immutable region.

Attention runs per component (Pallas flash-decode kernel on TPU) producing
un-normalized (acc, m, l) states; states merge associatively — the same
algebra that lets LSM merge disk components in any order — then normalize
once.  Frozen components never change layout, so they can be laid out
tile-aligned and (future work) quantized.

Everything is static-shape and jit/scan-friendly: counters are traced
scalars, flush/merge are dynamic_update_slice writes gated by lax.cond.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ref as kref

__all__ = ["TieredCacheConfig", "init_tiered_cache", "tiered_update",
           "tiered_attend", "tiered_decode_attention", "cache_config_for",
           "tiered_from_prefill"]


@dataclass(frozen=True)
class TieredCacheConfig:
    tail_cap: int = 128
    l1_comps: int = 4
    max_len: int = 4096           # L2 capacity

    def __post_init__(self):
        assert self.max_len % self.tail_cap == 0


def init_tiered_cache(batch: int, kv_heads: int, head_dim: int,
                      ccfg: TieredCacheConfig, dtype=jnp.bfloat16
                      ) -> Dict[str, jax.Array]:
    T, N = ccfg.tail_cap, ccfg.l1_comps
    shape_tail = (batch, T, kv_heads, head_dim)
    return {
        "tail_k": jnp.zeros(shape_tail, dtype),
        "tail_v": jnp.zeros(shape_tail, dtype),
        "tail_len": jnp.zeros((), jnp.int32),
        "l1_k": jnp.zeros((N,) + shape_tail, dtype),
        "l1_v": jnp.zeros((N,) + shape_tail, dtype),
        "l1_count": jnp.zeros((), jnp.int32),
        "l2_k": jnp.zeros((batch, ccfg.max_len, kv_heads, head_dim), dtype),
        "l2_v": jnp.zeros((batch, ccfg.max_len, kv_heads, head_dim), dtype),
        "l2_len": jnp.zeros((), jnp.int32),
        # stats (validity accounting: flushes/merges mirror lsm.LSMIndex)
        "flushes": jnp.zeros((), jnp.int32),
        "merges": jnp.zeros((), jnp.int32),
    }


def _merge_l1_into_l2(cache: Dict[str, jax.Array],
                      ccfg: TieredCacheConfig) -> Dict[str, jax.Array]:
    """Bulk-append the full L1 ring into L2 (the LSM merge; entries are
    position-ordered so the merged run is just the concatenation)."""
    T, N = ccfg.tail_cap, ccfg.l1_comps
    B = cache["tail_k"].shape[0]
    flat_k = jnp.swapaxes(cache["l1_k"], 0, 1).reshape(
        B, N * T, *cache["l1_k"].shape[3:])
    flat_v = jnp.swapaxes(cache["l1_v"], 0, 1).reshape(
        B, N * T, *cache["l1_v"].shape[3:])
    l2_k = jax.lax.dynamic_update_slice(
        cache["l2_k"], flat_k, (0, cache["l2_len"], 0, 0))
    l2_v = jax.lax.dynamic_update_slice(
        cache["l2_v"], flat_v, (0, cache["l2_len"], 0, 0))
    return {**cache, "l2_k": l2_k, "l2_v": l2_v,
            "l2_len": cache["l2_len"] + N * T,
            "l1_count": jnp.zeros((), jnp.int32),
            "merges": cache["merges"] + 1}


def _flush_tail(cache: Dict[str, jax.Array],
                ccfg: TieredCacheConfig) -> Dict[str, jax.Array]:
    """Freeze the full tail as the next L1 component (shadow install: the
    component becomes visible only by the l1_count increment — the validity
    bit of paper §4.4)."""
    i = cache["l1_count"]
    l1_k = jax.lax.dynamic_update_slice(
        cache["l1_k"], cache["tail_k"][None], (i, 0, 0, 0, 0))
    l1_v = jax.lax.dynamic_update_slice(
        cache["l1_v"], cache["tail_v"][None], (i, 0, 0, 0, 0))
    cache = {**cache, "l1_k": l1_k, "l1_v": l1_v, "l1_count": i + 1,
             "tail_len": jnp.zeros((), jnp.int32),
             "flushes": cache["flushes"] + 1}
    return jax.lax.cond(cache["l1_count"] >= ccfg.l1_comps,
                        lambda c: _merge_l1_into_l2(c, ccfg),
                        lambda c: c, cache)


def tiered_update(cache: Dict[str, jax.Array], k_new: jax.Array,
                  v_new: jax.Array, ccfg: TieredCacheConfig
                  ) -> Dict[str, jax.Array]:
    """Append one token's KV ([B, 1, KV, hd]) to the tail; flush/merge as
    thresholds trip."""
    cache = jax.lax.cond(cache["tail_len"] >= ccfg.tail_cap,
                         lambda c: _flush_tail(c, ccfg),
                         lambda c: c, cache)
    tk = jax.lax.dynamic_update_slice(
        cache["tail_k"], k_new.astype(cache["tail_k"].dtype),
        (0, cache["tail_len"], 0, 0))
    tv = jax.lax.dynamic_update_slice(
        cache["tail_v"], v_new.astype(cache["tail_v"].dtype),
        (0, cache["tail_len"], 0, 0))
    return {**cache, "tail_k": tk, "tail_v": tv,
            "tail_len": cache["tail_len"] + 1}


def tiered_attend(cache: Dict[str, jax.Array], q: jax.Array,
                  ccfg: TieredCacheConfig) -> jax.Array:
    """q: [B, H, hd] -> [B, H, hd]: merge partial attention over
    L2 + L1 components + tail (logsumexp merge = LSM component merge)."""
    partials = [kref.decode_partial_ref(q, cache["l2_k"], cache["l2_v"],
                                        cache["l2_len"])]

    def l1_partial(i):
        vl = jnp.where(i < cache["l1_count"], ccfg.tail_cap, 0)
        return kref.decode_partial_ref(q, cache["l1_k"][i], cache["l1_v"][i],
                                       vl)

    accs, ms, ls = jax.vmap(l1_partial)(jnp.arange(ccfg.l1_comps))
    partials.extend((accs[i], ms[i], ls[i]) for i in range(ccfg.l1_comps))
    partials.append(kref.decode_partial_ref(
        q, cache["tail_k"], cache["tail_v"], cache["tail_len"]))
    return kref.merge_partials_ref(partials).astype(q.dtype)


def tiered_decode_attention(cache: Dict[str, jax.Array], q: jax.Array,
                            k_new: jax.Array, v_new: jax.Array,
                            ccfg: TieredCacheConfig
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step: append then attend over all tiers."""
    cache = tiered_update(cache, k_new, v_new, ccfg)
    return tiered_attend(cache, q, ccfg), cache


def cache_config_for(max_len: int, tail_cap: int = 256,
                     l1_comps: int = 4) -> TieredCacheConfig:
    """Model-config -> tiered-cache geometry (L2 sized to a component
    multiple covering max_len)."""
    tail_cap = min(tail_cap, max(max_len, 1))
    l2 = -(-max_len // tail_cap) * tail_cap + l1_comps * tail_cap
    return TieredCacheConfig(tail_cap=tail_cap, l1_comps=l1_comps,
                             max_len=l2)


def tiered_from_prefill(k: jax.Array, v: jax.Array,
                        ccfg: TieredCacheConfig,
                        dtype=None) -> Dict[str, jax.Array]:
    """LSM *bulk load*: a prefilled [B, S, KV, hd] KV block arrives presorted
    so it installs directly as one big L2 component (no per-token appends) —
    the paper's bulk-load fast path for initial Dataset loads."""
    B, S, KV, hd = k.shape
    dtype = dtype or k.dtype
    cache = init_tiered_cache(B, KV, hd, ccfg, dtype)
    assert S <= ccfg.max_len, (S, ccfg.max_len)
    l2_k = jax.lax.dynamic_update_slice(
        cache["l2_k"], k.astype(dtype), (0, 0, 0, 0))
    l2_v = jax.lax.dynamic_update_slice(
        cache["l2_v"], v.astype(dtype), (0, 0, 0, 0))
    return {**cache, "l2_k": l2_k, "l2_v": l2_v,
            "l2_len": jnp.asarray(S, jnp.int32)}
