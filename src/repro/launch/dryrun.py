import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) cell on the production meshes and record
memory/cost/collective analysis for §Dry-run and §Roofline.

The two lines above MUST run before any other import (jax locks the device
count at first init).  Do not replicate them in conftest/pyproject — smoke
tests and benches are supposed to see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json (cells are
skipped if their JSON already exists; --force overrides).
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import applicable_cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, make_step, pick_rules
from repro.roofline.analysis import model_flops, roofline

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _compile_cell(cfg, shape, mesh, rules):
    step, donate = make_step(cfg, shape, rules)
    args = input_specs(cfg, shape, mesh, rules)
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return compiled


def _probe_costs(cfg, shape, mesh, rules):
    """Layer-extrapolated cost accounting.

    XLA's cost_analysis counts a while-loop (scan-over-layers) body ONCE, so
    flops/bytes/collectives of deep models are understated by ~num_layers.
    We compile UNROLLED probes at 1 and 2 pattern-cycles on the same mesh and
    extrapolate linearly: total = c1 + (cycles-1) * (c2 - c1).  Exact for
    per-layer costs; the intercept captures embed/head/loss/optimizer.
    """
    import dataclasses
    from ..roofline.analysis import collective_bytes
    p = len(cfg.layer_pattern)
    out = {}
    for n in (1, 2):
        cfg_n = dataclasses.replace(cfg, num_layers=n * p, scan_layers=False)
        compiled = _compile_cell(cfg_n, shape, mesh, rules)
        cost = compiled.cost_analysis()
        out[n] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": collective_bytes(compiled.as_text()),
        }
    cycles = cfg.num_layers // p
    per_cycle = {k: out[2][k] - out[1][k] for k in ("flops", "bytes")}
    total = {k: out[1][k] + (cycles - 1) * per_cycle[k]
             for k in ("flops", "bytes")}
    coll_total = {}
    for key in out[1]["coll"]:
        d = out[2]["coll"][key] - out[1]["coll"][key]
        coll_total[key] = max(out[1]["coll"][key] + (cycles - 1) * d, 0)
    return {"flops": total["flops"], "bytes": total["bytes"],
            "coll": coll_total,
            "probe_1cycle": out[1], "probe_2cycle": out[2]}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides=None, optimized: bool = False) -> dict:
    from repro.configs.registry import optimized_config
    cfg = optimized_config(arch) if optimized else get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2" if multi_pod else "pod1"
    chips = 512 if multi_pod else 256
    rules = pick_rules(cfg, shape)
    step, donate = make_step(cfg, shape, rules)
    args = input_specs(cfg, shape, mesh, rules)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else 1)
    mf = model_flops(cfg.params_per_token_active(), tokens,
                     "train" if shape.kind == "train" else "serve")
    # layer-extrapolated (corrected) accounting — see _probe_costs docstring
    probe = _probe_costs(cfg, shape, mesh, rules)
    rep = roofline(arch, shape_name, mesh_name, chips,
                   {"flops": probe["flops"],
                    "bytes accessed": probe["bytes"]},
                   "", mf)
    rep.coll_breakdown = probe["coll"]
    rep.coll_bytes = float(probe["coll"].get("total", 0))
    raw = roofline(arch, shape_name, mesh_name, chips, cost, hlo, mf)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "peak_bytes_per_device": int(mem.peak_memory_in_bytes),
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "roofline": rep.as_dict(),
        "roofline_raw_scan_body": raw.as_dict(),
        "params_total": cfg.params_total(),
        "params_active": cfg.params_per_token_active(),
    }
    return result


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    p.add_argument("--all", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--override", action="append", default=[],
                   help="cfg field=value overrides (perf experiments)")
    p.add_argument("--optimized", action="store_true",
                   help="apply the arch's §Perf profile (registry."
                        "OPTIMIZED_PROFILES)")
    p.add_argument("--tag", default="", help="suffix for experiment outputs")
    args = p.parse_args()
    if args.optimized and not args.tag:
        args.tag = "opt"

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    cells = applicable_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"pod1": [False], "pod2": [True],
              "both": [False, True]}[args.mesh]
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    n_ok = n_fail = n_skip = 0
    for arch, shape_name in cells:
        for multi_pod in meshes:
            mesh_name = "pod2" if multi_pod else "pod1"
            tag = f"__{args.tag}" if args.tag else ""
            out = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}{tag}.json"
            if out.exists() and not args.force:
                n_skip += 1
                continue
            print(f"== {arch} × {shape_name} × {mesh_name} ...", flush=True)
            try:
                result = run_cell(arch, shape_name, multi_pod, overrides,
                                  optimized=args.optimized)
                n_ok += 1
            except Exception as e:  # noqa: BLE001 — record the failure
                result = {"arch": arch, "shape": shape_name,
                          "mesh": mesh_name, "status": "fail",
                          "error": f"{type(e).__name__}: {e}",
                          "traceback": traceback.format_exc()[-4000:]}
                n_fail += 1
                print(f"   FAIL: {type(e).__name__}: {e}", flush=True)
            out.write_text(json.dumps(result, indent=1))
            if result["status"] == "ok":
                r = result["roofline"]
                print(f"   ok lower={result['lower_s']}s "
                      f"compile={result['compile_s']}s "
                      f"peak={result['memory']['peak_bytes_per_device']/2**30:.2f}GiB/dev "
                      f"bottleneck={r['bottleneck']} "
                      f"step>={r['step_time_lb_s']*1e3:.1f}ms "
                      f"mfu@bound={r['mfu_at_bound']:.2f}", flush=True)
    print(f"done: ok={n_ok} fail={n_fail} skipped={n_skip}")


if __name__ == "__main__":
    main()
