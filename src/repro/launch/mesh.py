"""Production mesh construction (multi-pod dry-run deliverable).

A FUNCTION, not a module constant: importing this module never touches jax
device state, so launchers can set XLA_FLAGS first.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "POD_SHAPE", "MULTI_POD_SHAPE"]

POD_SHAPE = (16, 16)                # 256 chips/pod: (data, model)
MULTI_POD_SHAPE = (2, 16, 16)       # 2 pods = 512 chips: (pod, data, model)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)  # absent on jax 0.4.x
    kwargs = ({"axis_types": (axis_type.Auto,) * len(axes)}
              if axis_type is not None else {})
    return jax.make_mesh(shape, axes, **kwargs)
