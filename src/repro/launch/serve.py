"""Serving launcher: batched prefill + cached decode with selectable KV
layout (flat | tiered LSM components).

``python -m repro.launch.serve --arch deepseek-67b --reduced --requests 4``

A request batch is prefetched through the prefill step; decode then streams
tokens with either the flat cache or the paper-C3 tiered cache (bulk-loaded
from the prefill KV — the LSM "initial load" path).  Reports per-phase
throughput; on TPU the tiered path's per-component attention runs the Pallas
kernel (kernels/lsm_decode_attention.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="deepseek-67b")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen-tokens", type=int, default=32)
    p.add_argument("--kv-layout", choices=["flat", "tiered"],
                   default="tiered")
    args = p.parse_args()

    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.kvcache.lsm_cache import cache_config_for, tiered_from_prefill
    from repro.models import model as M
    from repro.models.layers import init_params

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, kv_layout=args.kv_layout)
    cfg_flat = dataclasses.replace(cfg, kv_layout="flat")

    params = init_params(M.model_specs(cfg), jax.random.key(0), jnp.float32)
    prefill = jax.jit(M.make_prefill_fn(cfg_flat))
    decode = jax.jit(M.make_decode_fn(cfg))

    B, P, T = args.requests, args.prompt_len, args.gen_tokens
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    logits, cache0 = jax.block_until_ready(
        prefill(params, {"tokens": prompts}))
    t_prefill = time.time() - t0
    max_len = P + T
    hd = cfg.resolved_head_dim

    if args.kv_layout == "tiered":
        ccfg = cache_config_for(max_len, cfg.kv_tail_cap, cfg.kv_l1_comps)

        def convert(st):
            if isinstance(st, dict) and set(st) == {"k", "v"}:
                fn = lambda k, v: tiered_from_prefill(k, v, ccfg, jnp.float32)
                if st["k"].ndim == 5:          # stacked over scan cycles
                    return jax.vmap(fn)(st["k"], st["v"])
                return fn(st["k"], st["v"])
            return st

        cache = {pos: convert(st) for pos, st in cache0.items()}
    else:
        def grow(x):
            if x.ndim >= 3 and x.shape[-3] == P and x.shape[-1] == hd:
                pad = [(0, 0)] * x.ndim
                pad[-3] = (0, max_len - P)
                return jnp.pad(x, pad)
            return x

        cache = jax.tree.map(grow, cache0)

    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    t0 = time.time()
    for t in range(T - 1):
        logits, cache = decode(params, cache,
                               {"token": tok, "pos": jnp.int32(P + t)})
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} layout={args.kv_layout} "
          f"requests={B} prompt={P} generated={gen.shape[1]}")
    print(f"prefill: {B * P / t_prefill:.0f} tok/s   "
          f"decode: {B * (T - 1) / t_decode:.1f} tok/s")
    if args.kv_layout == "tiered":
        for st in cache.values():
            if isinstance(st, dict) and "flushes" in st:
                import numpy as np
                print(f"LSM cache: flushes={int(jnp.max(st['flushes']))} "
                      f"merges={int(jnp.max(st['merges']))} per layer")
                break


if __name__ == "__main__":
    main()
