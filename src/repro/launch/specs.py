"""ShapeDtypeStruct stand-ins for every model input (dry-run deliverable).

``input_specs`` returns weak-type-correct, shardable abstract values — no
device allocation — for each (arch × shape) cell, plus the step function the
cell lowers:

  train_*    -> train_step(params, opt_state, batch)
  prefill_*  -> prefill_step(params, batch)
  decode_* / long_* -> decode_step(params, cache, batch)   (serve_step)
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig, SHAPES
from ..models.layers import abstract_params
from ..models.model import cache_specs, model_specs
from ..optim import adamw
from ..runtime.sharding import (DECODE_KVSEQ_RULES, DEFAULT_RULES,
                                LONG_CONTEXT_RULES, ShardingRules,
                                resolve_spec)
from ..training.train_step import make_serve_steps, make_train_step

__all__ = ["pick_rules", "input_specs", "make_step", "batch_specs"]


def pick_rules(cfg: ModelConfig, shape: ShapeConfig,
               model_axis: int = 16) -> ShardingRules:
    """The Algebricks "safe rule" dispatch per cell:
      * long_500k (batch=1): context-parallel — KV sequence over data×model.
      * decode/prefill with kv_heads not divisible by the model axis: the KV
        cache's sequence axis takes `model` (else the cache replicates 16x).
      * everything else: the default table.
    """
    if shape.name == "long_500k":
        rules = LONG_CONTEXT_RULES
    elif shape.kind == "decode" and cfg.num_kv_heads % model_axis != 0:
        rules = DECODE_KVSEQ_RULES
    elif shape.kind == "prefill" and cfg.num_kv_heads % model_axis != 0:
        # prefill COMPUTE keeps heads TP-sharded (replicating heads made
        # every GQA prefill 16x compute-redundant — §Perf iteration 4);
        # only the cache OUTPUT layout takes the kv_seq sharding.
        rules = DEFAULT_RULES.override(kv_seq="model")
    else:
        rules = DEFAULT_RULES
    if cfg.seq_shard:
        # Megatron sequence parallelism: the residual stream between blocks
        # is sharded over `model`; GSPMD turns each TP all-reduce into an
        # all-gather + reduce-scatter pair (half the wire bytes) and the
        # remat-saved block inputs shrink by the model-axis factor.
        rules = rules.override(seq_blocks="model")
    if cfg.rule_hints:
        # per-arch hints (paper Query 14): JSON overrides arrive as lists
        def _ax(v):
            if isinstance(v, list):
                return tuple(v)
            return v
        rules = rules.override(**{k: _ax(v) for k, v in cfg.rule_hints})
    return rules


def _sds(shape: Tuple[int, ...], dtype, logical, rules: ShardingRules,
         mesh: Mesh) -> jax.ShapeDtypeStruct:
    sh = NamedSharding(mesh, resolve_spec(shape, logical, rules, mesh))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                rules: ShardingRules) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": _sds((B, S), jnp.int32, ("batch", "seq"), rules, mesh),
            "labels": _sds((B, S), jnp.int32, ("batch", "seq"), rules, mesh),
        }
        if cfg.prefix_len:
            batch["prefix_emb"] = _sds(
                (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16,
                ("batch", "seq", "act_model"), rules, mesh)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, S), jnp.int32, ("batch", "seq"),
                                rules, mesh)}
        if cfg.prefix_len:
            batch["prefix_emb"] = _sds(
                (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16,
                ("batch", "seq", "act_model"), rules, mesh)
        return batch
    if shape.kind == "decode":
        return {
            "token": _sds((B, 1), jnp.int32, ("batch", None), rules, mesh),
            "pos": jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P())),
        }
    raise ValueError(shape.kind)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                rules: Optional[ShardingRules] = None,
                param_dtype=jnp.bfloat16) -> Tuple[Any, ...]:
    """Abstract positional args for the cell's step function."""
    rules = rules or pick_rules(cfg, shape)
    params = abstract_params(model_specs(cfg), param_dtype, mesh, rules)
    batch = batch_specs(cfg, shape, mesh, rules)
    if shape.kind == "train":
        opt_state = {
            "m": abstract_params(model_specs(cfg), jnp.float32, mesh, rules),
            "v": abstract_params(model_specs(cfg), jnp.float32, mesh, rules),
            "step": jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P())),
        }
        return (params, opt_state, batch)
    if shape.kind == "prefill":
        return (params, batch)
    cache = abstract_params(cache_specs(cfg, shape.global_batch,
                                        shape.seq_len),
                            jnp.bfloat16, mesh, rules)
    return (params, cache, batch)


def make_step(cfg: ModelConfig, shape: ShapeConfig,
              rules: Optional[ShardingRules] = None,
              opt_cfg: adamw.OptimizerConfig = adamw.OptimizerConfig(),
              ) -> Tuple[Callable, Tuple[int, ...]]:
    """(step_fn, donate_argnums) for the cell."""
    rules = rules or pick_rules(cfg, shape)
    if shape.kind == "train":
        return make_train_step(cfg, opt_cfg, rules), (0, 1)
    prefill, decode = make_serve_steps(cfg, rules)
    if shape.kind == "prefill":
        return prefill, ()
    return decode, (1,)
