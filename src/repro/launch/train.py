"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

On a real TPU fleet this process runs per-host under the cluster scheduler
(jax.distributed.initialize + the production mesh); on CPU it drives the
same Trainer at reduced scale.  Fault tolerance is exercised end-to-end:
restart the same command after a crash and it resumes from the newest VALID
checkpoint component with a deterministic data cursor.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--checkpoint-every", type=int, default=25)
    p.add_argument("--reduced", action="store_true",
                   help="smoke-scale config (CPU)")
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--compress", action="store_true",
                   help="int8 error-feedback gradient compression")
    p.add_argument("--override", action="append", default=[],
                   help="ModelConfig field=json overrides")
    args = p.parse_args()

    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.optim.adamw import OptimizerConfig
    from repro.training.trainer import Trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    print(f"arch={cfg.name} params~{cfg.params_total()/1e6:.1f}M "
          f"devices={len(jax.devices())}")
    tr = Trainer(cfg, global_batch=args.global_batch, seq_len=args.seq_len,
                 ckpt_dir=args.ckpt_dir, compress=args.compress,
                 opt_cfg=OptimizerConfig(peak_lr=args.lr,
                                         decay_steps=args.steps))
    tr.init_or_restore()
    if tr.step:
        print(f"resumed from checkpoint at step {tr.step}")
    out = tr.run(args.steps - tr.step,
                 checkpoint_every=args.checkpoint_every)
    tr.save_checkpoint()
    print(f"done at step {tr.step}: loss={out.get('loss'):.4f} "
          f"wall={out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
