"""GQA attention: chunked (flash-style) training path + cached decode.

The training/prefill path streams over KV blocks with a running
(max, normalizer, accumulator) triple — the same associative merge state the
LSM-tiered decode kernel uses per component (docs/ARCHITECTURE.md §Mesh and
collectives).  On TPU the inner
loop is the Pallas flash kernel (kernels/flash_attention.py); this module is
the XLA path that the dry-run lowers and the kernels' oracle reuses.

Decode supports two cache layouts:
  * flat   — one [B, S_max, KV, hd] buffer per layer (baseline)
  * tiered — LSM components (kvcache/lsm_cache.py), merged by logsumexp
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import ShardingRules, DEFAULT_RULES, constrain
from .layers import ParamSpec, apply_rope, dense

__all__ = ["attention_specs", "attention", "attention_prefill",
           "decode_attention", "flash_attention_xla", "NEG_INF"]

NEG_INF = -1e30


def _out_pref(cfg):
    """Collective dtype of TP partial-sum reductions (out-projections).
    bf16 halves the wire bytes of every cross-shard psum; the local MXU
    contraction still accumulates in f32 internally."""
    import jax.numpy as _jnp
    return _jnp.bfloat16 if cfg.reduce_dtype == "bfloat16" else _jnp.float32



def attention_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    specs = {
        "wq": ParamSpec((d, h, hd), ("d_model", "heads", "head_dim"), "scaled"),
        "wk": ParamSpec((d, kv, hd), ("d_model", "kv_heads", "head_dim"), "scaled"),
        "wv": ParamSpec((d, kv, hd), ("d_model", "kv_heads", "head_dim"), "scaled"),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "d_model"), "scaled"),
    }
    if cfg.use_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros")
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bo"] = ParamSpec((d,), ("act_model",), "zeros")
    return specs


def _qkv(params, x, cfg: ModelConfig, positions, rules: ShardingRules):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.use_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "act_heads", "head_dim"), rules)
    k = constrain(k, ("batch", "seq", "act_kv_heads", "head_dim"), rules)
    v = constrain(v, ("batch", "seq", "act_kv_heads", "head_dim"), rules)
    return q, k, v


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, chunk: int = 1024,
                        q_offset: int = 0) -> jax.Array:
    """Blockwise attention with running logsumexp (flash-style), in XLA.

    q: [B, Sq, KV, G, hd]  (G = query heads per KV head)
    k, v: [B, Skv, KV, hd]
    Streams over KV chunks via lax.scan so peak memory is
    O(Sq * chunk) per (B, head) instead of O(Sq * Skv).
    """
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    if Skv % chunk:
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = Skv
        Skv = k.shape[1]
    else:
        kv_valid = Skv
    nchunks = Skv // chunk
    scale = 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale)

    kc = k.reshape(B, nchunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, inp):
        acc, m, l = carry
        j, k_j, v_j = inp
        k_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgh,bckh->bskgc", qf, k_j.astype(jnp.float32))
        mask = k_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((Sq, chunk), bool)
        mask = jnp.logical_and(mask, (k_pos < kv_valid)[None, :])
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bskgc,bckh->bskgh", p, v_j.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (jnp.arange(nchunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.astype(q.dtype)


def attention(params: Dict[str, jax.Array], x: jax.Array,
              positions: jax.Array, cfg: ModelConfig,
              rules: ShardingRules = DEFAULT_RULES) -> jax.Array:
    """Training / prefill self-attention. x: [B, S, d]."""
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = h // kv
    q, k, v = _qkv(params, x, cfg, positions, rules)
    q = q.reshape(B, S, kv, G, hd)
    out = flash_attention_xla(q, k, v, causal=True, chunk=cfg.attn_chunk)
    out = out.reshape(B, S, h, hd)
    out = constrain(out, ("batch", "seq", "act_heads", "head_dim"), rules)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                   preferred_element_type=_out_pref(cfg)).astype(x.dtype)
    if cfg.use_bias:
        y = y + params["bo"].astype(y.dtype)
    return constrain(y, ("batch", "seq_blocks", "act_model"), rules)


def attention_prefill(params: Dict[str, jax.Array], x: jax.Array,
                      positions: jax.Array, cfg: ModelConfig,
                      rules: ShardingRules = DEFAULT_RULES,
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: like ``attention`` but also returns the KV cache (the LSM
    "bulk load" path — components arrive presorted, no per-token appends)."""
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = h // kv
    q, k, v = _qkv(params, x, cfg, positions, rules)
    q = q.reshape(B, S, kv, G, hd)
    out = flash_attention_xla(q, k, v, causal=True, chunk=cfg.attn_chunk)
    out = out.reshape(B, S, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                   preferred_element_type=_out_pref(cfg)).astype(x.dtype)
    if cfg.use_bias:
        y = y + params["bo"].astype(y.dtype)
    y = constrain(y, ("batch", "seq_blocks", "act_model"), rules)
    # cache copies live in the decode layout (kv_seq may be model-sharded)
    cache = {"k": constrain(k, ("batch", "kv_seq", "act_kv_heads",
                                "head_dim"), rules),
             "v": constrain(v, ("batch", "kv_seq", "act_kv_heads",
                                "head_dim"), rules)}
    return y, cache


def decode_attention_tiered(params: Dict[str, jax.Array], x: jax.Array,
                            cache: Dict[str, jax.Array], pos: jax.Array,
                            cfg: ModelConfig,
                            rules: ShardingRules = DEFAULT_RULES,
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step against the LSM-tiered KV cache (paper C3 path).

    The cache geometry is static (read from the cache pytree's shapes); the
    new token appends to the mutable tail, flush/merge fire on thresholds,
    and attention is the logsumexp merge over L2 + L1 components + tail.
    """
    from ..kvcache.lsm_cache import (TieredCacheConfig,
                                     tiered_decode_attention)
    B = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(params, x, cfg, positions, rules)
    ccfg = TieredCacheConfig(tail_cap=cache["tail_k"].shape[1],
                             l1_comps=cache["l1_k"].shape[0],
                             max_len=cache["l2_k"].shape[1])
    out, cache = tiered_decode_attention(cache, q[:, 0], k_new, v_new, ccfg)
    out = out.reshape(B, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                   preferred_element_type=_out_pref(cfg)).astype(x.dtype)
    if cfg.use_bias:
        y = y + params["bo"].astype(y.dtype)
    return y, cache


def decode_attention(params: Dict[str, jax.Array], x: jax.Array,
                     cache: Dict[str, jax.Array], pos: jax.Array,
                     cfg: ModelConfig,
                     rules: ShardingRules = DEFAULT_RULES,
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step against a flat KV cache.

    x: [B, 1, d]; cache: {"k","v": [B, S_max, KV, hd]}; pos: scalar int32 —
    the number of tokens already cached.  The new token's KV is written at
    ``pos`` (the LSM memtable append); attention spans [0, pos].
    """
    B, _, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = h // kv
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(params, x, cfg, positions, rules)

    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))

    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B, 1, kv, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqkgh,bskh->bqkgs", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(k_cache.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqkgs,bskh->bqkgh", p / jnp.maximum(l, 1e-20),
                     v_cache.astype(jnp.float32))
    out = out.reshape(B, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                   preferred_element_type=_out_pref(cfg)).astype(x.dtype)
    if cfg.use_bias:
        y = y + params["bo"].astype(y.dtype)
    return y, {"k": k_cache, "v": v_cache}
