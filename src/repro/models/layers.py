"""Parameter-spec infrastructure + basic layers.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every leaf is
declared by a ``ParamSpec`` carrying its shape, init, and *logical axes* —
the names the sharding rule table (runtime/sharding.py) maps to mesh axes.
This keeps three views of the model in lockstep:

  init_params      — materialized parameters (smoke tests / real training)
  abstract_params  — ShapeDtypeStructs (dry-run: no allocation)
  param_shardings  — NamedShardings for pjit in_shardings / checkpoint restore
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..runtime.sharding import ShardingRules, resolve_spec
from ..runtime.mesh import current_mesh

__all__ = [
    "ParamSpec", "init_params", "abstract_params", "param_shardings",
    "param_logical_axes", "compute_view", "rms_norm", "layer_norm", "dense",
    "embed_lookup", "apply_rope", "rope_freqs", "softcap", "count_params",
]

Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | scaled | ssm_a
    scale: float = 1.0
    dtype: Any = None           # defaults to model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), \
            f"{self.shape} vs {self.logical_axes}"


def _materialize(spec: ParamSpec, key: jax.Array, dtype: Any) -> jax.Array:
    dt = spec.dtype or dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "ssm_a":
        # Mamba: A initialized to -[1..state] broadcast over channels (log-space)
        state = spec.shape[-1]
        a = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32),
                     spec.shape[:-1] + (1,))
        return jnp.log(a).astype(dt)
    if spec.init == "scaled":
        # fan-in = first non-"layers" dim (scan stacking prepends a layers
        # axis; counting it as fan-in once mis-scaled every scanned model
        # ~sqrt(d/cycles)x hot and overflowed xLSTM's exponential gating)
        fan_in = 1
        for dim, name in zip(spec.shape, spec.logical_axes):
            if name != "layers":
                fan_in = dim
                break
        if len(spec.shape) < 2:
            fan_in = 1
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
    # plain normal
    return (jax.random.normal(key, spec.shape, jnp.float32)
            * spec.scale).astype(dt)


def _tree_paths(specs: Any, prefix=()) -> Sequence[Tuple[Tuple[str, ...], ParamSpec]]:
    out = []
    if isinstance(specs, ParamSpec):
        return [(prefix, specs)]
    for k in sorted(specs):
        out.extend(_tree_paths(specs[k], prefix + (k,)))
    return out


def init_params(specs: Any, rng: jax.Array, dtype=jnp.float32) -> Any:
    leaves = _tree_paths(specs)
    keys = jax.random.split(rng, len(leaves))
    flat = {path: _materialize(s, k, dtype)
            for (path, s), k in zip(leaves, keys)}
    return _unflatten(flat)


def abstract_params(specs: Any, dtype=jnp.float32, mesh: Optional[Mesh] = None,
                    rules: Optional[ShardingRules] = None) -> Any:
    """ShapeDtypeStructs (optionally with shardings) — no allocation."""
    def mk(path, s: ParamSpec):
        dt = s.dtype or dtype
        if mesh is not None and rules is not None:
            sh = NamedSharding(mesh, resolve_spec(s.shape, s.logical_axes,
                                                  rules, mesh))
            return jax.ShapeDtypeStruct(s.shape, dt, sharding=sh)
        return jax.ShapeDtypeStruct(s.shape, dt)
    flat = {path: mk(path, s) for path, s in _tree_paths(specs)}
    return _unflatten(flat)


def param_shardings(specs: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    flat = {path: NamedSharding(mesh, resolve_spec(s.shape, s.logical_axes,
                                                   rules, mesh))
            for path, s in _tree_paths(specs)}
    return _unflatten(flat)


def param_logical_axes(specs: Any) -> Any:
    flat = {path: s.logical_axes for path, s in _tree_paths(specs)}
    return _unflatten(flat)


def _unflatten(flat: Dict[Tuple[str, ...], Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        d = root
        for p in path[:-1]:
            d = d.setdefault(p, {})
        d[path[-1]] = v
    return root


def count_params(specs: Any) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _tree_paths(specs))


def compute_view(params: Any, axes: Any, rules: ShardingRules) -> Any:
    """FSDP weight-gathering: constrain parameters to their *compute* layout
    (``d_model`` unsharded, width axes TP-sharded) at point of use.

    Storage layout shards weights 2-D (d_model over `data` = FSDP, width over
    `model` = TP).  Contracting the d_model-sharded weight directly against
    batch-sharded activations makes GSPMD emit full-batch partial-sum
    all-reduces (observed 25 GiB/layer on deepseek train — EXPERIMENTS.md
    §Perf iteration 1).  Gathering the weight first costs an all-gather of
    the small FSDP shard instead; its transpose in backward is the
    reduce-scatter of the gradients — exactly the ZeRO-3 schedule.
    """
    from ..runtime.sharding import constrain  # local import: avoid cycle
    cv = rules.override(d_model=None)
    flat_p, treedef = jax.tree.flatten(params)
    is_axes = lambda x: (isinstance(x, tuple)
                         and all(e is None or isinstance(e, str) for e in x))
    flat_ax = jax.tree.flatten(axes, is_leaf=is_axes)[0]
    assert len(flat_p) == len(flat_ax)
    return jax.tree.unflatten(
        treedef, [constrain(p, ax, cv) for p, ax in zip(flat_p, flat_ax)])


# ---------------------------------------------------------------------------
# Layers (pure functions over param dicts)
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32 accumulation (routed through the Pallas kernel on TPU
    by kernels/ops.py; this is the XLA path)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: Optional[jax.Array],
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
          ) -> jax.Array:
    """x @ w with bf16-safe accumulation."""
    y = jnp.einsum("...d,df->...f", x, w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def embed_lookup(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """Gather rows; with `vocab` sharded over `model`, GSPMD lowers this to a
    masked partial-gather + all_reduce (the MToNReplicating fan-in)."""
    return jnp.take(table, tokens, axis=0)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


# -- RoPE -------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    freqs = rope_freqs(x.shape[-1], theta)                   # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(ang)[..., None, :]                          # [..., seq, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
