"""Top-level language model: embed -> block stack -> norm -> head -> loss.

Modality frontends (paper-assigned [audio]/[vlm] archs) are STUBS: the batch
may carry ``prefix_emb`` — precomputed frame/patch embeddings [B, P, d] —
which are concatenated ahead of the token embeddings; the loss is computed on
token positions only.

Entry points return pure functions suitable for jax.jit + .lower():
  make_loss_fn      (params, batch) -> (loss, metrics)
  make_prefill_fn   (params, batch) -> (last_logits, cache)
  make_decode_fn    (params, cache, batch) -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import ShardingRules, DEFAULT_RULES, constrain
from .layers import ParamSpec, compute_view, param_logical_axes, softcap
from .transformer import (apply_norm, cache_specs, norm_specs, run_stack,
                          stack_specs)

__all__ = ["model_specs", "make_loss_fn", "make_prefill_fn", "make_decode_fn",
           "cache_specs", "cross_entropy"]


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "d_model"), "normal", 0.02),
        "blocks": stack_specs(cfg),
        "final_norm": norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("d_model", "vocab"), "scaled")
    return specs


def _embed(params, tokens: jax.Array, cfg: ModelConfig,
           rules: ShardingRules) -> jax.Array:
    table = compute_view(params["embed"], ("vocab", "d_model"), rules)
    x = jnp.take(table, tokens, axis=0)
    return constrain(x, ("batch", "seq", "act_model"), rules)


def _head(params, x: jax.Array, cfg: ModelConfig,
          rules: ShardingRules) -> jax.Array:
    if cfg.tie_embeddings:
        w = compute_view(params["embed"], ("vocab", "d_model"), rules).T
    else:
        w = compute_view(params["lm_head"], ("d_model", "vocab"), rules)
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return constrain(logits, ("batch", "seq", "vocab"), rules)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Mean token NLL + accuracy.  logits: [B,S,V] f32; labels: [B,S]."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    acc = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom, jnp.sum(acc * mask) / denom


def _forward(params, tokens, prefix_emb, cfg: ModelConfig,
             rules: ShardingRules, mode: str, states=None, pos=None):
    """Shared trunk.  Returns (x_tokens [B,S,d], aux, new_states)."""
    x = _embed(params, tokens, cfg, rules)
    P = 0
    if prefix_emb is not None:
        P = prefix_emb.shape[1]
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    if mode == "decode":
        positions = pos
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux, new_states = run_stack(params["blocks"], x, positions, cfg,
                                   rules, mode, states)
    x = apply_norm(params["final_norm"], x, cfg)
    if P:
        x = x[:, P:]
    return x, aux, new_states


def _chunked_nll(params, x, labels, mask, cfg: ModelConfig,
                 rules: ShardingRules) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing the full [B,S,V] f32 logits:
    scan over sequence chunks (perf lever ``loss_chunk``, §Perf)."""
    if cfg.tie_embeddings:
        w = compute_view(params["embed"], ("vocab", "d_model"), rules).T
    else:
        w = compute_view(params["lm_head"], ("d_model", "vocab"), rules)
    B, S, _ = x.shape
    c = cfg.loss_chunk
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = x.shape[1] // c
    xs = (x.reshape(B, nc, c, -1).transpose(1, 0, 2, 3),
          labels.reshape(B, nc, c).transpose(1, 0, 2),
          mask.reshape(B, nc, c).transpose(1, 0, 2))

    def body(carry, inp):
        nll_s, acc_s, cnt = carry
        xb, lb, mb = inp
        logits = jnp.einsum("bsd,dv->bsv", xb, w,
                            preferred_element_type=jnp.float32)
        if cfg.logit_softcap:
            logits = softcap(logits, cfg.logit_softcap)
        logits = constrain(logits, ("batch", "seq", "vocab"), rules)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        hit = (jnp.argmax(logits, axis=-1) == lb).astype(jnp.float32)
        return (nll_s + jnp.sum((lse - gold) * mb),
                acc_s + jnp.sum(hit * mb), cnt + jnp.sum(mb)), None

    (nll_s, acc_s, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), xs)
    cnt = jnp.maximum(cnt, 1.0)
    return nll_s / cnt, acc_s / cnt


def make_loss_fn(cfg: ModelConfig, rules: ShardingRules = DEFAULT_RULES
                 ) -> Callable:
    def loss_fn(params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x, aux, _ = _forward(params, batch["tokens"],
                             batch.get("prefix_emb"), cfg, rules, "train")
        mask = batch.get("loss_mask")
        if cfg.loss_chunk:
            nll, acc = _chunked_nll(
                params, x, batch["labels"],
                jnp.ones_like(batch["labels"], jnp.float32)
                if mask is None else mask, cfg, rules)
        else:
            logits = _head(params, x, cfg, rules)
            nll, acc = cross_entropy(logits, batch["labels"], mask)
        loss = nll
        metrics = {"nll": nll, "accuracy": acc}
        for k, v in aux.items():
            loss = loss + v
            metrics[k] = v
        metrics["loss"] = loss
        return loss, metrics
    return loss_fn


def make_prefill_fn(cfg: ModelConfig, rules: ShardingRules = DEFAULT_RULES
                    ) -> Callable:
    def prefill_fn(params, batch):
        x, _, states = _forward(params, batch["tokens"],
                                batch.get("prefix_emb"), cfg, rules,
                                "prefill")
        logits = _head(params, x[:, -1:], cfg, rules)[:, 0]
        return logits, states
    return prefill_fn


def make_decode_fn(cfg: ModelConfig, rules: ShardingRules = DEFAULT_RULES
                   ) -> Callable:
    def decode_fn(params, cache, batch):
        """batch: {"token": [B,1] int32, "pos": scalar int32}."""
        x, _, cache = _forward(params, batch["token"], None, cfg, rules,
                               "decode", states=cache, pos=batch["pos"])
        logits = _head(params, x, cfg, rules)[:, 0]
        return logits, cache
    return decode_fn
