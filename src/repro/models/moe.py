"""Mixture-of-Experts FFN: top-k routing with capacity (GShard-style).

Baseline ("paper-faithful" substrate): dense one-hot dispatch/combine einsums
with a capacity bound — experts sharded over `model` (EP); GSPMD lowers the
dispatch to the MToNPartitioning exchange (all-to-all) exactly where the
partitioning changes from token-partitioned to expert-partitioned.

The optimized path (sort-based dispatch, see training/hillclimbs) is selected
by ``dispatch="sort"``; it replaces the O(S·E·C·d) one-hot einsums with
argsort + gather (near-zero dispatch FLOPs) at the price of explicit
collective control.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import ShardingRules, DEFAULT_RULES, constrain
from .layers import ParamSpec

__all__ = ["moe_specs", "moe_ffn", "router_aux_losses"]


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    specs = {
        "router": ParamSpec((d, e), ("d_model", "experts"), "scaled"),
        "wo": ParamSpec((e, ff, d), ("experts", "d_ff", "d_model"), "scaled"),
    }
    if cfg.ffn_kind == "swiglu":
        specs["wg"] = ParamSpec((e, d, ff), ("experts", "d_model", "d_ff"), "scaled")
        specs["wu"] = ParamSpec((e, d, ff), ("experts", "d_model", "d_ff"), "scaled")
    else:
        specs["wi"] = ParamSpec((e, d, ff), ("experts", "d_model", "d_ff"), "scaled")
    return specs


def _expert_ffn(xe: jax.Array, params, cfg: ModelConfig) -> jax.Array:
    """xe: [..., E, C, d] -> [..., E, C, d]; per-expert FFN."""
    if cfg.ffn_kind == "swiglu":
        g = jnp.einsum("...ecd,edf->...ecf", xe, params["wg"],
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("...ecd,edf->...ecf", xe, params["wu"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xe.dtype)
    else:
        h = jnp.einsum("...ecd,edf->...ecf", xe, params["wi"],
                       preferred_element_type=jnp.float32)
        h = jax.nn.gelu(h).astype(xe.dtype)
    from .attention import _out_pref
    return jnp.einsum("...ecf,efd->...ecd", h, params["wo"],
                      preferred_element_type=_out_pref(cfg)).astype(xe.dtype)


def moe_ffn(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
            rules: ShardingRules = DEFAULT_RULES,
            dispatch: str = "einsum",
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, d] -> (y, aux_losses)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum("bsd,de->bse", x, params["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # f32
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # [B,S,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    aux = router_aux_losses(logits, probs, expert_idx, cfg)

    if dispatch == "sort":
        y = _sort_dispatch(params, x, expert_idx, gate_vals, cfg, rules)
        return y, aux

    # --- dense one-hot dispatch with capacity ---------------------------
    # Peak memory is kept at O(B*S*E*C) by accumulating the K routing slots
    # one at a time instead of materializing the [B,S,K,E,C] tensor.
    C = max(1, int(S * K / E * cfg.capacity_factor))
    assign = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)   # [B,S,K,E]
    # position of each (token, k) within its expert queue
    pos = jnp.cumsum(assign.reshape(B, S * K, E), axis=1).reshape(
        B, S, K, E) * assign - 1.0
    keep = (pos >= 0) & (pos < C)
    pos = jnp.where(keep, pos, 0.0).astype(jnp.int32)
    dispatch_m = jnp.zeros((B, S, E, C), jnp.float32)
    combine = jnp.zeros((B, S, E, C), jnp.float32)
    for kk in range(K):
        slot_k = jax.nn.one_hot(pos[:, :, kk], C, dtype=jnp.float32) * \
            keep[:, :, kk, :, None].astype(jnp.float32)         # [B,S,E,C]
        slot_k = constrain(slot_k, ("batch", "seq", "act_experts", None),
                           rules)
        dispatch_m = dispatch_m + slot_k
        combine = combine + slot_k * gate_vals[:, :, kk, None, None]
    dispatch_m = constrain(dispatch_m.astype(x.dtype),
                           ("batch", "seq", "act_experts", None), rules)
    combine = constrain(combine, ("batch", "seq", "act_experts", None), rules)

    xe = jnp.einsum("bsec,bsd->becd", dispatch_m, x,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xe = constrain(xe, ("batch", "act_experts", None, "act_model"), rules)
    ye = _expert_ffn(xe, params, cfg)
    ye = constrain(ye, ("batch", "act_experts", None, "act_model"), rules)
    y = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), ye,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return constrain(y, ("batch", "seq_blocks", "act_model"), rules), aux


def _sort_dispatch(params, x, expert_idx, gate_vals, cfg: ModelConfig,
                   rules: ShardingRules) -> jax.Array:
    """Optimized dispatch: argsort tokens by expert, segment the flat stream,
    run the expert FFN on contiguous slices, and scatter back.  Dispatch cost
    drops from O(S·E·C·d) matmul FLOPs to O(S·K log(S·K)) sort + gathers.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = S * K
    C = max(1, int(S * K / E * cfg.capacity_factor))

    def per_batch(xb, idxb, gateb):
        flat_e = idxb.reshape(T)                       # expert of each slot
        flat_t = jnp.repeat(jnp.arange(S), K)          # source token
        flat_g = gateb.reshape(T)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        # rank within expert = position - first-position-of-expert
        first = jnp.searchsorted(se, jnp.arange(E), side="left")
        rank = jnp.arange(T) - first[se]
        keep = rank < C
        slot_idx = jnp.where(keep, se * C + rank, E * C)   # overflow bucket
        xe_flat = jnp.zeros((E * C + 1, d), xb.dtype).at[slot_idx].set(
            jnp.where(keep[:, None], xb[st], 0))
        xe = xe_flat[:E * C].reshape(E, C, d)
        ye = _expert_ffn(xe[None], params, cfg)[0]         # [E, C, d]
        contrib = ye.reshape(E * C, d)
        safe_slot = jnp.minimum(slot_idx, E * C - 1)
        y_tok = jnp.where(keep[:, None], contrib[safe_slot], 0) * sg[:, None]
        return jnp.zeros((S, d), xb.dtype).at[st].add(y_tok.astype(xb.dtype))

    y = jax.vmap(per_batch)(x, expert_idx, gate_vals)
    return constrain(y, ("batch", "seq", "act_model"), rules)


def router_aux_losses(logits: jax.Array, probs: jax.Array,
                      expert_idx: jax.Array,
                      cfg: ModelConfig) -> Dict[str, jax.Array]:
    """Switch-style load-balance loss + router z-loss (on raw logits)."""
    E = cfg.num_experts
    # fraction of routed (token, k) slots landing on each expert
    counts = jnp.mean(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32),
                      axis=(0, 1, 2))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    balance = E * jnp.sum(counts * mean_prob)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return {"moe_balance": cfg.router_aux_coef * balance,
            "moe_zloss": cfg.router_z_coef * z}
