"""Mamba selective-SSM mixer (for the jamba hybrid arch).

Training/prefill: causal depthwise conv + selective scan.  The scan is
h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t;  y_t = C_t . h_t + D * x_t
— a first-order linear recurrence, associative in (a, b) pairs, which is the
same algebraic shape as the LSM/logsumexp merges used elsewhere
(docs/ARCHITECTURE.md §Mesh and collectives):
partial states combine in any grouping.  We exploit that with a *chunked*
scan: within a chunk of ``seq_chunk`` steps an associative scan runs in
parallel (VPU-friendly); across chunks a cheap sequential carry propagates.

Decode: O(1) state update per token (conv ring + ssm state).

Sharding: ``ssm_inner`` (the expanded channel dim) is TP-sharded over `model`;
the recurrence is elementwise over channels so no collective is needed inside
the scan — the Hyracks OneToOne connector case.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import ShardingRules, DEFAULT_RULES, constrain
from .layers import ParamSpec

__all__ = ["ssm_specs", "mamba_mixer", "mamba_decode", "init_mamba_state",
           "selective_scan"]


def ssm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di = cfg.d_model, cfg.ssm_inner
    st, k, dtr = cfg.ssm_state, cfg.ssm_conv, cfg.resolved_dt_rank
    return {
        "in_proj": ParamSpec((d, 2 * di), ("d_model", "ssm_inner"), "scaled"),
        "conv_w": ParamSpec((k, di), ("conv_k", "ssm_inner"), "scaled", 1.0),
        "conv_b": ParamSpec((di,), ("ssm_inner",), "zeros"),
        # x -> (dt_rank, B, C) low-rank selective params
        "x_proj": ParamSpec((di, dtr + 2 * st), ("ssm_inner", None), "scaled"),
        "dt_proj_w": ParamSpec((dtr, di), (None, "ssm_inner"), "scaled"),
        "dt_proj_b": ParamSpec((di,), ("ssm_inner",), "ones", dtype=jnp.float32),
        "A_log": ParamSpec((di, st), ("ssm_inner", "ssm_state"), "ssm_a",
                           dtype=jnp.float32),
        "D": ParamSpec((di,), ("ssm_inner",), "ones", dtype=jnp.float32),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "d_model"), "scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv over time.  x: [B, S, di]; w: [k, di].

    ``prev`` ([B, k-1, di]) carries history for chunked/decoding calls.
    """
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)                    # [B, S+k-1, di]
    # sum_j w[j] * x[t - (k-1) + j]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1], :].astype(jnp.float32) \
            * w[j].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _selective_terms(x: jax.Array, params, cfg: ModelConfig
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-step decay/input terms.  x: [..., di] (post-conv, post-silu).

    Returns (a, bx, C, dt):  a = exp(dt*A) [..., di, st],
    bx = dt * B ⊗ x [..., di, st], C [..., st], dt [..., di].
    """
    st, dtr = cfg.ssm_state, cfg.resolved_dt_rank
    proj = jnp.einsum("...d,dp->...p", x, params["x_proj"],
                      preferred_element_type=jnp.float32)
    dt_lr, B, C = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,rd->...d", dt_lr, params["dt_proj_w"],
                   preferred_element_type=jnp.float32)
        + params["dt_proj_b"])                                  # [..., di]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))           # [di, st]
    a = jnp.exp(dt[..., None] * A)                              # [..., di, st]
    bx = (dt * x.astype(jnp.float32))[..., None] * B[..., None, :]
    return a, bx, C, dt


def selective_scan(x: jax.Array, params, cfg: ModelConfig,
                   h0: Optional[jax.Array] = None,
                   ) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective scan.  x: [B, S, di] -> (y [B, S, di], h [B, di, st]).

    Within each ``seq_chunk`` the linear recurrence runs as an associative
    scan (parallel over the chunk); the carry crosses chunks sequentially.
    """
    Bb, S, di = x.shape
    st = cfg.ssm_state
    chunk = min(cfg.seq_chunk, S)
    valid = jnp.ones((Bb, S), bool)
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
        S_pad = x.shape[1]
    else:
        S_pad = S
    nchunks = S_pad // chunk
    xc = x.reshape(Bb, nchunks, chunk, di).transpose(1, 0, 2, 3)
    vc = valid.reshape(Bb, nchunks, chunk).transpose(1, 0, 2)
    if h0 is None:
        h0 = jnp.zeros((Bb, di, st), jnp.float32)

    def chunk_step(h, inp):
        xj, vj = inp
        a, bx, C, _ = _selective_terms(xj, params, cfg)   # [B,c,di,st] x2
        # padded steps are identity transitions: a=1, bx=0 (keeps the carried
        # state exact so prefill->decode hand-off matches the unpadded run)
        a = jnp.where(vj[..., None, None], a, 1.0)
        bx = jnp.where(vj[..., None, None], bx, 0.0)
        # associative scan over the chunk: (a2,b2) ∘ (a1,b1) = (a1*a2, b1*a2+b2)
        def combine(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])
        a_cum, b_cum = jax.lax.associative_scan(combine, (a, bx), axis=1)
        hs = a_cum * h[:, None] + b_cum                   # [B,c,di,st]
        y = jnp.einsum("bcds,bcs->bcd", hs, C,
                       preferred_element_type=jnp.float32)
        y = y + params["D"].astype(jnp.float32) * xj.astype(jnp.float32)
        return hs[:, -1], y.astype(x.dtype)

    h, yc = jax.lax.scan(chunk_step, h0, (xc, vc))
    y = yc.transpose(1, 0, 2, 3).reshape(Bb, S_pad, di)[:, :S]
    return y, h


def mamba_mixer(params: Dict[str, jax.Array], x: jax.Array,
                positions: jax.Array, cfg: ModelConfig,
                rules: ShardingRules = DEFAULT_RULES) -> jax.Array:
    """Full mamba block body (sans residual/norm).  x: [B, S, d]."""
    del positions
    xz = jnp.einsum("bsd,dz->bsz", x, params["in_proj"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, ("batch", "seq", "ssm_inner_act"), rules)
    xin = _causal_conv(xin, params["conv_w"], params["conv_b"])
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    y, _ = selective_scan(xin, params, cfg)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = constrain(y, ("batch", "seq", "ssm_inner_act"), rules)
    from .attention import _out_pref
    out = jnp.einsum("bsz,zd->bsd", y, params["out_proj"],
                     preferred_element_type=_out_pref(cfg)).astype(x.dtype)
    return constrain(out, ("batch", "seq_blocks", "act_model"), rules)


def mamba_prefill(params: Dict[str, jax.Array], x: jax.Array,
                  positions: jax.Array, cfg: ModelConfig,
                  rules: ShardingRules = DEFAULT_RULES,
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Like mamba_mixer but also returns the recurrent state for decoding."""
    del positions
    k = cfg.ssm_conv
    xz = jnp.einsum("bsd,dz->bsz", x, params["in_proj"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    raw, z = jnp.split(xz, 2, axis=-1)
    raw = constrain(raw, ("batch", "seq", "ssm_inner_act"), rules)
    xin = _causal_conv(raw, params["conv_w"], params["conv_b"])
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    y, h = selective_scan(xin, params, cfg)
    # conv ring = last k-1 pre-conv inputs (pad left if seq < k-1)
    pad = jnp.zeros((x.shape[0], max(0, k - 1 - x.shape[1]), raw.shape[-1]),
                    raw.dtype)
    ring = jnp.concatenate([pad, raw[:, -(k - 1):]], axis=1) if k > 1 else \
        jnp.zeros((x.shape[0], 0, raw.shape[-1]), raw.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    from .attention import _out_pref
    out = jnp.einsum("bsz,zd->bsd", y, params["out_proj"],
                     preferred_element_type=_out_pref(cfg)).astype(x.dtype)
    out = constrain(out, ("batch", "seq", "act_model"), rules)
    return out, {"conv": ring, "ssm": h}


# ---------------------------------------------------------------------------
# Decode: O(1) recurrent update
# ---------------------------------------------------------------------------

def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                     ) -> Dict[str, jax.Array]:
    di = cfg.ssm_inner
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(params: Dict[str, jax.Array], x: jax.Array,
                 state: Dict[str, jax.Array], pos: jax.Array,
                 cfg: ModelConfig,
                 rules: ShardingRules = DEFAULT_RULES,
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One token.  x: [B, 1, d] -> (y [B, 1, d], new state)."""
    del pos
    Bb = x.shape[0]
    xz = jnp.einsum("bsd,dz->bsz", x, params["in_proj"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    raw, z = jnp.split(xz, 2, axis=-1)                   # pre-conv input
    xin = _causal_conv(raw, params["conv_w"], params["conv_b"],
                       prev=state["conv"])
    # the ring carries the *pre-conv* inputs of the last k-1 steps
    conv_new = jnp.concatenate(
        [state["conv"][:, 1:], raw[:, :1].astype(state["conv"].dtype)],
        axis=1) if cfg.ssm_conv > 1 else state["conv"]
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    a, bx, C, _ = _selective_terms(xin[:, 0], params, cfg)   # [B,di,st]
    h = a * state["ssm"] + bx
    y = jnp.einsum("bds,bs->bd", h, C, preferred_element_type=jnp.float32)
    y = y + params["D"].astype(jnp.float32) * xin[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype)
    from .attention import _out_pref
    out = jnp.einsum("bsz,zd->bsd", y, params["out_proj"],
                     preferred_element_type=_out_pref(cfg)).astype(x.dtype)
    return out, {"conv": conv_new, "ssm": h}
