"""Composable decoder blocks + scan-over-layers stack.

A model is ``num_layers`` blocks following ``cfg.block_pattern`` — a cycle of
(mixer, ffn) pairs, e.g. jamba's 8-layer Mamba/attention/MoE interleave.  The
stack scans over *cycles* (all cycles share the pattern, so parameters stack
with a leading ``layers`` axis); within a cycle the pattern positions apply
sequentially.  This keeps the HLO size O(pattern) instead of O(num_layers) —
essential for 95-layer dry-runs — and gives remat a natural unit.

Modes: ``train`` (no state), ``prefill`` (returns per-layer recurrent/KV
state), ``decode`` (consumes + returns state).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import ShardingRules, DEFAULT_RULES, constrain
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import ParamSpec, compute_view, layer_norm, rms_norm

__all__ = ["mlp_specs", "mlp_apply", "block_specs", "stack_specs",
           "run_stack", "cache_specs", "stacked"]


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.ffn_kind == "swiglu":
        specs = {
            "wg": ParamSpec((d, ff), ("d_model", "d_ff"), "scaled"),
            "wu": ParamSpec((d, ff), ("d_model", "d_ff"), "scaled"),
            "wo": ParamSpec((ff, d), ("d_ff", "d_model"), "scaled"),
        }
    else:
        specs = {
            "wi": ParamSpec((d, ff), ("d_model", "d_ff"), "scaled"),
            "wo": ParamSpec((ff, d), ("d_ff", "d_model"), "scaled"),
        }
    if cfg.use_bias:
        specs["bi"] = ParamSpec((ff,), ("act_ff",), "zeros")
        specs["bo"] = ParamSpec((d,), ("act_model",), "zeros")
    return specs


def mlp_apply(params, x: jax.Array, cfg: ModelConfig,
              rules: ShardingRules) -> jax.Array:
    if cfg.ffn_kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["wg"],
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("bsd,df->bsf", x, params["wu"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
    else:
        h = jnp.einsum("bsd,df->bsf", x, params["wi"],
                       preferred_element_type=jnp.float32)
        if cfg.use_bias:
            h = h + params["bi"].astype(jnp.float32)
        h = jax.nn.gelu(h).astype(x.dtype)
    h = constrain(h, ("batch", "seq", "act_ff"), rules)
    from .attention import _out_pref
    y = jnp.einsum("bsf,fd->bsd", h, params["wo"],
                   preferred_element_type=_out_pref(cfg)).astype(x.dtype)
    if cfg.use_bias:
        y = y + params["bo"].astype(y.dtype)
    return constrain(y, ("batch", "seq_blocks", "act_model"), rules)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    specs = {"scale": ParamSpec((cfg.d_model,), ("act_model",), "ones")}
    if cfg.norm_kind == "layernorm" and cfg.use_bias:
        specs["bias"] = ParamSpec((cfg.d_model,), ("act_model",), "zeros")
    return specs


def apply_norm(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.norm_kind == "layernorm":
        return layer_norm(x, params["scale"], params.get("bias"))
    return rms_norm(x, params["scale"])


# ---------------------------------------------------------------------------
# One block = norm -> mixer -> residual [-> norm -> ffn -> residual]
# ---------------------------------------------------------------------------

_MIXER_SPECS = {
    "attn": attn_mod.attention_specs,
    "mamba": ssm_mod.ssm_specs,
    "mlstm": xlstm_mod.mlstm_specs,
    "slstm": xlstm_mod.slstm_specs,
}


def block_specs(cfg: ModelConfig, mixer: str, ffn: str) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "norm1": norm_specs(cfg),
        "mixer": _MIXER_SPECS[mixer](cfg),
    }
    if ffn == "mlp":
        specs["norm2"] = norm_specs(cfg)
        specs["ffn"] = mlp_specs(cfg)
    elif ffn == "moe":
        specs["norm2"] = norm_specs(cfg)
        specs["ffn"] = moe_mod.moe_specs(cfg)
    elif ffn != "none":
        raise ValueError(ffn)
    return specs


def _apply_mixer(params, x, positions, cfg, mixer, rules, mode, state):
    """Returns (y, new_state)."""
    if mode == "train":
        fn = {"attn": attn_mod.attention, "mamba": ssm_mod.mamba_mixer,
              "mlstm": xlstm_mod.mlstm_mixer,
              "slstm": xlstm_mod.slstm_mixer}[mixer]
        return fn(params, x, positions, cfg, rules), None
    if mode == "prefill":
        fn = {"attn": attn_mod.attention_prefill,
              "mamba": ssm_mod.mamba_prefill,
              "mlstm": xlstm_mod.mlstm_prefill,
              "slstm": xlstm_mod.slstm_prefill}[mixer]
        return fn(params, x, positions, cfg, rules)
    if mode == "decode":
        attn_fn = (attn_mod.decode_attention_tiered
                   if cfg.kv_layout == "tiered"
                   else attn_mod.decode_attention)
        fn = {"attn": attn_fn,
              "mamba": ssm_mod.mamba_decode,
              "mlstm": xlstm_mod.mlstm_decode,
              "slstm": xlstm_mod.slstm_decode}[mixer]
        return fn(params, x, state, positions, cfg, rules)
    raise ValueError(mode)


def block_apply(params, x, positions, cfg: ModelConfig, mixer: str, ffn: str,
                rules: ShardingRules, mode: str = "train",
                state: Any = None) -> Tuple[jax.Array, Dict, Any]:
    """x: [B, S, d] -> (x', aux_losses, new_state)."""
    h = apply_norm(params["norm1"], x, cfg)
    mixed, new_state = _apply_mixer(params["mixer"], h, positions, cfg,
                                    mixer, rules, mode, state)
    x = x + mixed
    aux: Dict[str, jax.Array] = {}
    if ffn != "none":
        h = apply_norm(params["norm2"], x, cfg)
        if ffn == "moe":
            y, aux = moe_mod.moe_ffn(params["ffn"], h, cfg, rules,
                                     dispatch=cfg_dispatch(cfg))
        else:
            y = mlp_apply(params["ffn"], h, cfg, rules)
        x = x + y
    x = constrain(x, ("batch", "seq_blocks", "act_model"), rules)
    return x, aux, new_state


def cfg_dispatch(cfg: ModelConfig) -> str:
    return cfg.moe_dispatch or "einsum"


# ---------------------------------------------------------------------------
# Layer stack (scan over cycles)
# ---------------------------------------------------------------------------

def stacked(specs: Any, n: int) -> Any:
    """Prepend a ``layers`` axis of size n to every ParamSpec leaf."""
    if isinstance(specs, ParamSpec):
        return ParamSpec((n,) + specs.shape, ("layers",) + specs.logical_axes,
                         specs.init, specs.scale, specs.dtype)
    return {k: stacked(v, n) for k, v in specs.items()}


def stack_specs(cfg: ModelConfig) -> Dict[str, Any]:
    pattern = cfg.layer_pattern
    cycles = cfg.num_layers // len(pattern)
    per_pos = {f"pos{i}": block_specs(cfg, m, f)
               for i, (m, f) in enumerate(pattern)}
    if cfg.scan_layers and cycles > 1:
        return stacked(per_pos, cycles)
    if cycles == 1:
        return per_pos
    # unrolled variant (debug / tiny models)
    return {f"cycle{c}": per_pos if c == 0 else
            {f"pos{i}": block_specs(cfg, m, f)
             for i, (m, f) in enumerate(pattern)}
            for c in range(cycles)}


def _remat_wrap(fn: Callable, policy: str) -> Callable:
    if policy == "nothing":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "full": save only block inputs


def _cycle_body(params_c, x, positions, cfg, rules, mode, states_c):
    pattern = cfg.layer_pattern
    aux_sum: Dict[str, jax.Array] = {}
    new_states = {}
    for i, (mixer, ffn) in enumerate(pattern):
        st = states_c.get(f"pos{i}") if states_c else None
        # FSDP weight-gathering at point of use (per-cycle all-gather of the
        # data-axis weight shards; reduce-scatter of grads in backward)
        from .layers import param_logical_axes
        axes_i = param_logical_axes(block_specs(cfg, mixer, ffn))
        p_i = compute_view(params_c[f"pos{i}"], axes_i, rules)
        x, aux, new_st = block_apply(p_i, x, positions, cfg,
                                     mixer, ffn, rules, mode, st)
        for k, v in aux.items():
            aux_sum[k] = aux_sum.get(k, 0.0) + v
        if new_st is not None:
            new_states[f"pos{i}"] = new_st
    return x, aux_sum, new_states


def run_stack(params, x: jax.Array, positions, cfg: ModelConfig,
              rules: ShardingRules = DEFAULT_RULES, mode: str = "train",
              states: Any = None) -> Tuple[jax.Array, Dict, Any]:
    """Apply all layers.  ``states`` (prefill out / decode in+out) is a pytree
    with leaves stacked over cycles when scanning."""
    pattern = cfg.layer_pattern
    cycles = cfg.num_layers // len(pattern)

    if not (cfg.scan_layers and cycles > 1):
        # plain loop (cycles == 1 or scan disabled)
        aux_sum: Dict[str, jax.Array] = {}
        out_states = {}
        for c in range(cycles):
            p_c = params if cycles == 1 else params[f"cycle{c}"]
            s_c = None
            if states is not None:
                s_c = states if cycles == 1 else states[f"cycle{c}"]
            x, aux, new_s = _cycle_body(p_c, x, positions, cfg, rules, mode,
                                        s_c)
            for k, v in aux.items():
                aux_sum[k] = aux_sum.get(k, 0.0) + v
            if new_s:
                if cycles == 1:
                    out_states = new_s
                else:
                    out_states[f"cycle{c}"] = new_s
        return x, aux_sum, (out_states or None)

    # ---- scan over cycles -------------------------------------------------
    def body(carry, xs):
        xc, aux_acc = carry
        params_c, states_c = xs
        xc, aux, new_states = _cycle_body(params_c, xc, positions, cfg,
                                          rules, mode, states_c)
        aux_acc = {k: aux_acc.get(k, 0.0) + aux.get(k, 0.0)
                   for k in set(aux_acc) | set(aux)}
        return (xc, aux_acc), (new_states or 0)

    if mode == "train":
        body = _remat_wrap(body, cfg.remat_policy)

    aux0: Dict[str, jax.Array] = {}
    if any(f == "moe" for _, f in pattern):
        aux0 = {"moe_balance": jnp.zeros((), jnp.float32),
                "moe_zloss": jnp.zeros((), jnp.float32)}
    (x, aux_sum), ys = jax.lax.scan(body, (x, aux0), (params, states))
    new_states = ys if states is not None or mode == "prefill" else None
    if isinstance(new_states, int):
        new_states = None
    return x, aux_sum, new_states


# ---------------------------------------------------------------------------
# Recurrent/KV cache specs (decode & prefill states)
# ---------------------------------------------------------------------------

def _mixer_state_specs(cfg: ModelConfig, mixer: str, batch: int,
                       max_len: int) -> Optional[Dict[str, ParamSpec]]:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    di = cfg.ssm_inner
    if mixer == "attn" and cfg.kv_layout == "tiered":
        from ..kvcache.lsm_cache import cache_config_for
        cc = cache_config_for(max_len, cfg.kv_tail_cap, cfg.kv_l1_comps)
        kvh = ("batch", "kv_seq", "act_kv_heads", "head_dim")
        scalar = lambda: ParamSpec((), (), "zeros", dtype=jnp.int32)
        return {
            "tail_k": ParamSpec((batch, cc.tail_cap, kv, hd),
                                ("batch", None, "act_kv_heads", "head_dim"),
                                "zeros", dtype=jnp.bfloat16),
            "tail_v": ParamSpec((batch, cc.tail_cap, kv, hd),
                                ("batch", None, "act_kv_heads", "head_dim"),
                                "zeros", dtype=jnp.bfloat16),
            "tail_len": scalar(),
            "l1_k": ParamSpec((cc.l1_comps, batch, cc.tail_cap, kv, hd),
                              (None, "batch", None, "act_kv_heads",
                               "head_dim"), "zeros", dtype=jnp.bfloat16),
            "l1_v": ParamSpec((cc.l1_comps, batch, cc.tail_cap, kv, hd),
                              (None, "batch", None, "act_kv_heads",
                               "head_dim"), "zeros", dtype=jnp.bfloat16),
            "l1_count": scalar(),
            "l2_k": ParamSpec((batch, cc.max_len, kv, hd), kvh, "zeros",
                              dtype=jnp.bfloat16),
            "l2_v": ParamSpec((batch, cc.max_len, kv, hd), kvh, "zeros",
                              dtype=jnp.bfloat16),
            "l2_len": scalar(),
            "flushes": scalar(),
            "merges": scalar(),
        }
    if mixer == "attn":
        return {
            "k": ParamSpec((batch, max_len, kv, hd),
                           ("batch", "kv_seq", "act_kv_heads", "head_dim"),
                           "zeros", dtype=jnp.bfloat16),
            "v": ParamSpec((batch, max_len, kv, hd),
                           ("batch", "kv_seq", "act_kv_heads", "head_dim"),
                           "zeros", dtype=jnp.bfloat16),
        }
    if mixer == "mamba":
        return {
            "conv": ParamSpec((batch, cfg.ssm_conv - 1, di),
                              ("batch", None, "ssm_inner_act"), "zeros",
                              dtype=jnp.bfloat16),
            "ssm": ParamSpec((batch, di, cfg.ssm_state),
                             ("batch", "ssm_inner_act", None), "zeros",
                             dtype=jnp.float32),
        }
    if mixer == "mlstm":
        mi = 2 * cfg.d_model
        nh = cfg.xlstm_heads
        dh = mi // nh
        return {
            "conv": ParamSpec((batch, cfg.ssm_conv - 1, mi),
                              ("batch", None, "ssm_inner_act"), "zeros",
                              dtype=jnp.bfloat16),
            "C": ParamSpec((batch, nh, dh, dh), ("batch", None, None, None),
                           "zeros", dtype=jnp.float32),
            "n": ParamSpec((batch, nh, dh), ("batch", None, None), "zeros",
                           dtype=jnp.float32),
            "m": ParamSpec((batch, nh), ("batch", None), "zeros",
                           dtype=jnp.float32),
        }
    if mixer == "slstm":
        d = cfg.d_model
        return {k: ParamSpec((batch, d), ("batch", "act_model"),
                             "ones" if k == "n" else "zeros",
                             dtype=jnp.float32)
                for k in ("c", "n", "m", "h")}
    raise ValueError(mixer)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Decode-state ParamSpec tree matching run_stack's ``states`` layout."""
    pattern = cfg.layer_pattern
    cycles = cfg.num_layers // len(pattern)
    per_pos = {f"pos{i}": _mixer_state_specs(cfg, m, batch, max_len)
               for i, (m, _) in enumerate(pattern)}
    per_pos = {k: v for k, v in per_pos.items() if v is not None}
    if cfg.scan_layers and cycles > 1:
        return stacked(per_pos, cycles)
    if cycles == 1:
        return per_pos
    return {f"cycle{c}": {f"pos{i}": _mixer_state_specs(cfg, m, batch, max_len)
                          for i, (m, _) in enumerate(pattern)}
            for c in range(cycles)}
