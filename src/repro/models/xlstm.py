"""xLSTM mixers: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential with recurrent gate weights).  arXiv:2405.04517.

The mLSTM recurrence
    C_t = f_t C_{t-1} + i_t k_t v_t^T,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = o_t ⊙ (C_t^T q_t) / max(|n_t^T q_t|, exp(-m_t))
is another associative first-order recurrence — the same merge algebra as the
LSM component merge (docs/ARCHITECTURE.md §Mesh and collectives) — so we
evaluate it chunkwise: a parallel
(attention-like) intra-chunk term plus a sequentially carried (C, n, m) state,
with exp-gating stabilized by the running max ``m`` exactly as flash attention
stabilizes softmax.

sLSTM has *recurrent gate weights* (h_{t-1} feeds the gates), which breaks
chunk parallelism — the paper accepts this for its state-tracking power.  We
scan over time; its cost is O(S·d·d/nh) (block-diagonal recurrent matrices).

Sharding: the expanded inner dim is TP-sharded over `model`; heads of the
125m config (4) do not divide the model axis (16) so the safe rule replicates
them (cf. runtime/sharding.py docstring).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import ShardingRules, DEFAULT_RULES, constrain
from .layers import ParamSpec
from .ssm import _causal_conv

__all__ = [
    "mlstm_specs", "mlstm_mixer", "mlstm_prefill", "mlstm_decode",
    "init_mlstm_state", "slstm_specs", "slstm_mixer", "slstm_prefill",
    "slstm_decode", "init_slstm_state",
]


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    di = 2 * cfg.d_model
    nh = cfg.xlstm_heads
    assert di % nh == 0
    return di, nh, di // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di, nh, dh = _mlstm_dims(cfg)
    return {
        "up": ParamSpec((d, 2 * di), ("d_model", "ssm_inner"), "scaled"),
        "conv_w": ParamSpec((cfg.ssm_conv, di), ("conv_k", "ssm_inner"),
                            "scaled", 1.0),
        "conv_b": ParamSpec((di,), ("ssm_inner",), "zeros"),
        "wq": ParamSpec((di, di), ("ssm_inner", None), "scaled"),
        "wk": ParamSpec((di, di), ("ssm_inner", None), "scaled"),
        "wv": ParamSpec((di, di), ("ssm_inner", None), "scaled"),
        # scalar i/f gate per head from the block input
        "w_if": ParamSpec((di, 2 * nh), ("ssm_inner", None), "scaled"),
        "b_if": ParamSpec((2 * nh,), (None,), "zeros", dtype=jnp.float32),
        "ln_scale": ParamSpec((di,), ("ssm_inner",), "ones"),
        "down": ParamSpec((di, d), ("ssm_inner", "d_model"), "scaled"),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                     ) -> Dict[str, jax.Array]:
    di, nh, dh = _mlstm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def _mlstm_qkvif(params, xc, cfg, state_conv=None):
    """Shared projections.  xc: [B, S, di] pre-conv; returns q,k,v [B,S,nh,dh],
    logi/logf [B,S,nh], new conv ring."""
    di, nh, dh = _mlstm_dims(cfg)
    conv = _causal_conv(xc, params["conv_w"], params["conv_b"],
                        prev=state_conv)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(xc.dtype)
    q = jnp.einsum("bsi,ij->bsj", conv, params["wq"],
                   preferred_element_type=jnp.float32).astype(xc.dtype)
    k = jnp.einsum("bsi,ij->bsj", conv, params["wk"],
                   preferred_element_type=jnp.float32).astype(xc.dtype)
    v = jnp.einsum("bsi,ij->bsj", xc, params["wv"],
                   preferred_element_type=jnp.float32).astype(xc.dtype)
    B, S = xc.shape[:2]
    q = q.reshape(B, S, nh, dh)
    k = k.reshape(B, S, nh, dh) / math.sqrt(dh)
    v = v.reshape(B, S, nh, dh)
    gates = jnp.einsum("bsi,ig->bsg", xc, params["w_if"],
                       preferred_element_type=jnp.float32) + params["b_if"]
    logi, logf_raw = gates[..., :nh], gates[..., nh:]
    logf = jax.nn.log_sigmoid(logf_raw)
    return q, k, v, logi, logf


def _mlstm_chunk_scan(q, k, v, logi, logf, carry, chunk: int):
    """Chunkwise stabilized mLSTM.  q,k,v: [B,S,nh,dh]; logi/logf: [B,S,nh].

    carry: (C [B,nh,dh,dh] storing C/exp(m), n [B,nh,dh], m [B,nh]).
    Returns (h [B,S,nh,dh], new carry).
    """
    B, S, nh, dh = q.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    def to_chunks(x):
        return x.reshape((B, nc, chunk) + x.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, x.ndim + 1)))

    qc, kc, vc = map(to_chunks, (q.astype(jnp.float32),
                                 k.astype(jnp.float32),
                                 v.astype(jnp.float32)))
    lic, lfc = map(to_chunks, (logi, logf))

    def step(carry, inp):
        C, n, m = carry                      # C,n already divided by exp(m)
        qj, kj, vj, li, lf = inp             # [B,c,nh,*]
        F = jnp.cumsum(lf, axis=1)           # [B,c,nh] inclusive
        total = F[:, -1]                     # [B,nh]
        # intra-chunk decay matrix: D̃[t,s] = F_t - F_s + li_s  (s <= t)
        Dt = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dt = jnp.where(tri[None, :, :, None], Dt, -1e30)   # [B,t,s,nh]
        m_intra = jnp.max(Dt, axis=2)                      # [B,c,nh]
        m_inter = F + m[:, None]                           # [B,c,nh]
        m_t = jnp.maximum(m_intra, m_inter)
        D = jnp.exp(Dt - m_t[:, :, None, :])               # [B,t,s,nh]
        S_ts = jnp.einsum("bthd,bshd->btsh", qj, kj) * D
        inter_w = jnp.exp(m_inter - m_t)                   # [B,c,nh]
        h_num = jnp.einsum("btsh,bshd->bthd", S_ts, vj) \
            + inter_w[..., None] * jnp.einsum("bthd,bhde->bthe", qj, C)
        n_dot = jnp.einsum("btsh->bth", S_ts) \
            + inter_w * jnp.einsum("bthd,bhd->bth", qj, n)
        denom = jnp.maximum(jnp.abs(n_dot), jnp.exp(-m_t))
        h = h_num / denom[..., None]
        # ---- carry update to chunk end ----
        m_next = jnp.maximum(total + m, jnp.max(
            total[:, None] - F + li, axis=1))              # [B,nh]
        kw = jnp.exp(total[:, None] - F + li - m_next[:, None])  # [B,c,nh]
        C_new = jnp.exp(total + m - m_next)[..., None, None] * C \
            + jnp.einsum("bshd,bshe,bsh->bhde", kj, vj, kw)
        n_new = jnp.exp(total + m - m_next)[..., None] * n \
            + jnp.einsum("bshd,bsh->bhd", kj, kw)
        return (C_new, n_new, m_next), h

    carry, hc = jax.lax.scan(step, carry, (qc, kc, vc, lic, lfc))
    h = hc.transpose(1, 0, 2, 3, 4).reshape(B, Sp, nh, dh)[:, :S]
    return h, carry


def _mlstm_block(params, x, cfg, rules, carry, conv_prev):
    di, nh, dh = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,dz->bsz", x, params["up"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xc, z = jnp.split(up, 2, axis=-1)
    xc = constrain(xc, ("batch", "seq", "ssm_inner_act"), rules)
    q, k, v, logi, logf = _mlstm_qkvif(params, xc, cfg, conv_prev)
    h, carry = _mlstm_chunk_scan(q, k, v, logi, logf, carry, cfg.seq_chunk)
    h = h.reshape(x.shape[0], x.shape[1], di).astype(x.dtype)
    # per-channel norm then output gate
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    h = (hf * jax.lax.rsqrt(var + 1e-5)
         * params["ln_scale"].astype(jnp.float32)).astype(x.dtype)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsz,zd->bsd", h, params["down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = constrain(out, ("batch", "seq_blocks", "act_model"), rules)
    # new conv ring = last k-1 pre-conv inputs
    kk = cfg.ssm_conv
    padn = max(0, kk - 1 - x.shape[1])
    padz = jnp.zeros((x.shape[0], padn, di), xc.dtype)
    ring = jnp.concatenate([padz, xc[:, -(kk - 1):]], axis=1) if kk > 1 \
        else jnp.zeros((x.shape[0], 0, di), xc.dtype)
    return out, carry, ring


def mlstm_mixer(params, x, positions, cfg: ModelConfig,
                rules: ShardingRules = DEFAULT_RULES) -> jax.Array:
    del positions
    carry = (jnp.zeros((x.shape[0], cfg.xlstm_heads,) + ((2 * cfg.d_model)
             // cfg.xlstm_heads,) * 2, jnp.float32),
             jnp.zeros((x.shape[0], cfg.xlstm_heads,
                        (2 * cfg.d_model) // cfg.xlstm_heads), jnp.float32),
             jnp.full((x.shape[0], cfg.xlstm_heads), -1e30, jnp.float32))
    out, _, _ = _mlstm_block(params, x, cfg, rules, carry, None)
    return out


def mlstm_prefill(params, x, positions, cfg: ModelConfig,
                  rules: ShardingRules = DEFAULT_RULES):
    del positions
    st0 = init_mlstm_state(cfg, x.shape[0], x.dtype)
    out, carry, ring = _mlstm_block(params, x, cfg, rules,
                                    (st0["C"], st0["n"], st0["m"]), None)
    return out, {"conv": ring, "C": carry[0], "n": carry[1], "m": carry[2]}


def mlstm_decode(params, x, state, pos, cfg: ModelConfig,
                 rules: ShardingRules = DEFAULT_RULES):
    """One token: sequential stabilized update.  x: [B, 1, d]."""
    del pos
    di, nh, dh = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,dz->bsz", x, params["up"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xc, z = jnp.split(up, 2, axis=-1)
    q, k, v, logi, logf = _mlstm_qkvif(params, xc, cfg, state["conv"])
    ring = jnp.concatenate([state["conv"][:, 1:],
                            xc[:, :1].astype(state["conv"].dtype)], axis=1) \
        if cfg.ssm_conv > 1 else state["conv"]
    q1, k1, v1 = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    li, lf = logi[:, 0], logf[:, 0]                     # [B,nh]
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)[..., None]
    iw = jnp.exp(li - m_new)[..., None]
    C_new = fw[..., None] * C + jnp.einsum("bhd,bhe->bhde",
                                           k1 * iw, v1)
    n_new = fw * n + k1 * iw
    num = jnp.einsum("bhd,bhde->bhe", q1, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n_new)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(x.shape[0], 1, di).astype(x.dtype)
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    h = (hf * jax.lax.rsqrt(var + 1e-5)
         * params["ln_scale"].astype(jnp.float32)).astype(x.dtype)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsz,zd->bsd", h, params["down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"conv": ring, "C": C_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, nh = cfg.d_model, cfg.xlstm_heads
    dh = d // nh
    return {
        # input weights for 4 gates (z, i, f, o), fused
        "w_in": ParamSpec((d, 4 * d), ("d_model", None), "scaled"),
        "b_in": ParamSpec((4 * d,), (None,), "zeros", dtype=jnp.float32),
        # block-diagonal recurrent weights per head per gate
        "r": ParamSpec((4, nh, dh, dh), (None, None, None, None),
                       "normal", 1.0 / math.sqrt(dh)),
        "ln_scale": ParamSpec((d,), ("act_model",), "ones"),
    }


def init_slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                     ) -> Dict[str, jax.Array]:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(params, xg, state, cfg: ModelConfig):
    """One step.  xg: [B, 4d] = W_in x + b (precomputed); returns new state."""
    d, nh = cfg.d_model, cfg.xlstm_heads
    dh = d // nh
    B = xg.shape[0]
    h_heads = state["h"].reshape(B, nh, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", h_heads,
                     params["r"].astype(jnp.float32))   # [4,B,nh,dh]
    rec = rec.reshape(4, B, d)
    g = xg.reshape(B, 4, d).transpose(1, 0, 2) + rec
    zt = jnp.tanh(g[0])
    it, ft, ot = g[1], g[2], jax.nn.sigmoid(g[3])
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + state["m"], it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(lf + state["m"] - m_new)
    c_new = f_p * state["c"] + i_p * zt
    n_new = f_p * state["n"] + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def _slstm_seq(params, x, state, cfg: ModelConfig):
    """x: [B, S, d] -> (h [B, S, d], final state).  Sequential scan."""
    xg = jnp.einsum("bsd,dg->bsg", x, params["w_in"],
                    preferred_element_type=jnp.float32) + params["b_in"]

    def step(st, xg_t):
        st2 = _slstm_cell(params, xg_t, st, cfg)
        return st2, st2["h"]

    state, hs = jax.lax.scan(step, state, xg.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), state


def _slstm_out(params, hs, x, rules):
    hf = hs.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    out = (hf * jax.lax.rsqrt(var + 1e-5)
           * params["ln_scale"].astype(jnp.float32)).astype(x.dtype)
    return constrain(out, ("batch", "seq_blocks", "act_model"), rules)


def slstm_mixer(params, x, positions, cfg: ModelConfig,
                rules: ShardingRules = DEFAULT_RULES) -> jax.Array:
    del positions
    hs, _ = _slstm_seq(params, x, init_slstm_state(cfg, x.shape[0]), cfg)
    return _slstm_out(params, hs, x, rules)


def slstm_prefill(params, x, positions, cfg: ModelConfig,
                  rules: ShardingRules = DEFAULT_RULES):
    del positions
    hs, state = _slstm_seq(params, x, init_slstm_state(cfg, x.shape[0]), cfg)
    return _slstm_out(params, hs, x, rules), state


def slstm_decode(params, x, state, pos, cfg: ModelConfig,
                 rules: ShardingRules = DEFAULT_RULES):
    del pos
    xg = jnp.einsum("bsd,dg->bsg", x, params["w_in"],
                    preferred_element_type=jnp.float32) + params["b_in"]
    st = _slstm_cell(params, xg[:, 0], state, cfg)
    return _slstm_out(params, st["h"][:, None], x, rules), st
