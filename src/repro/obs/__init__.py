"""Observability layer: span tracer + process-wide metrics registry.

This package is the measurement substrate the perf roadmap asserts
against (ROADMAP items 1–3): a nested span tracer with Chrome-trace
export (``tracer``) and a counters/gauges/histograms registry
(``metrics``), plus the one helper every kernel wrapper calls to account
dispatches and host<->device transfer bytes (``record_dispatch``).

Tracing is off by default and near-free when off (one module-flag check,
zero allocations).  Metrics counters are always on — they instrument
per-call/per-batch paths only, never per-row ones.

Span naming convention (``obs.span(name, **attrs)``):

  exec.<OP_KIND>          one executor operator, row/fallback engine
                          (storage/query.Executor.execute_op)
  columnar.<OP_KIND>      one columnar-lowered operator closure
                          (columnar/lower; the Figure-6 index chain is
                          one ``columnar.PRIMARY_INDEX_LOOKUP`` /
                          ``columnar.POST_VALIDATE_SELECT`` span)
  lsm.flush               one memtable flush (attrs: rows, bytes)
  lsm.merge               one k-way component merge (attrs: rows, bytes,
                          components)
  lsm.postings_build      ngram/secondary CSR postings build for one
                          component field (attrs: field)
  feed.pump.<feed>        one intake -> compute -> store cycle (attrs:
                          records)
  bench.rep               one repetition inside benchmarks/_timing.timed

Kernel spans are not opened per dispatch (too hot); instead
``record_dispatch`` *attributes* dispatch counts and byte totals onto
the innermost open span (``kernel_dispatches`` / ``h2d_bytes`` /
``d2h_bytes`` span attrs), so an ``exec.*``/``columnar.*`` span carries
the kernel traffic of exactly the operator that triggered it.

The metric *name* registry lives in ``docs/METRICS.md`` — one table per
family (kernel.*, mesh.*, buffer_pool.*, plan_cache.*, lsm.*, feed.*,
serve.*, obs.exporter.*), kept honest by ``tests/test_metrics_doc.py``,
which fails if a workload emits a metric the doc doesn't list.

Executor-level accounting stays on ``storage/query.ExecStats`` (per-query
scope): ``kernel_dispatches`` / ``h2d_bytes`` / ``d2h_bytes`` and
``spmd_dispatches`` / ``spmd_partitions`` are per-query deltas of the
process counters, and ``fallback_reasons`` maps "OP_KIND: reason" ->
occurrences for every subplan the columnar engine declined.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import numpy as np

from . import metrics, tracer
from .metrics import counter, gauge, histogram, snapshot, typed_snapshot
from .tracer import (Span, clear, current, disable, dump_trace, enable,
                     enabled, events, span, to_chrome)
from . import export
from .export import (ExporterServer, MetricsSampler, TimeSeriesRing,
                     render_prometheus, serve_http)

__all__ = ["metrics", "tracer", "export", "span", "enable", "disable",
           "enabled", "current", "events", "clear", "dump_trace",
           "to_chrome", "counter", "gauge", "histogram", "snapshot",
           "typed_snapshot", "reset", "record_dispatch", "record_retrace",
           "kernel_totals", "Span", "ExporterServer", "MetricsSampler",
           "TimeSeriesRing", "render_prometheus", "serve_http"]

# hot-path handles: resolved once so record_dispatch costs dict-free
# increments on the totals plus one cached lookup per kernel name
_K_DISPATCH = counter("kernel.dispatches")
_K_H2D = counter("kernel.h2d_bytes")
_K_D2H = counter("kernel.d2h_bytes")
_K_TRACES = counter("kernel.jit_traces")
_per_kernel: Dict[str, Tuple[Any, Any, Any]] = {}


def reset() -> None:
    """Zero all metrics and drop all finished spans (tracer enablement is
    untouched)."""
    metrics.reset()
    tracer.clear()


def _nbytes(arrs: Sequence[Any]) -> int:
    return sum(int(a.nbytes) for a in arrs if isinstance(a, np.ndarray))


def record_dispatch(name: str, h2d: Sequence[Any] = (),
                    d2h: Sequence[Any] = ()) -> None:
    """Account one device-bound kernel call: ``h2d`` are the operand
    arrays shipped to the jitted/Pallas core (post-padding; 0-d bound
    scalars are excluded by convention), ``d2h`` the result arrays
    fetched back (padded shape, before host-side slicing).  Updates the
    process-wide kernel counters and attributes onto the innermost open
    span when tracing is enabled."""
    hb = _nbytes(h2d)
    db = _nbytes(d2h)
    _K_DISPATCH.inc(1)
    if hb:
        _K_H2D.inc(hb)
    if db:
        _K_D2H.inc(db)
    per = _per_kernel.get(name)
    if per is None:
        per = _per_kernel[name] = (counter(f"kernel.{name}.dispatches"),
                                   counter(f"kernel.{name}.h2d_bytes"),
                                   counter(f"kernel.{name}.d2h_bytes"))
    per[0].inc(1)
    if hb:
        per[1].inc(hb)
    if db:
        per[2].inc(db)
    sp = tracer.current()
    if sp is not None:
        sp.add("kernel_dispatches", 1)
        sp.add("h2d_bytes", hb)
        sp.add("d2h_bytes", db)


def record_retrace() -> None:
    """Mirror of the kernel cores' trace-time counter (called from inside
    jitted functions at trace time only)."""
    _K_TRACES.inc(1)


def kernel_totals() -> Tuple[int, int, int]:
    """(dispatches, h2d_bytes, d2h_bytes) snapshot — the executor diffs
    two of these around a query to fill ExecStats."""
    return (_K_DISPATCH.value, _K_H2D.value, _K_D2H.value)
