"""Observability layer: span tracer + process-wide metrics registry.

This package is the measurement substrate the perf roadmap asserts
against (ROADMAP items 1–3): a nested span tracer with Chrome-trace
export (``tracer``) and a counters/gauges/histograms registry
(``metrics``), plus the one helper every kernel wrapper calls to account
dispatches and host<->device transfer bytes (``record_dispatch``).

Tracing is off by default and near-free when off (one module-flag check,
zero allocations).  Metrics counters are always on — they instrument
per-call/per-batch paths only, never per-row ones.

Span naming convention (``obs.span(name, **attrs)``):

  exec.<OP_KIND>          one executor operator, row/fallback engine
                          (storage/query.Executor.execute_op)
  columnar.<OP_KIND>      one columnar-lowered operator closure
                          (columnar/lower; the Figure-6 index chain is
                          one ``columnar.PRIMARY_INDEX_LOOKUP`` /
                          ``columnar.POST_VALIDATE_SELECT`` span)
  lsm.flush               one memtable flush (attrs: rows, bytes)
  lsm.merge               one k-way component merge (attrs: rows, bytes,
                          components)
  lsm.postings_build      ngram/secondary CSR postings build for one
                          component field (attrs: field)
  feed.pump.<feed>        one intake -> compute -> store cycle (attrs:
                          records)
  bench.rep               one repetition inside benchmarks/_timing.timed

Kernel spans are not opened per dispatch (too hot); instead
``record_dispatch`` *attributes* dispatch counts and byte totals onto
the innermost open span (``kernel_dispatches`` / ``h2d_bytes`` /
``d2h_bytes`` span attrs), so an ``exec.*``/``columnar.*`` span carries
the kernel traffic of exactly the operator that triggered it.

Metric name registry (``metrics.snapshot()`` keys):

  Counters — kernel wrappers (kernels/columnar_ops, kernels/fuzzy_ops):
    kernel.dispatches           device-bound kernel calls (jitted jnp or
                                Pallas; host-path fast floors don't count)
    kernel.h2d_bytes            operand bytes shipped host -> device,
                                post-padding (scalar bounds excluded)
    kernel.d2h_bytes            result bytes fetched device -> host,
                                pre-slicing (padded result shape)
    kernel.jit_traces           cumulative jit traces of the kernel cores
                                (mirrors columnar_ops.trace_count())
    kernel.<name>.dispatches    per-kernel splits of the three above
    kernel.<name>.h2d_bytes     (<name> is the public wrapper: range_mask,
    kernel.<name>.d2h_bytes     fused_filter_aggregate,
                                sorted_intersect_mask, t_occurrence_mask,
                                edit_distances, set_intersect_counts,
                                bitset_intersect_counts, and
                                fused_index_chain — the whole Figure-6
                                chain as one dispatch per partition,
                                columnar/plancache)

  Device buffer pool (kernels/device_pool): upload-once residency for
  pow2-padded columns and postings across queries —
    buffer_pool.hits            counter: operands found device-resident
    buffer_pool.misses          counter: first-touch uploads (these are
                                the only operands record_dispatch counts
                                as h2d bytes — a warm query reports
                                h2d_bytes == 0)
    buffer_pool.evictions       counter: buffers dropped (LSM component
                                retirement via release_component, or the
                                host array's weakref finalizer)
    buffer_pool.resident_bytes  gauge: bytes currently device-resident

  Fused plan cache (columnar/plancache): compiled Figure-6 chains keyed
  by plan shape (op sequence + pow2 operand buckets + dtypes) —
    plan_cache.hits             counter: fused dispatches of an
                                already-compiled plan shape
    plan_cache.misses           counter: first sighting of a shape (the
                                dispatch that traces _chain_core)
    plan_cache.entries          gauge: distinct plan shapes seen

  Counters — LSM storage (core/lsm):
    lsm.flushes / lsm.merges    completed flush / merge operations
    lsm.rows_ingested           memtable inserts+deletes accepted
    lsm.rows_flushed            rows written by flushes
    lsm.rows_merged             rows written by merges
    lsm.bytes_flushed           estimated component bytes written by
    lsm.bytes_merged            flushes / merges (column arrays + keys +
                                tombstones + string dictionaries)
    write amplification == (rows_flushed + rows_merged) / rows_ingested;
    per-index, ``LSMIndex.write_amplification()`` computes it from the
    index-local stats dict.

  Histograms — LSM storage:
    lsm.flush_seconds           wall time per flush
    lsm.merge_seconds           wall time per merge
    lsm.postings_build_seconds  wall time per postings (re)build
    lsm.component_rows          rows per created component
    lsm.component_bytes         estimated bytes per created component

  Gauges — LSM storage:
    lsm.components              valid components in the index that last
                                flushed/merged (a freshness sample, not a
                                cross-index aggregate)

  Snapshot pinning — LSM storage (core/lsm):
    lsm.pins                    counter: snapshot views pinned
    lsm.deferred_retires        counter: replaced components whose
                                physical retirement waited on a pin
    lsm.pinned_snapshots        gauge: currently-live pinned views

  Feeds (data/feeds):
    feed.<feed>.records             counter: records stored by the feed
    feed.<feed>.batch_records       histogram: records per pump cycle
    feed.joint.<joint>.published    counter: records published to a joint
    feed.joint.<joint>.dropped      counter: *unconsumed* records evicted
                                    past the replay window (overflow
                                    policy "drop"; fully-consumed
                                    retirements are never counted)
    feed.joint.<joint>.lag.<sub>    gauge: head - subscriber cursor after
                                    each consume (records behind)
    feed.sink.<dataset>.records     counter: records delivered via
                                    insert_batch
    feed.sink.<dataset>.batch_records  histogram: insert_batch sizes
    feed.sink.<dataset>.backlog     gauge: records buffered awaiting a
                                    full micro-batch (sink lag)
    per-joint ingest rate: ``FeedJoint.rate()`` (records/sec over the
    joint's publish lifetime).

  Serving harness (serve/harness):
    serve.ingest.acked          counter: records acknowledged to storage
                                (after insert_batch returned)
    serve.admission.rejected    counter: queries shed by the admission
                                controller (no slot within timeout)
    serve.admission.inflight    gauge: admitted queries currently running
    serve.query.latency_s       histogram: admitted-query wall time,
                                queue wait excluded (p50/p99 are the
                                serve_bench report numbers)
    serve.query.torn_reads      counter: snapshot scans violating the
                                lane-prefix consistency oracle
    serve.query.lost_acks       counter: snapshot scans missing records
                                acked before the pin
    serve.recoveries            counter: crash_and_recover cycles

  Request tracing + SLOs (serve/harness.RequestTracker; every
  QueryWorker submission is a request with a monotone trace id and
  queue-wait / pin / execute / result phases):
    serve.queue_wait_s          histogram: admission queue wait per
                                request — *including* time-to-rejection
                                for shed requests, so rejected load is
                                visible in the same distribution
    serve.phase.pin_s           histogram: snapshot-pin phase wall time
    serve.phase.execute_s       histogram: execute phase wall time
    serve.phase.result_s        histogram: result/validation phase wall
                                time (phase p99s feed the ServeReport
                                tail-latency attribution table)
    serve.slo.attained          counter: requests completed within the
                                per-request deadline (queue wait counts)
    serve.slo.missed            counter: requests completed but over
                                deadline
    serve.slo.rejected_deadline counter: requests rejected *because*
                                their queue wait would have blown the
                                deadline (deadline-based admission; slot
                                -timeout rejections stay in
                                serve.admission.rejected)
    serve.request.profiled      counter: requests sampled by the 1-in-N
                                profiler (full span trees retained in
                                the harness's bounded profile ring)

  Exporter (obs/export; nothing is sampled or served until
  ``obs.serve_http()`` is called):
    obs.exporter.scrapes        counter: HTTP requests answered on
                                /metrics, /snapshot, /trace
    ``MetricsSampler`` additionally exposes windowed per-second rates of
    the feed./serve./kernel./buffer_pool. counters via the ``/metrics``
    ``<family>_rate`` gauges (not registry metrics themselves — they
    live in the sampler's time-series ring).

Executor-level accounting stays on ``storage/query.ExecStats`` (per-query
scope): ``kernel_dispatches`` / ``h2d_bytes`` / ``d2h_bytes`` are the
per-query deltas of the kernel counters above, and
``fallback_reasons`` maps "OP_KIND: reason" -> occurrences for every
subplan the columnar engine declined.  ``explain_analyze`` (same module)
returns the physical plan annotated per operator with wall time, rows,
connector movement, and this kernel traffic.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import numpy as np

from . import metrics, tracer
from .metrics import counter, gauge, histogram, snapshot, typed_snapshot
from .tracer import (Span, clear, current, disable, dump_trace, enable,
                     enabled, events, span, to_chrome)
from . import export
from .export import (ExporterServer, MetricsSampler, TimeSeriesRing,
                     render_prometheus, serve_http)

__all__ = ["metrics", "tracer", "export", "span", "enable", "disable",
           "enabled", "current", "events", "clear", "dump_trace",
           "to_chrome", "counter", "gauge", "histogram", "snapshot",
           "typed_snapshot", "reset", "record_dispatch", "record_retrace",
           "kernel_totals", "Span", "ExporterServer", "MetricsSampler",
           "TimeSeriesRing", "render_prometheus", "serve_http"]

# hot-path handles: resolved once so record_dispatch costs dict-free
# increments on the totals plus one cached lookup per kernel name
_K_DISPATCH = counter("kernel.dispatches")
_K_H2D = counter("kernel.h2d_bytes")
_K_D2H = counter("kernel.d2h_bytes")
_K_TRACES = counter("kernel.jit_traces")
_per_kernel: Dict[str, Tuple[Any, Any, Any]] = {}


def reset() -> None:
    """Zero all metrics and drop all finished spans (tracer enablement is
    untouched)."""
    metrics.reset()
    tracer.clear()


def _nbytes(arrs: Sequence[Any]) -> int:
    return sum(int(a.nbytes) for a in arrs if isinstance(a, np.ndarray))


def record_dispatch(name: str, h2d: Sequence[Any] = (),
                    d2h: Sequence[Any] = ()) -> None:
    """Account one device-bound kernel call: ``h2d`` are the operand
    arrays shipped to the jitted/Pallas core (post-padding; 0-d bound
    scalars are excluded by convention), ``d2h`` the result arrays
    fetched back (padded shape, before host-side slicing).  Updates the
    process-wide kernel counters and attributes onto the innermost open
    span when tracing is enabled."""
    hb = _nbytes(h2d)
    db = _nbytes(d2h)
    _K_DISPATCH.inc(1)
    if hb:
        _K_H2D.inc(hb)
    if db:
        _K_D2H.inc(db)
    per = _per_kernel.get(name)
    if per is None:
        per = _per_kernel[name] = (counter(f"kernel.{name}.dispatches"),
                                   counter(f"kernel.{name}.h2d_bytes"),
                                   counter(f"kernel.{name}.d2h_bytes"))
    per[0].inc(1)
    if hb:
        per[1].inc(hb)
    if db:
        per[2].inc(db)
    sp = tracer.current()
    if sp is not None:
        sp.add("kernel_dispatches", 1)
        sp.add("h2d_bytes", hb)
        sp.add("d2h_bytes", db)


def record_retrace() -> None:
    """Mirror of the kernel cores' trace-time counter (called from inside
    jitted functions at trace time only)."""
    _K_TRACES.inc(1)


def kernel_totals() -> Tuple[int, int, int]:
    """(dispatches, h2d_bytes, d2h_bytes) snapshot — the executor diffs
    two of these around a query to fill ExecStats."""
    return (_K_DISPATCH.value, _K_H2D.value, _K_D2H.value)
