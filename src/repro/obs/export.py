"""Metrics exporter: Prometheus text rendering, a windowed-rate sampler,
and a stdlib HTTP endpoint.

PR 6 made every layer observable through ``metrics.snapshot()``; this
module makes that state *servable* without any new dependency:

* ``render_prometheus()`` turns ``metrics.typed_snapshot()`` into the
  Prometheus text exposition format (version 0.0.4): metric names are
  sanitized to ``[a-zA-Z_:][a-zA-Z0-9_:]*``, a small rule table folds
  per-entity name families (``kernel.<name>.dispatches``,
  ``feed.joint.<j>.lag.<sub>``, ...) into one family with labels
  (``kernel_dispatches{kernel="range_mask"}``), counters/gauges render
  as single samples and histograms as summaries (``{quantile="0.5"}`` /
  ``_sum`` / ``_count`` plus ``_min``/``_max`` gauges).

* ``TimeSeriesRing`` + ``MetricsSampler`` fill a fixed-size ring of
  (monotonic time, counter values) samples on a background interval so
  monotone counters become *windowed rates*: ``rates(window_s)`` is
  (newest - oldest-within-window) / elapsed for every sampled counter
  (default prefixes ``feed.`` / ``serve.`` / ``kernel.`` /
  ``buffer_pool.``, histogram ``count`` streams included as
  ``<name>.count``).  Rates ride into ``/metrics`` as
  ``<family>_rate`` gauges.

* ``serve_http(port)`` starts a ``http.server.ThreadingHTTPServer`` on
  a daemon thread serving

    /metrics    Prometheus text (plus ``*_rate`` gauges when a sampler
                is attached)
    /snapshot   the raw ``metrics.snapshot()`` JSON
    /trace      Chrome trace-event JSON of the retained spans (the
                process tracer ring by default; pass ``trace_source``
                to export a profile ring, e.g. the serve harness's
                sampled request spans)

  and returns an :class:`ExporterServer` (``.port``, ``.url``,
  ``.stop()``).  Nothing runs until ``serve_http`` is called — when the
  exporter is off the only cost anywhere is an unused import.
"""

from __future__ import annotations

import json
import math
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

from . import metrics, tracer

__all__ = ["ExporterServer", "MetricsSampler", "TimeSeriesRing",
           "render_prometheus", "sanitize_metric_name", "serve_http"]

CONTENT_TYPE_PROM = "text/plain; version=0.0.4; charset=utf-8"

_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")
_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def sanitize_metric_name(name: str) -> str:
    """Registry name -> legal Prometheus metric name: every illegal
    character becomes ``_`` and a leading digit gets a ``_`` prefix."""
    out = _NAME_BAD.sub("_", name)
    if not out or not _NAME_OK.match(out):
        out = "_" + out
    return out


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


# Per-entity name families -> one Prometheus family + labels.  A rule is
# (regex with named groups, family template); groups consumed by the
# template become part of the family name, the rest become labels.
LABEL_RULES: List[Tuple["re.Pattern[str]", str]] = [
    (re.compile(r"^kernel\.(?P<kernel>.+)\."
                r"(?P<which>dispatches|h2d_bytes|d2h_bytes)$"),
     "kernel_{which}"),
    (re.compile(r"^feed\.joint\.(?P<joint>.+)\.lag\.(?P<subscriber>.+)$"),
     "feed_joint_lag"),
    (re.compile(r"^feed\.joint\.(?P<joint>.+)\.(?P<which>published|dropped)$"),
     "feed_joint_{which}"),
    (re.compile(r"^feed\.sink\.(?P<dataset>.+)\."
                r"(?P<which>records|batch_records|backlog)$"),
     "feed_sink_{which}"),
    (re.compile(r"^feed\.(?P<feed>[^.]+)\.(?P<which>records|batch_records)$"),
     "feed_{which}"),
]


def _family(name: str) -> Tuple[str, Dict[str, str]]:
    """(family, labels) for a registry metric name."""
    for rx, tmpl in LABEL_RULES:
        m = rx.match(name)
        if m is None:
            continue
        groups = m.groupdict()
        family = tmpl.format(**groups)
        labels = {k: v for k, v in groups.items()
                  if "{%s}" % k not in tmpl}
        return (sanitize_metric_name(family),
                {sanitize_metric_name(k): v for k, v in labels.items()})
    return sanitize_metric_name(name), {}


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: Any) -> Optional[str]:
    if v is None:
        return "NaN"
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    if isinstance(v, float):
        if math.isnan(v):
            return "NaN"
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        return repr(v)
    return None                      # non-numeric gauge payloads are skipped


def render_prometheus(typed: Optional[Dict[str, Any]] = None,
                      rates: Optional[Dict[str, float]] = None) -> str:
    """Prometheus text exposition of a ``metrics.typed_snapshot()`` (the
    live registry when None).  ``rates`` (registry-name -> per-second
    value, from :class:`MetricsSampler`) render as ``<family>_rate``
    gauges so scrapes see windowed throughput without PromQL."""
    if typed is None:
        typed = metrics.typed_snapshot()
    # family -> (kind, [(labels, snap)]) so each family prints one
    # ``# TYPE`` header with all its samples together (required format)
    families: Dict[str, Tuple[str, List[Tuple[Dict[str, str], Any]]]] = {}

    def put(family: str, kind: str, labels: Dict[str, str],
            snap: Any) -> None:
        cur = families.get(family)
        if cur is None:
            families[family] = (kind, [(labels, snap)])
        elif cur[0] == kind:
            cur[1].append((labels, snap))
        else:                        # kind clash after sanitization: keep
            put(family + "_" + kind, kind, labels, snap)   # both, suffixed

    for name, (kind, snap) in typed.items():
        family, labels = _family(name)
        put(family, kind, labels, snap)
    if rates:
        for name, rate in sorted(rates.items()):
            family, labels = _family(name)
            put(family + "_rate", "gauge", labels, float(rate))

    lines: List[str] = []
    for family in sorted(families):
        kind, samples = families[family]
        if kind == "histogram":
            # registry histograms expose exact count/sum + windowed
            # quantiles -> Prometheus *summary* is the matching type
            lines.append(f"# TYPE {family} summary")
            for labels, snap in samples:
                for q, key in (("0.5", "p50"), ("0.95", "p95"),
                               ("0.99", "p99")):
                    ql = dict(labels, quantile=q)
                    lines.append(f"{family}{_fmt_labels(ql)} "
                                 f"{_fmt_value(snap[key])}")
                lines.append(f"{family}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(snap['sum'])}")
                lines.append(f"{family}_count{_fmt_labels(labels)} "
                             f"{_fmt_value(snap['count'])}")
            for suffix, key in (("_min", "min"), ("_max", "max")):
                lines.append(f"# TYPE {family}{suffix} gauge")
                for labels, snap in samples:
                    lines.append(f"{family}{suffix}{_fmt_labels(labels)} "
                                 f"{_fmt_value(snap[key])}")
        else:
            rendered = [(labels, _fmt_value(snap))
                        for labels, snap in samples]
            rendered = [(lb, v) for lb, v in rendered if v is not None]
            if not rendered:
                continue
            lines.append(f"# TYPE {family} {kind}")
            for labels, v in rendered:
                lines.append(f"{family}{_fmt_labels(labels)} {v}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Windowed rates
# ---------------------------------------------------------------------------

class TimeSeriesRing:
    """Fixed-size ring of (t, {counter_name: value}) samples.  Appends
    overwrite the oldest slot once full; ``rate(name, window_s)`` is the
    slope between the newest sample and the oldest sample still inside
    the window — monotone counters become windowed rates."""

    def __init__(self, size: int = 120):
        if size < 2:
            raise ValueError("ring needs >= 2 slots to compute a rate")
        self.size = int(size)
        self._lock = threading.Lock()
        self._slots: List[Tuple[float, Dict[str, float]]] = []
        self._pos = 0

    def append(self, t: float, values: Dict[str, float]) -> None:
        with self._lock:
            if len(self._slots) < self.size:
                self._slots.append((t, values))
            else:
                self._slots[self._pos] = (t, values)
                self._pos = (self._pos + 1) % self.size

    def samples(self) -> List[Tuple[float, Dict[str, float]]]:
        """Retained samples, oldest first."""
        with self._lock:
            return self._slots[self._pos:] + self._slots[:self._pos]

    def __len__(self) -> int:
        with self._lock:
            return len(self._slots)

    def rate(self, name: str, window_s: Optional[float] = None
             ) -> Optional[float]:
        """Per-second rate of ``name`` over the trailing window (whole
        ring when None).  None when fewer than two samples carry the
        counter."""
        samples = self.samples()
        if len(samples) < 2:
            return None
        t1, new = samples[-1]
        if name not in new:
            return None
        floor = -math.inf if window_s is None else t1 - window_s
        for t0, old in samples[:-1]:
            if t0 >= floor and name in old:
                if t1 <= t0:
                    return None
                return (new[name] - old[name]) / (t1 - t0)
        return None

    def rates(self, window_s: Optional[float] = None) -> Dict[str, float]:
        """Windowed rate for every counter in the newest sample."""
        samples = self.samples()
        if len(samples) < 2:
            return {}
        out = {}
        for name in samples[-1][1]:
            r = self.rate(name, window_s)
            if r is not None:
                out[name] = r
        return out


class MetricsSampler:
    """Background thread sampling counter values (and histogram
    ``count`` streams, as ``<name>.count``) into a
    :class:`TimeSeriesRing` every ``interval_s`` so the exporter can
    serve windowed rates.  Only names under ``prefixes`` are retained —
    the hot serving families, not every metric ever registered."""

    DEFAULT_PREFIXES = ("feed.", "serve.", "kernel.", "buffer_pool.")

    def __init__(self, interval_s: float = 1.0, size: int = 120,
                 prefixes: Sequence[str] = DEFAULT_PREFIXES):
        self.interval_s = float(interval_s)
        self.prefixes = tuple(prefixes)
        self.ring = TimeSeriesRing(size)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _values(self) -> Dict[str, float]:
        vals: Dict[str, float] = {}
        for name, (kind, snap) in metrics.typed_snapshot().items():
            if not name.startswith(self.prefixes):
                continue
            if kind == "counter" and isinstance(snap, (int, float)):
                vals[name] = float(snap)
            elif kind == "histogram":
                vals[name + ".count"] = float(snap["count"])
        return vals

    def sample_now(self, t: Optional[float] = None) -> None:
        """Take one sample (tests drive this directly with explicit
        timestamps for deterministic rate math)."""
        self.ring.append(time.monotonic() if t is None else t,
                         self._values())

    def rates(self, window_s: Optional[float] = None) -> Dict[str, float]:
        return self.ring.rates(window_s)

    def start(self) -> "MetricsSampler":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="obs-sampler")
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.sample_now()
            self._stop.wait(self.interval_s)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


# ---------------------------------------------------------------------------
# HTTP endpoint
# ---------------------------------------------------------------------------

class ExporterServer:
    """Threaded stdlib HTTP server exposing /metrics, /snapshot and
    /trace.  ``port=0`` binds an ephemeral port (read it back from
    ``.port``).  ``stop()`` shuts the listener down and, when the server
    owns its sampler (``serve_http`` wiring), stops that too."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 sampler: Optional[MetricsSampler] = None,
                 trace_source: Optional[Callable[[], Iterable[Any]]] = None,
                 rate_window_s: Optional[float] = None):
        self.sampler = sampler
        self.trace_source = trace_source or tracer.events
        self.rate_window_s = rate_window_s
        self._owns_sampler = False
        self._scrapes = metrics.counter("obs.exporter.scrapes")
        exporter = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt: str, *args: Any) -> None:
                pass                       # silence per-request stderr spam

            def do_GET(self) -> None:      # noqa: N802 (stdlib API name)
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        rates = (exporter.sampler.rates(
                                     exporter.rate_window_s)
                                 if exporter.sampler is not None else None)
                        body = render_prometheus(rates=rates).encode()
                        ctype = CONTENT_TYPE_PROM
                    elif path == "/snapshot":
                        body = json.dumps(metrics.snapshot(),
                                          default=str).encode()
                        ctype = "application/json"
                    elif path == "/trace":
                        spans = list(exporter.trace_source())
                        body = json.dumps(tracer.to_chrome(spans)).encode()
                        ctype = "application/json"
                    else:
                        self.send_error(404, "unknown path")
                        return
                except Exception as e:     # noqa: BLE001 — a broken
                    self.send_error(500, f"{type(e).__name__}: {e}")
                    return                 # renderer must not kill serving
                exporter._scrapes.inc()
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

        self._httpd = ThreadingHTTPServer((host, int(port)), Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True,
                                        name=f"obs-http:{self.port}")
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)
        if self._owns_sampler and self.sampler is not None:
            self.sampler.stop()

    def __enter__(self) -> "ExporterServer":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.stop()


def serve_http(port: int = 0, host: str = "127.0.0.1",
               sample_interval_s: float = 1.0,
               rate_window_s: Optional[float] = 15.0,
               trace_source: Optional[Callable[[], Iterable[Any]]] = None
               ) -> ExporterServer:
    """Start the metrics endpoint: spins up a :class:`MetricsSampler`
    (so ``/metrics`` carries ``*_rate`` gauges over ``rate_window_s``)
    plus an :class:`ExporterServer`, and returns the server —
    ``server.stop()`` tears both down.  ``port=0`` picks an ephemeral
    port.  Until this is called the exporter costs nothing."""
    sampler = MetricsSampler(interval_s=sample_interval_s).start()
    server = ExporterServer(port=port, host=host, sampler=sampler,
                            trace_source=trace_source,
                            rate_window_s=rate_window_s)
    server._owns_sampler = True
    return server
