"""Process-wide metrics registry: counters, gauges, histograms.

One global ``Registry`` (module-level ``counter()`` / ``gauge()`` /
``histogram()`` accessors) shared by every instrumented layer — kernel
wrappers, the LSM, feeds, the executor.  Metric creation is
lock-protected; updates take the per-metric lock (a dict increment plus
one lock acquisition — microseconds-scale kernel dispatches dwarf it,
and per-*row* paths are never instrumented, only per-call/per-batch
ones).

``snapshot()`` returns a flat JSON-safe dict (histograms expand to
``{count, sum, min, max, p50, p95, p99}``) — this is what
``benchmarks/run.py --json`` embeds so every CI run records the metric
state alongside the bench numbers.  ``reset()`` zeroes everything
(tests and per-query deltas use it or diff two snapshots).

Snapshots never stall the hot path: ``snapshot()`` copies the
name->metric mapping under the registry lock, then each metric copies
its own state under its *per-metric* lock for only as long as a list
copy takes — sorting (histogram quantiles) happens on the copy, outside
every lock.  A slow consumer (the HTTP exporter scraping a large
registry) therefore can never block a concurrent counter increment for
longer than one bounded copy.  ``typed_snapshot()`` is the same walk
but keeps the metric kind (``"counter"`` / ``"gauge"`` /
``"histogram"``) alongside each value — the Prometheus renderer in
``obs/export.py`` needs the kind to pick the exposition type.

Histograms keep a bounded ring of recent observations (default 8192)
for the quantiles; ``count``/``sum``/``min``/``max`` stay exact over
the full stream.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Union

__all__ = ["Counter", "Gauge", "Histogram", "Registry",
           "counter", "gauge", "histogram", "snapshot", "typed_snapshot",
           "reset"]


def _nearest_rank(sorted_xs: List[float], p: float) -> Optional[float]:
    """Nearest-rank quantile over an already-sorted window (None when
    empty)."""
    if not sorted_xs:
        return None
    n = len(sorted_xs)
    k = min(n - 1, max(0, int(round(p / 100.0 * (n - 1)))))
    return sorted_xs[k]


class Counter:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> Union[int, float]:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0

    def _snap(self) -> Union[int, float]:
        with self._lock:
            return self._value


class Gauge:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value: Any = 0

    def set(self, v: Any) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> Any:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0

    def _snap(self) -> Any:
        with self._lock:
            return self._value


class Histogram:
    """Streaming distribution: exact count/sum/min/max, quantiles over a
    bounded ring of the most recent ``window`` observations."""

    __slots__ = ("name", "window", "_lock", "_ring", "_pos",
                 "count", "sum", "min", "max")

    def __init__(self, name: str, window: int = 8192):
        self.name = name
        self.window = int(window)
        self._lock = threading.Lock()
        self._ring: List[float] = []
        self._pos = 0
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: Union[int, float]) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            if len(self._ring) < self.window:
                self._ring.append(v)
            else:                       # overwrite oldest (ring buffer)
                self._ring[self._pos] = v
                self._pos = (self._pos + 1) % self.window

    def percentile(self, p: float) -> Optional[float]:
        """p in [0, 100] over the retained window (None when empty)."""
        with self._lock:
            if not self._ring:
                return None
            xs = list(self._ring)       # copy only; sort outside the lock
        xs.sort()
        return _nearest_rank(xs, p)

    def _reset(self) -> None:
        with self._lock:
            self._ring = []
            self._pos = 0
            self.count = 0
            self.sum = 0.0
            self.min = None
            self.max = None

    def _snap(self) -> Dict[str, Any]:
        # one lock acquisition copies the whole state (scalars are read
        # together with the ring, so count/sum/min/max are never torn
        # against the quantiles); the sort runs on the copy, unlocked
        with self._lock:
            xs = list(self._ring)
            count, total = self.count, self.sum
            lo, hi = self.min, self.max
        xs.sort()
        return {
            "count": count,
            "sum": total,
            "min": lo,
            "max": hi,
            "p50": _nearest_rank(xs, 50),
            "p95": _nearest_rank(xs, 95),
            "p99": _nearest_rank(xs, 99),
        }


class Registry:
    """Named-metric store.  A name is permanently one metric type — a
    kind clash raises instead of silently shadowing."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)       # racy read is fine: dict get
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, "
                    f"not {cls.__name__}")
            return m
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, "
                    f"not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 8192) -> Histogram:
        return self._get(name, Histogram, window=window)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:                  # registry lock: mapping copy only
            items = list(self._metrics.items())
        # each _snap() takes its own per-metric lock just long enough to
        # copy state — a hot-path increment never waits on the full walk
        return {name: m._snap() for name, m in sorted(items)}

    def typed_snapshot(self) -> Dict[str, Any]:
        """Like ``snapshot()`` but each value is ``(kind, snap)`` where
        kind is "counter" / "gauge" / "histogram" — what the Prometheus
        renderer keys its exposition types on."""
        with self._lock:
            items = list(self._metrics.items())
        kinds = {Counter: "counter", Gauge: "gauge", Histogram: "histogram"}
        return {name: (kinds[type(m)], m._snap())
                for name, m in sorted(items)}

    def reset(self) -> None:
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            m._reset()


REGISTRY = Registry()

counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
typed_snapshot = REGISTRY.typed_snapshot
reset = REGISTRY.reset
