"""Process-wide metrics registry: counters, gauges, histograms.

One global ``Registry`` (module-level ``counter()`` / ``gauge()`` /
``histogram()`` accessors) shared by every instrumented layer — kernel
wrappers, the LSM, feeds, the executor.  Metric creation is
lock-protected; updates take the per-metric lock (a dict increment plus
one lock acquisition — microseconds-scale kernel dispatches dwarf it,
and per-*row* paths are never instrumented, only per-call/per-batch
ones).

``snapshot()`` returns a flat JSON-safe dict (histograms expand to
``{count, sum, min, max, p50, p95, p99}``) — this is what
``benchmarks/run.py --json`` embeds so every CI run records the metric
state alongside the bench numbers.  ``reset()`` zeroes everything
(tests and per-query deltas use it or diff two snapshots).

Histograms keep a bounded ring of recent observations (default 8192)
for the quantiles; ``count``/``sum``/``min``/``max`` stay exact over
the full stream.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Union

__all__ = ["Counter", "Gauge", "Histogram", "Registry",
           "counter", "gauge", "histogram", "snapshot", "reset"]


class Counter:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> Union[int, float]:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0

    def _snap(self) -> Union[int, float]:
        return self._value


class Gauge:
    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value: Any = 0

    def set(self, v: Any) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> Any:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0

    def _snap(self) -> Any:
        return self._value


class Histogram:
    """Streaming distribution: exact count/sum/min/max, quantiles over a
    bounded ring of the most recent ``window`` observations."""

    __slots__ = ("name", "window", "_lock", "_ring", "_pos",
                 "count", "sum", "min", "max")

    def __init__(self, name: str, window: int = 8192):
        self.name = name
        self.window = int(window)
        self._lock = threading.Lock()
        self._ring: List[float] = []
        self._pos = 0
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: Union[int, float]) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            if len(self._ring) < self.window:
                self._ring.append(v)
            else:                       # overwrite oldest (ring buffer)
                self._ring[self._pos] = v
                self._pos = (self._pos + 1) % self.window

    def percentile(self, p: float) -> Optional[float]:
        """p in [0, 100] over the retained window (None when empty)."""
        with self._lock:
            if not self._ring:
                return None
            xs = sorted(self._ring)
        # nearest-rank on the sorted window
        k = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
        return xs[k]

    def _reset(self) -> None:
        with self._lock:
            self._ring = []
            self._pos = 0
            self.count = 0
            self.sum = 0.0
            self.min = None
            self.max = None

    def _snap(self) -> Dict[str, Any]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class Registry:
    """Named-metric store.  A name is permanently one metric type — a
    kind clash raises instead of silently shadowing."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)       # racy read is fine: dict get
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, "
                    f"not {cls.__name__}")
            return m
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, "
                    f"not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 8192) -> Histogram:
        return self._get(name, Histogram, window=window)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            items = list(self._metrics.items())
        return {name: m._snap() for name, m in sorted(items)}

    def reset(self) -> None:
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            m._reset()


REGISTRY = Registry()

counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
reset = REGISTRY.reset
