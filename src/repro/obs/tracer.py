"""Span tracer: nested wall-time spans with Chrome-trace JSON export.

The tracer is process-wide and *off by default*: ``span(...)`` returns a
shared no-op context manager singleton until ``enable()`` is called, so
instrumented hot paths (executor operators, kernel wrappers, LSM
flush/merge, feed pumps) pay one module-flag check and zero allocations
per call when tracing is disabled.

Enabled, each ``span(name, **attrs)`` pushes a ``Span`` onto a
thread-local stack on ``__enter__`` and appends it to the process-wide
finished-event list on ``__exit__`` (exceptions still close the span —
``__exit__`` runs either way and never swallows the error).  Spans
therefore nest per thread; ``current()`` exposes the innermost open span
so other instrumentation (``obs.record_dispatch``) can attribute kernel
dispatches and transfer bytes to the operator that triggered them.

``dump_trace(path)`` writes the finished spans as a Chrome trace-event
JSON file (``ph: "X"`` complete events, microsecond timestamps), loadable
in ``chrome://tracing`` / Perfetto, so a whole feed -> flush -> merge ->
query run is inspectable on one timeline.

Span naming convention (see ``obs.__init__`` for the full registry):

  exec.<OP_KIND>       row/fallback executor operator (storage/query)
  columnar.<OP_KIND>   columnar-lowered operator (columnar/lower)
  lsm.flush / lsm.merge / lsm.postings_build
  feed.pump.<feed>     one intake->compute->store cycle
  bench.rep            one repetition inside benchmarks/_timing.timed
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["Span", "span", "enable", "disable", "enabled", "current",
           "clear", "events", "dump_trace", "to_chrome"]

_enabled = False
_lock = threading.Lock()
_events: List["Span"] = []
_tls = threading.local()
# trace timestamps are perf_counter-relative to import time so every
# thread shares one monotonic origin
_T0 = time.perf_counter()


class Span:
    """One wall-time interval.  ``attrs`` ride into the Chrome trace's
    ``args``; ``add``/``set`` mutate them while the span is open (or
    after — spans are plain records)."""

    __slots__ = ("name", "attrs", "t0", "t1", "tid", "depth")

    def __init__(self, name: str, attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.attrs: Dict[str, Any] = attrs or {}
        self.t0 = 0.0
        self.t1 = 0.0
        self.tid = 0
        self.depth = 0

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def add(self, key: str, n: Any) -> None:
        """Accumulate a numeric attribute (kernel dispatch / byte
        attribution)."""
        self.attrs[key] = self.attrs.get(key, 0) + n

    def set(self, key: str, v: Any) -> None:
        self.attrs[key] = v

    def __enter__(self) -> "Span":
        stack = _stack()
        self.depth = len(stack)
        self.tid = threading.get_ident()
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # close even when the body raised: pop self (and, defensively,
        # anything opened above and leaked) so the stack never wedges
        self.t1 = time.perf_counter()
        stack = _stack()
        while stack:
            if stack.pop() is self:
                break
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        # explicitly-constructed spans (the serve profile sampler builds
        # Span(...) directly while global tracing is off) still nest on
        # the thread-local stack — so record_dispatch attribution lands
        # on them — but only enabled tracing retains them process-wide;
        # the sampler keeps its own bounded ring instead
        if _enabled:
            with _lock:
                _events.append(self)
        return None                     # never swallow the exception


class _NoopSpan:
    """Shared disabled-path singleton: ``span()`` allocates nothing when
    tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def add(self, key: str, n: Any) -> None:
        pass

    def set(self, key: str, v: Any) -> None:
        pass


_NOOP = _NoopSpan()


def _stack() -> List[Span]:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


def span(name: str, **attrs: Any):
    """Context manager for one traced interval.  Disabled: returns the
    shared no-op singleton (no allocation, no clock read)."""
    if not _enabled:
        return _NOOP
    return Span(name, attrs)


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def current() -> Optional[Span]:
    """Innermost open span on this thread (None when no span is open).
    Purely stack-based: ``span()`` never pushes when tracing is disabled,
    so the common disabled path still returns None — but an explicitly
    constructed ``Span`` (profile sampling) is visible here regardless
    of the global flag, which is what routes kernel-dispatch attribution
    onto sampled serve requests."""
    s = getattr(_tls, "stack", None)
    return s[-1] if s else None


def clear() -> None:
    with _lock:
        _events.clear()


def events() -> List[Span]:
    """Finished spans, oldest first (a copy; safe to iterate while
    tracing continues)."""
    with _lock:
        return list(_events)


def to_chrome(spans: List[Span]) -> Dict[str, Any]:
    """Render finished spans as a Chrome trace-event dict (``ph: "X"``
    complete events, ts/dur in microseconds) — shared by
    ``dump_trace`` and the ``/trace`` HTTP endpoint (obs/export)."""
    return {
        "displayTimeUnit": "ms",
        "traceEvents": [
            {
                "name": e.name,
                "ph": "X",
                "ts": (e.t0 - _T0) * 1e6,
                "dur": max(e.duration, 0.0) * 1e6,
                "pid": 0,
                "tid": e.tid % (1 << 31),
                "args": {k: v for k, v in e.attrs.items()
                         if isinstance(v, (int, float, str, bool))},
            }
            for e in spans
        ],
    }


def dump_trace(path: str) -> int:
    """Write finished spans as Chrome trace-event JSON.  Returns the
    number of events written.  Open the file in chrome://tracing or
    https://ui.perfetto.dev to see the nested operator/flush/merge/pump
    timeline."""
    trace = to_chrome(events())
    with open(path, "w") as f:
        json.dump(trace, f)
    return len(trace["traceEvents"])
