"""AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule — pure functions over param pytrees.

Sharding: the ``m``/``v`` moments are ``zeros_like`` the parameters, so under
jit they inherit the parameters' (FSDP × TP) shardings — optimizer state is
2-D sharded exactly like the weights (the ZeRO-3 analogue of the paper's
node-local secondary indexes: state lives with the data it indexes).

Parameters are stored bf16 at scale; moments are f32 and the update math runs
in f32 (see docs/ARCHITECTURE.md §Training-stack deviations for the
deviation note vs f32 master weights).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "schedule", "init", "update", "global_norm"]


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 200
    decay_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0


def schedule(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads: Any, state: Dict[str, Any], params: Any,
           cfg: OptimizerConfig) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.max_grad_norm / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [leaf(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    metrics = {"grad_norm": gnorm, "lr": lr,
               "param_norm": global_norm(new_params)}
    return new_params, new_state, metrics
