"""Error-feedback int8 gradient compression for the pod-axis reduction.

Beyond-paper distributed-optimization trick (task deliverable): the pod axis
crosses the slowest links (inter-pod DCN/ICI), so the cross-pod gradient
all-reduce is the wire-dominant collective of a multi-pod step.  We compress
it ~3.8x with blockwise-int8 quantization (collectives.int8_encode) and keep
the quantization residual in an *error-feedback* buffer added back to the
next step's gradient — the standard EF-SGD construction that preserves
convergence (Karimireddy et al., 2019).

Two entry points:
  * ``ef_quantize``/``ef_state`` — pure-pytree transform usable under GSPMD
    (quantize-dequantize with residual carry; the wire saving is realized
    when the reduction runs via ``collectives.compressed_psum`` under
    shard_map — see training/train_step.py::make_train_step(compress=...)).
  * property-tested in tests/test_collectives.py.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..runtime.collectives import int8_decode, int8_encode

__all__ = ["ef_state", "ef_quantize"]


def ef_state(params: Any) -> Any:
    """Residual buffers, shaped/sharded like the gradients (f32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _leaf(g: jax.Array, e: jax.Array, block: int) -> Tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32) + e
    q, scale = int8_encode(gf, block=block)
    deq = int8_decode(q, scale, gf.shape)
    return deq.astype(g.dtype), gf - deq


def ef_quantize(grads: Any, err: Any, *, block: int = 256
                ) -> Tuple[Any, Any]:
    """Quantize-dequantize each gradient leaf with error feedback.

    Returns (compressed_grads, new_err).  The returned gradients are exactly
    the values a quantized all-reduce would contribute from this shard, so
    applying them under the normal (GSPMD-inserted) reduction models the
    compressed collective's *numerics*; the wire saving itself is measured in
    benchmarks/collectives_bench.py via compressed_psum.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [_leaf(g, e, block) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
