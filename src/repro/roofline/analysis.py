"""Roofline terms from a compiled dry-run artifact (§Roofline deliverable).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis()`` on an SPMD executable reports *per-device* flops/bytes
(the module is the per-device program), so terms divide by per-chip rates
directly.  collective_bytes is not in cost_analysis: we parse the optimized
HLO and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (task-specified).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

__all__ = ["HW", "collective_bytes", "roofline", "RooflineReport",
           "model_flops"]

HW = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # B/s per chip
    "ici_bw": 50e9,           # B/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+[\w\-]+\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    return sum(_shape_bytes(dt, dims) for dt, dims in
               _SHAPE_RE.findall(type_str))


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of *operand* bytes per collective kind (per device, per step).

    The optimized HLO prints operands as bare %names, so we build a
    name -> output-bytes map first, then resolve each collective's operand
    list against it (the task-specified "sum operand sizes" accounting).
    """
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _type_bytes(m.group(2))

    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            # match "op(" or "op-start(" but skip "-done(" (avoid double count)
            m = re.search(r"\b" + re.escape(op) + r"(-start)?\(", line)
            if m is None:
                continue
            operands = line[m.end():]
            depth = 1
            for i, ch in enumerate(operands):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        operands = operands[:i]
                        break
            for name in _OPERAND_RE.findall(operands):
                out[op] += sizes.get(name, 0)
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def model_flops(n_active_params: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D for train, 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per device
    hlo_bytes: float             # per device (HBM traffic estimate)
    coll_bytes: float            # per device
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops_total: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / HW["peak_flops"]

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HW["hbm_bw"]

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / HW["ici_bw"]

    @property
    def wire_bytes(self) -> float:
        """Ring-wire estimate from the operand-bytes breakdown (n=16, the
        dominant collective axis): AR 2(n-1)/n, AG (n-1) x shard operand,
        RS/A2A (n-1)/n, CP 1x.  Reported alongside the task-specified
        operand metric because the two diverge for AG-heavy schedules."""
        n = 16.0
        b = self.coll_breakdown
        return (b.get("all-reduce", 0) * 2 * (n - 1) / n
                + b.get("all-gather", 0) * (n - 1)
                + b.get("reduce-scatter", 0) * (n - 1) / n
                + b.get("all-to-all", 0) * (n - 1) / n
                + b.get("collective-permute", 0))

    @property
    def collective_wire_s(self) -> float:
        return self.wire_bytes / HW["ici_bw"]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time lower bound: max of the three terms (perfect
        overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): how much compiled compute is
        'useful' — catches remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops_total / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops_total / (t * self.chips * HW["peak_flops"])

    def as_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "wire_bytes_est": self.wire_bytes,
            "collective_wire_s": self.collective_wire_s,
            "bottleneck": self.bottleneck,
            "step_time_lb_s": self.step_time_s,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_at_bound": self.mfu,
        }


def roofline(arch: str, shape: str, mesh_name: str, chips: int,
             cost: Dict[str, float], hlo_text: str,
             model_flops_total: float) -> RooflineReport:
    coll = collective_bytes(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(coll["total"]),
        coll_breakdown=coll,
        model_flops_total=model_flops_total,
    )
