"""Exchange operators — the Hyracks Connector library on ICI.

Paper §4.1 lists six Connectors; each has a collective twin on a TPU mesh:

  OneToOneConnector            -> no-op (partitioning already agrees)
  MToNPartitioningConnector    -> all_to_all     (repartition by a new key)
  MToNReplicatingConnector     -> all_gather     (replicate to all peers)
  MToNPartitioningMerging      -> reduce_scatter (partition + merge)
  global aggregation fan-in    -> psum / all_reduce
  LocalityAwareMToN            -> hierarchical reduce (model-axis first, then
                                  data, then pod — cheapest links first)

These helpers are shard_map-level building blocks used where we take explicit
control of the schedule (gradient reduction, distributed decode merge,
compressed collectives).  Most model code instead relies on sharding
constraints + GSPMD (docs/ARCHITECTURE.md §Mesh and collectives).  The
SPMD partition runtime (runtime/spmd.py) drives these for the database's
Hyracks-style connectors — the connector -> collective mapping table is in
docs/ARCHITECTURE.md §Connectors.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "one_to_one", "replicate", "partition_by", "partition_merge",
    "hierarchical_psum", "int8_encode", "int8_decode", "compressed_psum",
    "logsumexp_merge",
]


# ---------------------------------------------------------------------------
# Connector twins (for use inside shard_map bodies)
# ---------------------------------------------------------------------------

def one_to_one(x: jax.Array) -> jax.Array:
    return x


def replicate(x: jax.Array, axis: str) -> jax.Array:
    """MToNReplicating: gather everyone's partition along a mesh axis."""
    return jax.lax.all_gather(x, axis, tiled=True)


def partition_by(x: jax.Array, axis: str, *, split_dim: int,
                 concat_dim: int) -> jax.Array:
    """MToNPartitioning: re-key data across the axis (all_to_all)."""
    return jax.lax.all_to_all(x, axis, split_axis=split_dim,
                              concat_axis=concat_dim, tiled=True)


def partition_merge(x: jax.Array, axis: str, *, scatter_dim: int) -> jax.Array:
    """MToNPartitioningMerging: combine + repartition (reduce_scatter)."""
    return jax.lax.psum_scatter(x, axis, scatter_dimension=scatter_dim,
                                tiled=True)


def hierarchical_psum(x: jax.Array, axes: Sequence[str]) -> jax.Array:
    """LocalityAware fan-in: reduce over the cheapest axes first.  Axes must
    be ordered fastest-link-first (e.g. ("model", "data", "pod"))."""
    for ax in axes:
        x = jax.lax.psum(x, ax)
    return x


# ---------------------------------------------------------------------------
# Gradient compression (beyond-paper distributed-optimization trick)
# ---------------------------------------------------------------------------

def int8_encode(x: jax.Array, *, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decode(q: jax.Array, scale: jax.Array, shape: Tuple[int, ...],
                dtype=jnp.float32) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jax.Array, axis: str, *, block: int = 256) -> jax.Array:
    """All-reduce of an int8-compressed tensor over ``axis``.

    Quantize -> all_gather(q, scales) -> dequantize + sum.  For an axis of
    size A this moves ~A * n * (1 + 4/block) bytes instead of the 4n-byte
    float ring all-reduce; at A=2 (pod axis) the wire bytes drop ~3.8x.
    The quantization error is bounded by scale/2 per element; pair with
    error feedback (optim.grad_compress) for training-neutral behavior.
    """
    q, scale = int8_encode(x, block=block)
    qg = jax.lax.all_gather(q, axis)            # [A, nblk, block] int8
    sg = jax.lax.all_gather(scale, axis)        # [A, nblk, 1] f32
    deq = qg.astype(jnp.float32) * sg
    total = jnp.sum(deq, axis=0)
    n = 1
    for d in x.shape:
        n *= d
    return total.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Logsumexp merge — the LSM component-merge, distributed
# ---------------------------------------------------------------------------

def logsumexp_merge(partials: Sequence[Tuple[jax.Array, jax.Array, jax.Array]]
                    ) -> jax.Array:
    """Merge per-component partial attention results.

    Each partial is (out, m, l): un-normalized weighted value sum ``out`` with
    running max ``m`` and normalizer ``l`` (flash-attention state).  Merging K
    partials is associative/commutative — exactly the property LSM merge
    relies on for disk components (paper §4.3) — so components can be merged
    in any order, pairwise, or across mesh shards via psum.
    """
    out, m, l = partials[0]
    for o2, m2, l2 in partials[1:]:
        m_new = jnp.maximum(m, m2)
        a = jnp.exp(m - m_new)
        b = jnp.exp(m2 - m_new)
        out = out * a[..., None] + o2 * b[..., None]
        l = l * a + l2 * b
        m = m_new
    return out / jnp.maximum(l, 1e-20)[..., None]


def distributed_logsumexp_merge(out: jax.Array, m: jax.Array, l: jax.Array,
                                axis: str) -> jax.Array:
    """Merge flash-attention partials held by shards along ``axis``.

    Used for context-parallel decode: each shard attends over its KV slice;
    the merge is two cheap collectives (max + weighted psum) instead of
    gathering the KV cache.
    """
    m_glob = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_glob)
    out = jax.lax.psum(out * corr[..., None], axis)
    l = jax.lax.psum(l * corr, axis)
    return out / jnp.maximum(l, 1e-20)[..., None]
