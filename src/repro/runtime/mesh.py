"""Device-mesh construction and axis conventions.

Axis semantics (cf. docs/ARCHITECTURE.md §Mesh and collectives):
  pod    — data-parallel replica groups across pods (slowest links / DCN)
  data   — FSDP + batch partitioning within a pod
  model  — tensor/expert parallelism (fastest collectives)

Nothing in this module touches jax device state at import time; meshes are
built by functions so that ``XLA_FLAGS=--xla_force_host_platform_device_count``
set by a launcher before first jax use is respected.
"""

from __future__ import annotations

import contextlib
import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5 has explicit axis types; 0.4.x does not
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

__all__ = [
    "make_mesh", "make_host_mesh", "batch_axes", "mesh_axis_size",
    "current_mesh", "use_mesh", "MESH_AXES",
]

MESH_AXES = ("pod", "data", "model")

_ACTIVE_MESH: list = []


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` pinned to Auto axis types (we steer sharding with
    constraints, the GSPMD analogue of Algebricks' partitioning properties)."""
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} / axes {axes} rank mismatch")
    need = int(np.prod(shape))
    have = len(jax.devices())
    if need > have:
        raise ValueError(
            f"mesh {tuple(shape)} needs {need} devices but only {have} are "
            f"visible; launchers must set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count before importing jax")
    kwargs = ({"axis_types": (AxisType.Auto,) * len(axes)}
              if AxisType is not None else {})
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh for CPU tests; collapses to whatever devices exist."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return make_mesh((data, model), ("data", "model"))


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes over which the batch (and gradients) are partitioned."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def current_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH[-1] if _ACTIVE_MESH else None


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate a mesh both for our constraint helpers and as the jax mesh
    context (so ``with_sharding_constraint`` resolves named axes)."""
    _ACTIVE_MESH.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _ACTIVE_MESH.pop()
