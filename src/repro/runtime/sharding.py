"""Logical-axis sharding rules — the Algebricks analogue for tensors.

AsterixDB's optimizer (paper §4.2, §5.1) is *rule-based*: deterministic "safe"
rewrites assign partitioning properties to each operator, and data only moves
when the required property differs from the delivered one.  We port that idea:
every tensor dimension carries a *logical axis name*; a rule table maps logical
axes to mesh axes; ``constrain`` applies the resulting PartitionSpec.  GSPMD
then inserts the minimal exchanges (collectives) exactly where partitioning
changes — the Connector-insertion step of Hyracks job construction.

Rules are *safe* in the paper's sense: a mapping is dropped (axis replicated)
whenever the mesh axis does not divide the dimension, rather than failing.
Per-arch "hints" (paper Query 14) override entries in the table.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import current_mesh

__all__ = ["ShardingRules", "DEFAULT_RULES", "LONG_CONTEXT_RULES",
           "DECODE_KVSEQ_RULES", "resolve_spec", "constrain",
           "named_sharding", "logical_axes_spec"]

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """Ordered mapping logical-axis -> mesh axis (or tuple of mesh axes)."""

    table: Tuple[Tuple[str, MeshAxes], ...]

    def lookup(self, logical: str) -> MeshAxes:
        for k, v in self.table:
            if k == logical:
                return v
        return None

    def override(self, **kv: MeshAxes) -> "ShardingRules":
        """Per-arch hints (paper §5.1 'query optimization hints')."""
        tbl = [(k, kv.pop(k)) if k in kv else (k, v) for k, v in self.table]
        tbl.extend(kv.items())
        return ShardingRules(tuple(tbl))


# The "safe rules" table.  Activation batch over (pod, data); model-parallel
# width axes over `model`; parameter non-width axes over `data` (= FSDP / ZeRO-3,
# the tensor analogue of hash-partitioning datasets by primary key).
DEFAULT_RULES = ShardingRules((
    # -- activations
    ("batch",        ("pod", "data")),
    ("seq",          None),
    ("act_model",    None),          # d_model of activations: replicated
    ("act_ff",       "model"),       # hidden activations: TP-sharded
    ("act_heads",    "model"),
    ("act_kv_heads", "model"),
    ("kv_seq",       None),          # KV-cache sequence axis
    ("head_dim",     None),
    ("act_experts",  "model"),
    # -- parameters (2-D sharded: width over `model`, depth over `data`)
    ("vocab",        "model"),
    ("d_model",      "data"),        # FSDP axis of weight matrices
    ("heads",        "model"),
    ("kv_heads",     "model"),
    ("d_ff",         "model"),
    ("experts",      "model"),
    ("ssm_state",    None),
    ("ssm_inner",    "model"),
    ("ssm_inner_act", "model"),      # activation twin of ssm_inner
    ("layers",       None),          # scan-over-layers leading axis
    ("conv_k",       None),
))

# Context-parallel overlay for long_500k decode (batch=1): the KV cache is
# sharded over BOTH batch-free axes; per-shard partial attention merges via
# logsumexp reductions (the distributed LSM-component merge —
# docs/ARCHITECTURE.md §Mesh and collectives).
LONG_CONTEXT_RULES = DEFAULT_RULES.override(
    kv_seq=("data", "model"),
    act_kv_heads=None,
    act_heads=None,
    batch="pod",
)

# Decode overlay for archs whose KV-head count does not divide the model
# axis (kv < 16): the cache's sequence axis takes `model` instead, otherwise
# a 32k decode cache replicates 16x and blows past HBM (observed 54 GiB/dev
# for internlm2 decode_32k before this rule).
DECODE_KVSEQ_RULES = DEFAULT_RULES.override(
    kv_seq="model",
    act_kv_heads=None,
    act_heads=None,
)


def _axes_tuple(a: MeshAxes) -> Tuple[str, ...]:
    if a is None:
        return ()
    if isinstance(a, str):
        return (a,)
    return tuple(a)


def resolve_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 rules: ShardingRules, mesh: Mesh) -> P:
    """Map logical axis names to a PartitionSpec, applying the safety rules:
    (1) a mesh axis may be used at most once; (2) the product of mesh-axis
    sizes must divide the dimension; otherwise the dim is replicated."""
    if len(shape) != len(logical):
        raise ValueError(f"shape {tuple(shape)} vs logical axes {logical}")
    used: set = set()
    spec = []
    for dim, name in zip(shape, logical):
        chosen: Tuple[str, ...] = ()
        if name is not None:
            want = [ax for ax in _axes_tuple(rules.lookup(name))
                    if ax in mesh.shape and ax not in used]
            # greedy prefix that divides the dimension
            acc = []
            prod = 1
            for ax in want:
                if dim % (prod * mesh.shape[ax]) == 0:
                    acc.append(ax)
                    prod *= mesh.shape[ax]
            chosen = tuple(acc)
            used.update(chosen)
        if len(chosen) == 0:
            spec.append(None)
        elif len(chosen) == 1:
            spec.append(chosen[0])
        else:
            spec.append(chosen)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def named_sharding(shape: Sequence[int], logical: Sequence[Optional[str]],
                   rules: ShardingRules, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, logical, rules, mesh))


def constrain(x: jax.Array, logical: Sequence[Optional[str]],
              rules: ShardingRules = DEFAULT_RULES,
              mesh: Optional[Mesh] = None) -> jax.Array:
    """``with_sharding_constraint`` driven by logical axis names.  No-op when
    no mesh is active (single-device tests).  Falls back to the jax
    ``with mesh:`` context when our own use_mesh() stack is empty."""
    mesh = mesh or current_mesh()
    if mesh is None:
        try:
            from jax._src import mesh as _mesh_lib
            env_mesh = _mesh_lib.thread_resources.env.physical_mesh
        except (ImportError, AttributeError):  # pragma: no cover
            from jax.interpreters import pxla
            env_mesh = pxla.thread_resources.env.physical_mesh
        if not env_mesh.empty:
            mesh = env_mesh
    if mesh is None or np.prod(list(mesh.shape.values())) == 1:
        return x
    spec = resolve_spec(x.shape, logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def logical_axes_spec(logical: Sequence[Optional[str]],
                      rules: ShardingRules, mesh: Mesh,
                      shape: Sequence[int]) -> P:
    """Public alias used by checkpoint restore to recompute specs on a new
    mesh (elastic scaling)."""
    return resolve_spec(shape, logical, rules, mesh)
