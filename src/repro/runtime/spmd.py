"""Mesh-parallel SPMD partition runtime: one ``shard_map`` dispatch for
all partitions instead of a python loop over them.

The paper's runtime is shared-nothing partitioned parallelism — Hyracks
operators run once per partition and Connectors move data between them.
Nine PRs in, our columnar engine still executed that model as a python
loop: per partition, one fused-chain / mask / aggregate dispatch plus a
``device_get``.  This module is the mesh analogue (ROADMAP item 2,
docs/ARCHITECTURE.md §"SPMD partition runtime"): per-partition
pow2-padded operands are stacked along a leading partition axis
(:class:`StackCache` keeps the stacked array identity-stable so the
device pool keeps it resident), a ``shard_map`` over the partition mesh
(axis ``"part"``) runs the same per-partition kernel body on every
shard via ``vmap``, and results come back in one transfer.  Hash
repartitioning lowers onto ``runtime/collectives.partition_by``
(``all_to_all``) and partial-aggregate merging onto column-wise
``psum``/``pmin``/``pmax`` collectives.

Activation is explicit and ambient: ``with use_partition_mesh(4):``
(or ``run_query(..., mesh=...)``) turns the SPMD paths on; with no
active mesh every consumer keeps the 1-device python-loop fallback, and
``tests/test_differential.py`` locks the two bit-for-bit.  Single-host
multi-device comes from ``XLA_FLAGS=--xla_force_host_platform_device_
count=N`` set before the first jax import (the CI mesh leg and
``benchmarks/mesh_bench.py`` do this); nothing here touches jax device
state at import time.

Metrics (docs/METRICS.md §mesh):

  mesh.devices                  gauge: active partition-mesh size (0 when
                                no mesh is active)
  mesh.spmd_dispatches          counter: shard_map'ed SPMD dispatches
  mesh.spmd_partitions          counter: partitions covered by those
                                dispatches (loop dispatches would have
                                paid one call each)
  mesh.spmd_fallbacks           counter: SPMD-eligible calls that fell
                                back to the python loop (operand shape /
                                dtype disagreement across partitions)
  mesh.exchange_rows            counter: rows moved by the all_to_all
                                device exchange (connector repartition)
  mesh.shard<k>.h2d_bytes       counter: per-shard share of sharded
                                uploads (``fetch_sharded``)
  mesh.partitions_per_dispatch  histogram: stacked partition count per
                                SPMD dispatch
"""

from __future__ import annotations

import contextlib
import functools
import threading
import weakref
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from .. import obs
from ..kernels import device_pool as _pool
from ..obs import record_dispatch as _record_dispatch
from ..obs import record_retrace as _record_retrace
from .collectives import partition_by

__all__ = [
    "PART_AXIS", "partition_mesh", "use_partition_mesh", "active_mesh",
    "mesh_key", "dispatch_totals", "StackCache", "stack_cache",
    "fetch_sharded", "batched_range_masks", "batched_select_aggregate",
    "exchange_batches", "psum_merge", "pmin_merge", "pmax_merge",
]

PART_AXIS = "part"

_DEVICES = obs.gauge("mesh.devices")
_DISPATCHES = obs.counter("mesh.spmd_dispatches")
_PARTITIONS = obs.counter("mesh.spmd_partitions")
_FALLBACKS = obs.counter("mesh.spmd_fallbacks")
_EXCH_ROWS = obs.counter("mesh.exchange_rows")
_PART_HIST = obs.histogram("mesh.partitions_per_dispatch")


# ---------------------------------------------------------------------------
# partition mesh context
# ---------------------------------------------------------------------------

_ACTIVE: List[Mesh] = []


def partition_mesh(devices: Optional[int] = None) -> Mesh:
    """1-d mesh over the first ``devices`` jax devices with the partition
    axis ``"part"``.  ``devices=None`` takes every visible device."""
    devs = jax.devices()
    n = len(devs) if devices is None else int(devices)
    if n < 1 or n > len(devs):
        raise ValueError(
            f"partition mesh wants {n} devices but {len(devs)} are visible; "
            f"launchers must set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count before importing jax")
    return Mesh(np.asarray(devs[:n]), (PART_AXIS,))


@contextlib.contextmanager
def use_partition_mesh(devices: Optional[int] = None,
                       mesh: Optional[Mesh] = None):
    """Activate a partition mesh for the executor's SPMD paths.  Inside
    the context, eligible per-partition loops (index chains, select
    masks, fused aggregates, hash exchanges) run as one ``shard_map``
    dispatch; outside it the python loop is the unconditional path."""
    m = mesh if mesh is not None else partition_mesh(devices)
    if PART_AXIS not in m.axis_names:
        raise ValueError(f"mesh {m} has no '{PART_AXIS}' axis")
    _ACTIVE.append(m)
    _DEVICES.set(int(m.devices.size))
    try:
        yield m
    finally:
        _ACTIVE.pop()
        _DEVICES.set(int(_ACTIVE[-1].devices.size) if _ACTIVE else 0)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE[-1] if _ACTIVE else None


def mesh_key(mesh: Optional[Mesh] = None) -> Optional[Tuple]:
    """Hashable mesh signature for plan-cache keys: plan shapes compiled
    for the loop, a 2-device mesh, and a 4-device mesh are distinct
    entries (the jitted programs differ)."""
    m = mesh if mesh is not None else active_mesh()
    if m is None:
        return None
    return (PART_AXIS, int(m.devices.size),
            tuple(int(d.id) for d in m.devices.flat))


def mesh_size() -> int:
    m = active_mesh()
    return int(m.devices.size) if m is not None else 0


def dispatch_totals() -> Tuple[int, int]:
    """(spmd dispatches, partitions covered) — ExecStats diffs these per
    query, mirroring ``obs.kernel_totals``."""
    return (_DISPATCHES.value, _PARTITIONS.value)


def rows_for(n_real: int, mesh: Mesh) -> int:
    """Stack row count: partitions padded up to a multiple of the mesh
    size so shard_map's leading-axis split is even."""
    d = int(mesh.devices.size)
    return max(-(-n_real // d) * d, d)


_rows_for = rows_for


def note_fallback() -> None:
    """Count one SPMD-eligible call that fell back to the python loop
    (cross-partition operand drift)."""
    _FALLBACKS.inc()


def _note_spmd(mesh: Mesh, n_parts: int) -> None:
    _DISPATCHES.inc()
    _PARTITIONS.inc(n_parts)
    _PART_HIST.observe(n_parts)


# ---------------------------------------------------------------------------
# stacked-operand cache (identity-stable, so the device pool can keep the
# sharded upload resident across queries: warm mesh queries h2d == 0)
# ---------------------------------------------------------------------------

class StackCache:
    """Memoized stacking of per-partition pow2-padded operands along a
    leading partition axis.  Keyed by the identity of every input array
    plus the output geometry, guarded by weak references (any input
    dying drops the entry, and the stacked array's own death evicts its
    device copy through the pool's finalizer).  Entries are capped FIFO
    as a leak backstop — the working set is one stack per pooled operand
    per live LSM version, far under the cap."""

    def __init__(self, max_entries: int = 4096) -> None:
        self._lock = threading.Lock()
        self._entries: Dict[Tuple, Tuple[Tuple, np.ndarray, List]] = {}
        self._max = max_entries

    def stack(self, arrs: Sequence[Optional[np.ndarray]], rows: int,
              width: int, dtype: Any, fill: Any = 0) -> np.ndarray:
        """``[rows, width]`` array whose row ``i`` is ``arrs[i]`` (zero-
        padded to ``width``); ``None`` inputs and rows past ``len(arrs)``
        are ``fill``-rows (their lanes must be masked out by the
        caller's validity/liveness conjuncts)."""
        key = (tuple(0 if a is None else id(a) for a in arrs),
               rows, width, np.dtype(dtype).str, repr(fill))
        with self._lock:
            e = self._entries.get(key)
            if e is not None and all(
                    r() is a for r, a in zip(e[0], arrs) if r is not None):
                return e[1]
        out = np.full((rows, width), fill, dtype=dtype)
        for i, a in enumerate(arrs):
            if a is not None and a.shape[0]:
                out[i, :a.shape[0]] = a
        refs = tuple(None if a is None else weakref.ref(a) for a in arrs)
        fins = []
        for a in arrs:
            if a is not None:
                fin = weakref.finalize(a, self._drop, key)
                fin.atexit = False
                fins.append(fin)
        with self._lock:
            if len(self._entries) >= self._max:
                oldest = next(iter(self._entries))
                self._drop_locked(oldest)
            self._entries[key] = (refs, out, fins)
        return out

    def _drop(self, key: Tuple) -> None:
        with self._lock:
            self._drop_locked(key)

    def _drop_locked(self, key: Tuple) -> None:
        e = self._entries.pop(key, None)
        if e is not None:
            for fin in e[2]:
                fin.detach()

    def clear(self) -> int:
        with self._lock:
            n = len(self._entries)
            for key in list(self._entries):
                self._drop_locked(key)
            return n

    def entry_count(self) -> int:
        return len(self._entries)


stack_cache = StackCache()


def fetch_sharded(arrs: Sequence[Any], mesh: Mesh
                  ) -> Tuple[List[Any], List[Any]]:
    """Pool-fetch stacked operands placed as partition-sharded device
    arrays (leading axis split over the mesh).  First touch uploads and
    is attributed per shard (``mesh.shard<k>.h2d_bytes``) on top of the
    usual kernel h2d accounting; later touches are pool hits, so a warm
    mesh query ships nothing."""
    placement = NamedSharding(mesh, PS(PART_AXIS))
    ops, missed = _pool.fetch(arrs, placement=placement)
    if missed:
        d = int(mesh.devices.size)
        for a in missed:
            per = int(a.nbytes) // d
            for k in range(d):
                obs.counter(f"mesh.shard{k}.h2d_bytes").inc(per)
    return ops, missed


# ---------------------------------------------------------------------------
# shard_map'ed kernel bodies (per-partition math vmapped over the local
# block; one jit trace per (mesh, structure, bucket) — counted exactly
# like the loop cores so retrace assertions keep holding)
# ---------------------------------------------------------------------------

def _traces() -> Dict[str, int]:
    from ..kernels.columnar_ops import _TRACES
    return _TRACES


@functools.lru_cache(maxsize=256)
def _chain_fn(mesh: Mesh, tiers_struct: Tuple[int, ...], n_preds: int,
              n_aggs: int, total_p2: int, live_p2: int):
    """jit(shard_map(vmap(chain math))) for one chain structure: the
    same fused Figure-6 math as ``plancache._chain_core``, run on every
    partition lane of the local shard."""
    from ..columnar.plancache import _chain_math
    tr = _traces()

    def body(tiers, bounds, idx_pad, n_live, preds, aggds):
        tr["n"] += 1
        _record_retrace()

        def one(args):
            t, b, ix, nl, pr, ag = args
            return _chain_math(t, b, ix, nl, pr, ag, total_p2, live_p2)
        return jax.vmap(one)((tiers, bounds, idx_pad, n_live, preds, aggds))

    fn = shard_map(body, mesh=mesh, in_specs=PS(PART_AXIS),
                   out_specs=PS(PART_AXIS))
    return jax.jit(fn)


def run_chain_stack(mesh: Mesh, tiers, bounds, idx_pad, n_live, preds,
                    aggds, total_p2: int, live_p2: int, n_parts: int):
    """Dispatch one stacked chain (plancache.run_all's device half).
    Stacked pooled operands go through :func:`fetch_sharded`; bound
    scalars stay dynamic [R] operands (excluded from h2d accounting by
    the kernel convention).  Returns host (n_cand, n_found, n_valid,
    mask, per_col) arrays with a leading partition-row axis."""
    tiers_struct = tuple(len(fp) for fp in tiers)
    flat: List[np.ndarray] = []
    for fp in tiers:
        flat.extend(fp)
    flat.append(idx_pad)
    for d, v, _lo, _hi in preds:
        flat.extend((d, v))
    for d, v in aggds:
        flat.extend((d, v))
    ops, missed = fetch_sharded(flat, mesh)
    it = iter(ops)
    dev_tiers = tuple(tuple(next(it) for _ in fp) for fp in tiers)
    dev_idx = next(it)
    dev_preds = tuple((next(it), next(it), lo, hi)
                      for _d, _v, lo, hi in preds)
    dev_aggs = tuple((next(it), next(it)) for _ in aggds)
    fn = _chain_fn(mesh, tiers_struct, len(preds), len(aggds),
                   total_p2, live_p2)
    with enable_x64():
        outs = fn(dev_tiers, bounds, dev_idx, n_live, dev_preds, dev_aggs)
        n_cand, n_found, n_valid, mask, per_col = jax.device_get(outs)
    mask_np = np.asarray(mask)
    _record_dispatch("spmd_index_chain", h2d=missed, d2h=[mask_np])
    _note_spmd(mesh, n_parts)
    return n_cand, n_found, n_valid, mask_np, per_col


@functools.lru_cache(maxsize=128)
def _mask_fn(mesh: Mesh, n_preds: int, live_p2: int):
    """Stacked twin of ``columnar_ops._mask_core`` (same conjunct order,
    so masks are bit-identical to the loop kernel's)."""
    tr = _traces()

    def body(datas, valids, los, his):
        tr["n"] += 1
        _record_retrace()

        def one(args):
            ds, vs, ls, hs = args
            m = None
            for x, v, lo, hi in zip(ds, vs, ls, hs):
                mm = v & (x >= lo) & (x <= hi)
                m = mm if m is None else (m & mm)
            return m
        return jax.vmap(one)((datas, valids, los, his))

    fn = shard_map(body, mesh=mesh, in_specs=PS(PART_AXIS),
                   out_specs=PS(PART_AXIS))
    return jax.jit(fn)


@functools.lru_cache(maxsize=128)
def _agg_fn(mesh: Mesh, n_preds: int, n_aggs: int, live_p2: int):
    """Stacked twin of ``columnar_ops._agg_core`` plus the mask (the
    caller's non-kernelable aggregate columns reduce host-side over the
    mask-filtered batch, exactly like ``operators.aggregate_batch``)."""
    from ..kernels.columnar_ops import _ident
    tr = _traces()

    def body(datas, valids, los, his, adatas, avalids):
        tr["n"] += 1
        _record_retrace()

        def one(args):
            ds, vs, ls, hs, ads, avs = args
            mask = None
            for x, v, lo, hi in zip(ds, vs, ls, hs):
                mm = v & (x >= lo) & (x <= hi)
                mask = mm if mask is None else (mask & mm)
            total = jnp.sum(mask)
            per_col = []
            for x, v in zip(ads, avs):
                ok = mask & v
                cnt = jnp.sum(ok)
                s = jnp.sum(jnp.where(ok, x, jnp.asarray(0, x.dtype)))
                mn = jnp.min(jnp.where(ok, x, _ident(x.dtype, True)))
                mx = jnp.max(jnp.where(ok, x, _ident(x.dtype, False)))
                per_col.append((s, mn, mx, cnt))
            return total, mask, tuple(per_col)
        return jax.vmap(one)((datas, valids, los, his, adatas, avalids))

    fn = shard_map(body, mesh=mesh, in_specs=PS(PART_AXIS),
                   out_specs=PS(PART_AXIS))
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# batched select masks (STREAM_SELECT over all partitions at once)
# ---------------------------------------------------------------------------

def _stack_preds(entries, ranges_len: int, mesh: Mesh
                 ) -> Optional[Tuple]:
    """Stack per-partition kernel predicates into [R, live_p2] operands
    plus [R] bound vectors.  ``entries`` is [(partition, preds, ...)];
    returns None when dtypes disagree across partitions (rare open-type
    drift — the python loop handles it)."""
    from ..kernels.columnar_ops import _prep_bounds
    preds0 = entries[0][1]
    dts = tuple(str(p[0].dtype) for p in preds0)
    for e in entries[1:]:
        if tuple(str(p[0].dtype) for p in e[1]) != dts:
            return None
    live_p2 = max(int(p[0].shape[0]) for e in entries for p in e[1])
    rows = _rows_for(len(entries), mesh)
    datas, valids, los, his = [], [], [], []
    for j in range(ranges_len):
        dt0 = entries[0][1][j][0].dtype
        d_list = [e[1][j][0] for e in entries]
        v_list = [e[1][j][1] for e in entries]
        datas.append(stack_cache.stack(d_list, rows, live_p2, dt0))
        valids.append(stack_cache.stack(v_list, rows, live_p2, np.bool_,
                                        fill=False))
        lo_a = np.zeros(rows, dtype=dt0)
        hi_a = np.zeros(rows, dtype=dt0)
        for r, e in enumerate(entries):
            _d, _v, lo, hi = e[1][j]
            blo, bhi = _prep_bounds(_d, lo, hi)
            lo_a[r], hi_a[r] = blo, bhi
        los.append(lo_a)
        his.append(hi_a)
    return datas, valids, los, his, live_p2, rows


def batched_range_masks(batches: Sequence[Any],
                        ranges: Dict[str, Tuple[Any, Any]]
                        ) -> Optional[List[Optional[np.ndarray]]]:
    """All partitions' ``K.range_mask`` in one shard_map dispatch.
    Returns per-partition boolean masks (None entries: partition needs
    the host path — empty batch or absent column), or None when the
    whole select should stay on the python loop."""
    mesh = active_mesh()
    if mesh is None or not ranges:
        return None
    from ..columnar import operators as O
    entries = []            # (partition index, preds)
    for i, b in enumerate(batches):
        if len(b) == 0:
            continue
        made = O.make_range_preds(b, ranges)
        if made is None:
            _FALLBACKS.inc()
            return None     # not vectorizable anywhere: row-engine path
        if made is O.EMPTY:
            continue        # host short-circuit (empty result)
        entries.append((i, made))
    if len(entries) < 2:
        return None         # nothing to gain from a collective dispatch
    stacked = _stack_preds(entries, len(ranges), mesh)
    if stacked is None:
        _FALLBACKS.inc()
        return None
    datas, valids, los, his, live_p2, rows = stacked
    k = len(datas)
    flat = list(datas) + list(valids)
    ops, missed = fetch_sharded(flat, mesh)
    fn = _mask_fn(mesh, k, live_p2)
    with enable_x64():
        out = np.asarray(jax.device_get(
            fn(tuple(ops[:k]), tuple(ops[k:]), tuple(los), tuple(his))))
    _record_dispatch("spmd_range_mask", h2d=missed, d2h=[out])
    _note_spmd(mesh, len(entries))
    result: List[Optional[np.ndarray]] = [None] * len(batches)
    for r, (i, _preds) in enumerate(entries):
        result[i] = out[r, :len(batches[i])]
    return result


# ---------------------------------------------------------------------------
# batched fused select+aggregate (LOCAL_AGG over an exact-range select)
# ---------------------------------------------------------------------------

def batched_select_aggregate(batches: Sequence[Any],
                             ranges: Dict[str, Tuple[Any, Any]],
                             aggs: Dict[str, Tuple[str, str]]
                             ) -> Optional[List[Optional[Tuple]]]:
    """All partitions' ``fused_select_aggregate`` in one shard_map
    dispatch.  Returns per-partition ``(row, survivors)`` results (None
    entries fall back to the per-partition host kernel), or None when
    partitions disagree structurally and the loop should run."""
    mesh = active_mesh()
    if mesh is None or not ranges:
        return None
    from ..columnar import operators as O
    entries = []   # (i, preds, n, arrays, meta, batch)
    for i, b in enumerate(batches):
        n = len(b)
        if n == 0:
            continue
        made = O.make_range_preds(b, ranges)
        if made is None:
            _FALLBACKS.inc()
            return None
        if made is O.EMPTY:
            continue
        arrays, meta = O._kernel_agg_cols(b, aggs)
        entries.append((i, made, n, arrays, meta, b))
    if len(entries) < 2:
        return None
    sig0 = tuple((m[0], m[1], m[2]) for m in entries[0][4])
    adts = tuple(str(a[0].dtype) for a in entries[0][3])
    for e in entries[1:]:
        if tuple((m[0], m[1], m[2]) for m in e[4]) != sig0 \
                or tuple(str(a[0].dtype) for a in e[3]) != adts:
            _FALLBACKS.inc()
            return None
    stacked = _stack_preds(entries, len(ranges), mesh)
    if stacked is None:
        _FALLBACKS.inc()
        return None
    datas, valids, los, his, live_p2, rows = stacked
    live_p2 = max([live_p2] + [int(a[0].shape[0])
                               for e in entries for a in e[3]])
    if live_p2 != stacked[4]:
        # aggregate columns sit in a larger bucket: restack predicates
        stacked = None
    if stacked is None:
        return None      # pred/agg bucket split: loop path (rare)
    m = len(adts)
    adatas, avalids = [], []
    for j in range(m):
        dt0 = entries[0][3][j][0].dtype
        adatas.append(stack_cache.stack([e[3][j][0] for e in entries],
                                        rows, live_p2, dt0))
        avalids.append(stack_cache.stack([e[3][j][1] for e in entries],
                                         rows, live_p2, np.bool_,
                                         fill=False))
    k = len(datas)
    flat = list(datas) + list(valids) + adatas + avalids
    ops, missed = fetch_sharded(flat, mesh)
    fn = _agg_fn(mesh, k, m, live_p2)
    with enable_x64():
        outs = fn(tuple(ops[:k]), tuple(ops[k:2 * k]),
                  tuple(los), tuple(his),
                  tuple(ops[2 * k:2 * k + m]), tuple(ops[2 * k + m:]))
        total_a, mask_a, per_col_a = jax.device_get(outs)
    mask_np = np.asarray(mask_a)
    _record_dispatch("spmd_filter_aggregate", h2d=missed, d2h=[mask_np])
    _note_spmd(mesh, len(entries))
    result: List[Optional[Tuple]] = [None] * len(batches)
    for r, (i, _preds, n, _arrays, meta, b) in enumerate(entries):
        res = {"count": int(total_a[r]), "sums": [], "mins": [],
               "maxs": [], "cnts": []}
        for s, mn, mx, cnt in per_col_a:
            c = int(cnt[r])
            res["cnts"].append(c)
            res["sums"].append(s[r].item())
            res["mins"].append(mn[r].item() if c else None)
            res["maxs"].append(mx[r].item() if c else None)
        row_mask = mask_np[r]
        result[i] = O._finish_aggregate(
            aggs, meta, res, True,
            lambda bb=b, mm=row_mask, nn=n: bb.filter(mm[:nn]))
    return result


# ---------------------------------------------------------------------------
# hash repartitioning on the mesh (MToNPartitioningConnector -> all_to_all)
# ---------------------------------------------------------------------------

_EXCHANGE_KINDS = ("i64", "f64", "bool", "dt", "date")


@functools.lru_cache(maxsize=64)
def _exchange_fn(mesh: Mesh, n_arrays: int, cap: int):
    tr = _traces()

    def body(*arrs):
        tr["n"] += 1
        _record_retrace()
        outs = []
        for x in arrs:          # local [1, p, cap]
            y = partition_by(x[0], PART_AXIS, split_dim=0, concat_dim=0)
            outs.append(y[None])
        return tuple(outs)

    fn = shard_map(body, mesh=mesh, in_specs=PS(PART_AXIS),
                   out_specs=PS(PART_AXIS))
    return jax.jit(fn)


def exchange_batches(cparts: Sequence[Any], keys: Sequence[str], p: int
                     ) -> Optional[Tuple[List[Any], int]]:
    """Hash-repartition ColumnBatches across the mesh with one tiled
    ``all_to_all`` per column plane (MToNPartitioningConnector lowered
    onto the ICI collective, paper §4.1).  Placement and row order are
    bit-identical to the host bucketing path (same ``partition_ids``
    hash, source-major row order).  Returns (batches, rows moved), or
    None when the exchange must stay host-side: mesh size != partition
    count, schema drift across partitions, or non-numeric (string/obj)
    columns whose dictionary codes are partition-local."""
    mesh = active_mesh()
    if mesh is None or int(mesh.devices.size) != p or p < 2:
        return None
    from ..columnar import operators as O
    from ..columnar.batch import Column, ColumnBatch, pow2_len
    schema: Optional[Tuple] = None
    for b in cparts:
        if not len(b):
            continue
        sig = tuple(sorted((nm, c.kind) for nm, c in b.columns.items()))
        if any(kd not in _EXCHANGE_KINDS for _nm, kd in sig):
            return None
        if schema is None:
            schema = sig
        elif sig != schema:
            _FALLBACKS.inc()
            return None
    if schema is None:
        return None                      # all partitions empty: host path
    counts = np.zeros((p, p), dtype=np.int64)
    orders: List[Optional[np.ndarray]] = []
    moved = 0
    for i, b in enumerate(cparts):
        if not len(b):
            orders.append(None)
            continue
        pid = O.partition_ids(b, keys, p)
        moved += int((pid != i).sum())
        orders.append(np.argsort(pid, kind="stable"))
        counts[i] = np.bincount(pid, minlength=p)
    cap = pow2_len(int(counts.max()))
    if cap == 0:
        return None
    names = [nm for nm, _kd in schema]
    kinds = dict(schema)
    ref = next(b for b in cparts if len(b))
    send: List[np.ndarray] = []
    for nm in names:
        dt0 = ref.columns[nm].data.dtype
        data_s = np.zeros((p, p, cap), dtype=dt0)
        valid_s = np.zeros((p, p, cap), dtype=bool)
        for i, b in enumerate(cparts):
            if not len(b):
                continue
            col = b.columns[nm]
            d_srt = col.data[orders[i]]
            v_srt = col.valid[orders[i]]
            offs = np.concatenate([[0], np.cumsum(counts[i])])
            for j in range(p):
                a, z = int(offs[j]), int(offs[j + 1])
                data_s[i, j, :z - a] = d_srt[a:z]
                valid_s[i, j, :z - a] = v_srt[a:z]
        send.extend((data_s, valid_s))
    fn = _exchange_fn(mesh, len(send), cap)
    with enable_x64():
        recv = [np.asarray(a) for a in jax.device_get(fn(*send))]
    _record_dispatch("spmd_exchange", h2d=send, d2h=recv)
    _note_spmd(mesh, p)
    _EXCH_ROWS.inc(moved)
    out: List[Any] = []
    for j in range(p):
        n_j = int(counts[:, j].sum())
        if n_j == 0:
            out.append(ColumnBatch({}, 0))
            continue
        cols: Dict[str, Column] = {}
        for c_idx, nm in enumerate(names):
            recv_d = recv[2 * c_idx]
            recv_v = recv[2 * c_idx + 1]
            data = np.concatenate(
                [recv_d[j, i, :counts[i, j]] for i in range(p)])
            valid = np.concatenate(
                [recv_v[j, i, :counts[i, j]] for i in range(p)])
            cols[nm] = Column(kinds[nm], data, valid, None)
        out.append(ColumnBatch(cols, n_j))
    return out, moved


# ---------------------------------------------------------------------------
# column-wise collective merge of partial aggregates
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _merge_fn(mesh: Mesh, op: str):
    tr = _traces()
    local = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[op]
    glob = {"sum": jax.lax.psum, "min": jax.lax.pmin, "max": jax.lax.pmax}[op]

    def body(x):                         # local [R/D, M]
        tr["n"] += 1
        _record_retrace()
        return glob(local(x, axis=0), PART_AXIS)

    fn = shard_map(body, mesh=mesh, in_specs=PS(PART_AXIS),
                   out_specs=PS())
    return jax.jit(fn)


def _collective_merge(parts: np.ndarray, op: str,
                      mesh: Optional[Mesh]) -> np.ndarray:
    m = mesh if mesh is not None else active_mesh()
    if m is None:
        raise RuntimeError("no active partition mesh")
    parts = np.asarray(parts)
    if parts.ndim == 1:
        parts = parts[:, None]
        squeeze = True
    else:
        squeeze = False
    rows = _rows_for(parts.shape[0], m)
    if rows != parts.shape[0]:
        if op == "sum":
            fill = np.zeros((rows - parts.shape[0], parts.shape[1]),
                            dtype=parts.dtype)
        else:
            if np.issubdtype(parts.dtype, np.integer):
                info = np.iinfo(parts.dtype)
                ident = info.max if op == "min" else info.min
            else:
                ident = np.inf if op == "min" else -np.inf
            fill = np.full((rows - parts.shape[0], parts.shape[1]),
                           ident, dtype=parts.dtype)
        parts = np.concatenate([parts, fill])
    fn = _merge_fn(m, op)
    with enable_x64():
        out = np.asarray(jax.device_get(fn(parts)))
    _record_dispatch(f"spmd_merge_{op}", d2h=[out])
    _note_spmd(m, parts.shape[0])
    return out[:, 0] if squeeze and out.ndim == 2 else out


def psum_merge(parts: np.ndarray, mesh: Optional[Mesh] = None) -> np.ndarray:
    """Column-wise psum of per-partition partial aggregates [P, M] -> [M]
    (GLOBAL_AGG's sum/count merge as one collective; exact for the
    integer-domain aggregates the executor keys correctness on)."""
    return _collective_merge(parts, "sum", mesh)


def pmin_merge(parts: np.ndarray, mesh: Optional[Mesh] = None) -> np.ndarray:
    return _collective_merge(parts, "min", mesh)


def pmax_merge(parts: np.ndarray, mesh: Optional[Mesh] = None) -> np.ndarray:
    return _collective_merge(parts, "max", mesh)
