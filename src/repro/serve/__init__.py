"""Concurrent serving harness (paper §2.4, §4.4).

The paper's headline workload is continuous feed ingestion *while*
serving queries "with transaction support akin to that of a NoSQL
store".  This package provides the admission-controlled server loop
that drives both sides against one :class:`PartitionedDataset`:

* **ingest lanes** — N feed pumps, each an intake→compute→store
  :class:`~repro.data.feeds.Feed` whose store stage is a *bounded*
  queue (backpressure: block, never drop) drained by a sink worker
  delivering micro-batches via ``insert_batch``;
* **query lanes** — M workers running snapshot-isolated reads
  (``PartitionedDataset.pin()`` / ``run_query(snapshot=True)``) behind
  an admission controller capping in-flight queries;
* **fault tolerance** — ``checkpoint()`` quiesces the pipeline and
  captures every feed cursor; ``crash_and_recover()`` rebuilds the
  dataset from (components + WAL) and replays feeds from the last
  checkpoint — at-least-once delivery made exactly-once by PK-idempotent
  upserts.

Every query worker doubles as a consistency checker: lane-strided
primary keys make "some prefix of each lane's acknowledged inserts" the
exact snapshot invariant, so torn reads and lost acknowledged records
are *counted*, not hoped against.  See ``benchmarks/serve_bench.py``
for the mixed open-loop workload reporting sustained ingest rate and
p50/p99 query latency through the ``obs`` histograms.
"""

from .harness import (Admission, AdmissionController, BoundedSink,
                      IngestPump, QueryWorker, RequestRecord, RequestTracker,
                      ServeHarness, ServeReport, SinkWorker,
                      StridedRecordAdaptor)

__all__ = ["Admission", "AdmissionController", "BoundedSink", "IngestPump",
           "QueryWorker", "RequestRecord", "RequestTracker", "ServeHarness",
           "ServeReport", "SinkWorker", "StridedRecordAdaptor"]
