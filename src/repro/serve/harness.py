"""Admission-controlled concurrent serving loop: feeds in, queries out.

One :class:`ServeHarness` owns a :class:`PartitionedDataset` plus

* N ingest lanes — ``Feed`` pump threads whose store stage is a
  :class:`BoundedSink` (a bounded ``queue.Queue``: *block*, never drop)
  drained by one :class:`SinkWorker` per lane delivering micro-batches
  through ``insert_batch`` and acknowledging primary keys only after
  the insert returns;
* M :class:`QueryWorker` threads behind an :class:`AdmissionController`
  semaphore, alternating snapshot-isolated verification scans
  (``dataset.pin()``) with executor queries
  (``run_query(..., snapshot=True)``).

**The consistency invariant.**  Lane ``l`` of ``L`` inserts primary keys
``l, l+L, l+2L, ...`` in order, so any snapshot must contain, per lane,
exactly a *prefix* of that lane's key sequence — and at least every key
acknowledged before the snapshot was pinned.  A gap in a lane is a torn
read; a count below the pre-pin ack floor is a lost acknowledged write.
Both are counted (``serve.query.torn_reads`` / ``serve.query.lost_acks``)
on every verification scan, making the stress benchmark an oracle, not a
smoke test.

**Fault tolerance.**  ``checkpoint()`` parks the pumps, drains the
queues, and captures every feed's cursor state; ``crash_and_recover()``
rebuilds the dataset from (valid components + WAL), restores the feeds
from the last checkpoint, and resumes — records between checkpoint and
crash are replayed at-least-once and deduplicated by PK upsert.
"""

from __future__ import annotations

import queue
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

from .. import obs as _obs
from ..core import algebra as A
from ..data.feeds import Adaptor, Feed, FeedJoint
from ..storage.query import run_query

__all__ = ["AdmissionController", "BoundedSink", "IngestPump", "QueryWorker",
           "ServeHarness", "ServeReport", "SinkWorker",
           "StridedRecordAdaptor"]


# ---------------------------------------------------------------------------
# Workload pieces
# ---------------------------------------------------------------------------

def _default_record(pk: int) -> Dict[str, Any]:
    return {"pk": int(pk),
            "val": int((pk * 2654435761) % 100003),
            "text": f"rec-{pk % 97}"}


class StridedRecordAdaptor(Adaptor):
    """Deterministic record source for ingest lane ``lane`` of ``lanes``:
    the i-th record carries primary key ``i*lanes + lane``, so concurrent
    lanes never collide and each lane's key sequence is monotone — the
    property the snapshot-consistency oracle checks.  Seekable, so a feed
    ``restore()`` replays exactly."""

    def __init__(self, lane: int, lanes: int,
                 make_record: Optional[Callable[[int], Dict[str, Any]]] = None,
                 limit: Optional[int] = None):
        self.lane = int(lane)
        self.lanes = int(lanes)
        self.make_record = make_record or _default_record
        self.limit = limit
        self.cursor = 0

    def next_batch(self, n: int) -> List[Any]:
        if self.limit is not None:
            n = max(0, min(n, self.limit - self.cursor))
        out = [self.make_record((self.cursor + j) * self.lanes + self.lane)
               for j in range(n)]
        self.cursor += len(out)
        return out

    def seek(self, cursor: int) -> None:
        self.cursor = cursor


class BoundedSink:
    """Feed store stage pushing micro-batches onto a bounded queue.  A
    full queue *blocks* the pump (backpressure) instead of dropping —
    the fix for silent feed-side loss under a slow storage stage."""

    def __init__(self, q: "queue.Queue[List[Any]]"):
        self.q = q

    def __call__(self, records: Sequence[Any]) -> None:
        if records:
            self.q.put(list(records), block=True)


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

class AdmissionController:
    """Caps in-flight queries with a semaphore.  ``admit()`` either
    grants a slot within ``timeout`` seconds or rejects (counted in
    ``serve.admission.rejected``) — open-loop clients keep offering
    load; the controller sheds it instead of queueing unboundedly."""

    def __init__(self, max_inflight: int = 8, timeout: float = 0.2):
        self.max_inflight = int(max_inflight)
        self.timeout = float(timeout)
        self._sem = threading.Semaphore(self.max_inflight)
        self._lock = threading.Lock()
        self.admitted = 0
        self.rejected = 0
        self._inflight = _obs.gauge("serve.admission.inflight")
        self._rejected_c = _obs.counter("serve.admission.rejected")

    @contextmanager
    def admit(self) -> Iterator[bool]:
        ok = self._sem.acquire(timeout=self.timeout)
        if not ok:
            with self._lock:
                self.rejected += 1
            self._rejected_c.inc()
            yield False
            return
        with self._lock:
            self.admitted += 1
            self._inflight.set(self.max_inflight - self._sem._value)
        try:
            yield True
        finally:
            self._sem.release()


# ---------------------------------------------------------------------------
# Worker threads
# ---------------------------------------------------------------------------

class IngestPump(threading.Thread):
    """Runs one feed's intake→compute→store cycle until stopped or the
    adaptor is exhausted.  Parks (without consuming) while the harness
    gate is closed, so ``checkpoint()`` can quiesce the pipeline."""

    def __init__(self, feed: Feed, batch: int, gate: threading.Event,
                 stop: threading.Event):
        super().__init__(daemon=True, name=f"pump-{feed.name}")
        self.feed = feed
        self.batch = int(batch)
        self.gate = gate
        self.stop_ev = stop
        self.parked = threading.Event()
        self.exhausted = threading.Event()

    def run(self) -> None:
        while not self.stop_ev.is_set():
            if not self.gate.is_set():
                self.parked.set()
                self.gate.wait(0.02)
                continue
            self.parked.clear()
            self.feed.pump(self.batch)
            if self.feed.last_intake == 0:       # end of stream
                self.exhausted.set()
                self.parked.set()
                self.stop_ev.wait(0.02)
        self.parked.set()


class SinkWorker(threading.Thread):
    """Drains one ingest lane's bounded queue into the dataset and
    acknowledges primary keys *after* ``insert_batch`` returns — the ack
    list is the ground truth the consistency oracle checks against."""

    def __init__(self, harness: "ServeHarness", lane: int,
                 q: "queue.Queue[List[Any]]", stop: threading.Event):
        super().__init__(daemon=True, name=f"sink-{lane}")
        self.h = harness
        self.lane = lane
        self.q = q
        self.stop_ev = stop

    def run(self) -> None:
        ds, pk = self.h.dataset, self.h.dataset.pk
        acked_c = _obs.counter("serve.ingest.acked")
        while True:
            try:
                chunk = self.q.get(timeout=0.02)
            except queue.Empty:
                if self.stop_ev.is_set():
                    return
                continue
            try:
                ds.insert_batch(chunk)
                pks = [r[pk] for r in chunk]
                with self.h._ack_lock:
                    # a set, not a list: at-least-once replay after a
                    # crash re-delivers (and re-acks) records, and the
                    # consistency floor must count *distinct* acks
                    self.h.acked[self.lane].update(pks)
                acked_c.inc(len(pks))
            finally:
                self.q.task_done()


class QueryWorker(threading.Thread):
    """Open-loop query client: on every admitted slot it runs either a
    snapshot verification scan (the consistency oracle) or an executor
    query over a pinned snapshot, and observes the latency histogram."""

    def __init__(self, harness: "ServeHarness", idx: int,
                 stop: threading.Event):
        super().__init__(daemon=True, name=f"query-{idx}")
        self.h = harness
        self.idx = idx
        self.stop_ev = stop
        self.queries = 0
        self.torn = 0
        self.lost = 0
        self.errors: List[str] = []

    def run(self) -> None:
        lat = _obs.histogram("serve.query.latency_s")
        torn_c = _obs.counter("serve.query.torn_reads")
        lost_c = _obs.counter("serve.query.lost_acks")
        i = 0
        while not self.stop_ev.is_set():
            with self.h.admission.admit() as ok:
                if not ok:
                    continue
                t0 = time.perf_counter()
                try:
                    if i % 2 == 0:
                        torn, lost = self.h.verify_snapshot()
                        if torn:
                            self.torn += 1
                            torn_c.inc()
                        if lost:
                            self.lost += 1
                            lost_c.inc()
                    else:
                        self.h.executor_query(self.idx + i)
                except Exception as e:            # noqa: BLE001
                    self.errors.append(f"{type(e).__name__}: {e}")
                lat.observe(time.perf_counter() - t0)
                self.queries += 1
                i += 1


# ---------------------------------------------------------------------------
# Report + harness
# ---------------------------------------------------------------------------

@dataclass
class ServeReport:
    """Outcome of one mixed-workload run (see ``as_dict`` for the JSON
    schema serve_bench emits)."""
    duration_s: float
    ingest_acked: int
    ingest_rate: float            # acked records / wall second
    queries: int
    admission_rejected: int
    query_p50_ms: Optional[float]
    query_p99_ms: Optional[float]
    torn_reads: int
    lost_acks: int                # live-scan floor violations
    lost_acked_final: int         # acked pks missing from the final scan
    recoveries: int
    query_errors: List[str] = field(default_factory=list)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "duration_s": self.duration_s,
            "ingest_acked": self.ingest_acked,
            "ingest_rate": self.ingest_rate,
            "queries": self.queries,
            "admission_rejected": self.admission_rejected,
            "query_p50_ms": self.query_p50_ms,
            "query_p99_ms": self.query_p99_ms,
            "torn_reads": self.torn_reads,
            "lost_acks": self.lost_acks,
            "lost_acked_final": self.lost_acked_final,
            "recoveries": self.recoveries,
            "query_errors": self.query_errors[:8],
        }


class ServeHarness:
    """Concurrent serving loop over one ``PartitionedDataset``: N ingest
    lanes + M query workers under admission control.  ``run(duration_s)``
    is the one-call driver; ``start()``/``stop()`` plus ``checkpoint()``
    and ``crash_and_recover()`` compose for fault-injection tests."""

    def __init__(self, dataset: Any, *, n_ingest: int = 2, n_query: int = 2,
                 pump_batch: int = 64, queue_depth: int = 8,
                 max_inflight: int = 8,
                 make_record: Optional[Callable[[int], Dict[str, Any]]] = None,
                 records_per_lane: Optional[int] = None,
                 joint_window: int = 4096):
        self.dataset = dataset
        self.n_ingest = int(n_ingest)
        self.n_query = int(n_query)
        self.pump_batch = int(pump_batch)
        self.queue_depth = int(queue_depth)
        self.joint_window = int(joint_window)
        self.admission = AdmissionController(max_inflight)
        self.acked: List[set] = [set() for _ in range(self.n_ingest)]
        self._ack_lock = threading.Lock()
        self.recoveries = 0
        self.feeds: List[Feed] = []
        self.queues: List["queue.Queue[List[Any]]"] = []
        for lane in range(self.n_ingest):
            q: "queue.Queue[List[Any]]" = queue.Queue(maxsize=queue_depth)
            adaptor = StridedRecordAdaptor(lane, self.n_ingest,
                                           make_record=make_record,
                                           limit=records_per_lane)
            feed = Feed(name=f"{dataset.name}-ingest{lane}",
                        adaptor=adaptor, store=BoundedSink(q),
                        joint=FeedJoint(window=self.joint_window,
                                        name=f"{dataset.name}-ingest{lane}"))
            self.queues.append(q)
            self.feeds.append(feed)
        self._ckpt: Optional[List[Dict[str, Any]]] = None
        self._gate = threading.Event()
        self._stop = threading.Event()
        self._pumps: List[IngestPump] = []
        self._sinks: List[SinkWorker] = []
        self._workers: List[QueryWorker] = []
        self._done_workers: List[QueryWorker] = []
        self._t0: Optional[float] = None
        self._elapsed = 0.0

    # -- query surface ------------------------------------------------------
    def verify_snapshot(self) -> "tuple[bool, bool]":
        """Pin a snapshot and check the lane-prefix consistency oracle.
        Returns (torn, lost): ``torn`` — some lane's key set is not a
        prefix of its insertion order; ``lost`` — some lane holds fewer
        keys than were acknowledged before the pin."""
        lanes = self.n_ingest
        with self._ack_lock:
            floors = [len(a) for a in self.acked]
        snap = self.dataset.pin()
        try:
            parts = [snap.partition_pk_array(i)
                     for i in range(self.dataset.num_partitions)]
        finally:
            snap.release()
        parts = [p for p in parts if p.size]
        pks = (np.concatenate(parts) if parts
               else np.empty(0, dtype=np.int64)).astype(np.int64)
        torn = lost = False
        for lane in range(lanes):
            lane_pks = pks[pks % lanes == lane]
            k = int(lane_pks.size)
            if k and (int(lane_pks.max()) // lanes != k - 1
                      or np.unique(lane_pks).size != k):
                torn = True
            if k < floors[lane]:
                lost = True
        return torn, lost

    def executor_query(self, salt: int) -> int:
        """One executor query through the optimizer + row/columnar engine
        over a pinned snapshot (``run_query(snapshot=True)``)."""
        pk = self.dataset.pk
        r = salt % 7
        plan = A.select(A.scan(self.dataset.name),
                        pred=lambda row: row[pk] % 7 == r,
                        fields=[pk])
        rows, _ = run_query(plan, {self.dataset.name: self.dataset},
                            snapshot=True)
        return len(rows)

    # -- lifecycle ----------------------------------------------------------
    def _spawn(self) -> None:
        self._stop = threading.Event()
        self._pumps = [IngestPump(f, self.pump_batch, self._gate, self._stop)
                       for f in self.feeds]
        self._sinks = [SinkWorker(self, lane, q, self._stop)
                       for lane, q in enumerate(self.queues)]
        self._workers = [QueryWorker(self, j, self._stop)
                         for j in range(self.n_query)]
        for t in self._pumps + self._sinks + self._workers:
            t.start()

    def start(self) -> None:
        if self._ckpt is None:
            self._ckpt = [f.state() for f in self.feeds]   # initial cursors
        self._gate.set()
        self._t0 = time.perf_counter()
        self._spawn()

    def stop(self) -> None:
        """Quiesce and join every thread (queues drain first, so all
        pumped records are delivered and acked)."""
        self._quiesce()
        self._stop.set()
        for t in self._pumps + self._sinks + self._workers:
            t.join(timeout=10.0)
        self._done_workers.extend(self._workers)
        self._workers = []
        if self._t0 is not None:
            self._elapsed += time.perf_counter() - self._t0
            self._t0 = None

    def _quiesce(self) -> None:
        self._gate.clear()
        for p in self._pumps:
            p.parked.wait(timeout=10.0)
        for q in self.queues:
            q.join()                       # every delivered chunk acked

    def exhausted(self) -> bool:
        return all(p.exhausted.is_set() for p in self._pumps)

    def checkpoint(self) -> List[Dict[str, Any]]:
        """Park the pumps, drain the queues, capture every feed cursor,
        resume.  The captured state is durable: everything at or before
        each cursor has been acked to storage."""
        self._quiesce()
        self._ckpt = [f.state() for f in self.feeds]
        self._gate.set()
        return self._ckpt

    def crash_and_recover(self) -> None:
        """Kill the pipeline mid-flight, rebuild the dataset from (valid
        components + WAL), restore feeds from the last checkpoint and
        resume pumping — at-least-once replay; PK upserts dedupe."""
        self._stop.set()
        self._gate.set()                   # unblock parked pumps to exit
        for t in self._pumps + self._sinks + self._workers:
            t.join(timeout=10.0)
        self._done_workers.extend(self._workers)
        for q in self.queues:              # drop in-flight chunks: the
            while True:                    # replay below re-delivers them
                try:
                    q.get_nowait()
                    q.task_done()
                except queue.Empty:
                    break
        self.dataset.crash_and_recover()
        self.recoveries += 1
        _obs.counter("serve.recoveries").inc()
        if self._ckpt is not None:
            for f, st in zip(self.feeds, self._ckpt):
                f.restore(st)
        self._gate.set()
        self._spawn()

    # -- driver -------------------------------------------------------------
    def run(self, duration_s: float = 2.0,
            checkpoint_after: Optional[int] = None,
            crash_after: Optional[int] = None) -> ServeReport:
        """Drive the mixed workload for ``duration_s`` (or until every
        lane's adaptor is exhausted).  ``checkpoint_after`` /
        ``crash_after`` are total-acked-record thresholds: once acks
        pass ``checkpoint_after`` a checkpoint is taken, and once they
        pass ``crash_after`` the pipeline is crashed and recovered —
        everything acked between the two replays at-least-once."""
        self.start()
        deadline = time.perf_counter() + duration_s
        did_ckpt = checkpoint_after is None
        did_crash = crash_after is None
        while time.perf_counter() < deadline:
            with self._ack_lock:
                total = sum(len(a) for a in self.acked)
            if not did_ckpt and total >= checkpoint_after:
                self.checkpoint()
                did_ckpt = True
            if did_ckpt and not did_crash and total >= crash_after:
                self.crash_and_recover()
                did_crash = True
            if self.exhausted() and did_ckpt and did_crash:
                break
            time.sleep(0.005)
        self.stop()
        return self.report()

    def report(self) -> ServeReport:
        lat = _obs.histogram("serve.query.latency_s")
        with self._ack_lock:
            acked_sets = [set(a) for a in self.acked]   # defensive copies
        n_acked = sum(len(s) for s in acked_sets)
        final = set()
        for i in range(self.dataset.num_partitions):
            final.update(int(x) for x in
                         self.dataset.partition_pk_array(i).tolist())
        lost_final = sum(len(s - final) for s in acked_sets)
        workers = self._done_workers + self._workers
        elapsed = self._elapsed if self._elapsed > 0 else 1e-9
        p50 = lat.percentile(50)
        p99 = lat.percentile(99)
        return ServeReport(
            duration_s=elapsed,
            ingest_acked=n_acked,
            ingest_rate=n_acked / elapsed,
            queries=sum(w.queries for w in workers),
            admission_rejected=self.admission.rejected,
            query_p50_ms=None if p50 is None else p50 * 1e3,
            query_p99_ms=None if p99 is None else p99 * 1e3,
            torn_reads=sum(w.torn for w in workers),
            lost_acks=sum(w.lost for w in workers),
            lost_acked_final=lost_final,
            recoveries=self.recoveries,
            query_errors=[e for w in workers for e in w.errors],
        )
