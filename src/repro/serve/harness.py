"""Admission-controlled concurrent serving loop: feeds in, queries out.

One :class:`ServeHarness` owns a :class:`PartitionedDataset` plus

* N ingest lanes — ``Feed`` pump threads whose store stage is a
  :class:`BoundedSink` (a bounded ``queue.Queue``: *block*, never drop)
  drained by one :class:`SinkWorker` per lane delivering micro-batches
  through ``insert_batch`` and acknowledging primary keys only after
  the insert returns;
* M :class:`QueryWorker` threads behind an :class:`AdmissionController`
  semaphore, alternating snapshot-isolated verification scans
  (``dataset.pin()``) with executor queries
  (``run_query(..., snapshot=True)``).

**The consistency invariant.**  Lane ``l`` of ``L`` inserts primary keys
``l, l+L, l+2L, ...`` in order, so any snapshot must contain, per lane,
exactly a *prefix* of that lane's key sequence — and at least every key
acknowledged before the snapshot was pinned.  A gap in a lane is a torn
read; a count below the pre-pin ack floor is a lost acknowledged write.
Both are counted (``serve.query.torn_reads`` / ``serve.query.lost_acks``)
on every verification scan, making the stress benchmark an oracle, not a
smoke test.

**Fault tolerance.**  ``checkpoint()`` parks the pumps, drains the
queues, and captures every feed's cursor state; ``crash_and_recover()``
rebuilds the dataset from (valid components + WAL), restores the feeds
from the last checkpoint, and resumes — records between checkpoint and
crash are replayed at-least-once and deduplicated by PK upsert.

**Request tracing + SLOs.**  Every query-worker submission is a
*request*: :class:`RequestTracker` assigns a monotone trace id, the
admission queue wait / snapshot-pin / execute / result phases are timed
individually (``serve.queue_wait_s`` + ``serve.phase.*_s`` histograms),
and a per-request deadline turns the admission controller
deadline-aware — a request whose queue wait alone would blow its
deadline is rejected up front (``serve.slo.rejected_deadline``) instead
of burning an execution slot it can no longer use.  Completed requests
settle into ``serve.slo.attained`` / ``serve.slo.missed`` on total
latency (queue wait included).  A 1-in-N profile sampler retains the
full span tree of sampled requests in a bounded ring — those spans
carry the kernel dispatch / transfer-byte attribution from
``obs.record_dispatch`` even while global tracing is off, and feed both
the ``/trace`` exporter endpoint and :meth:`ServeReport` tail-latency
attribution (which phase dominates p99).
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import deque
from contextlib import contextmanager, nullcontext
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Iterator, List, Optional, \
    Sequence

import numpy as np

from .. import obs as _obs
from ..obs import tracer as _tracer
from ..obs.metrics import Histogram as _LocalHistogram
from ..core import algebra as A
from ..data.feeds import Adaptor, Feed, FeedJoint
from ..storage.query import run_query

__all__ = ["Admission", "AdmissionController", "BoundedSink", "IngestPump",
           "QueryWorker", "RequestRecord", "RequestTracker", "ServeHarness",
           "ServeReport", "SinkWorker", "StridedRecordAdaptor"]

PHASES = ("queue_wait", "pin", "execute", "result")


def _null_phase(name: str):
    """Phase hook for untracked calls (direct test use of the query
    surface): no timing, no spans."""
    return nullcontext()


# ---------------------------------------------------------------------------
# Workload pieces
# ---------------------------------------------------------------------------

def _default_record(pk: int) -> Dict[str, Any]:
    return {"pk": int(pk),
            "val": int((pk * 2654435761) % 100003),
            "text": f"rec-{pk % 97}"}


class StridedRecordAdaptor(Adaptor):
    """Deterministic record source for ingest lane ``lane`` of ``lanes``:
    the i-th record carries primary key ``i*lanes + lane``, so concurrent
    lanes never collide and each lane's key sequence is monotone — the
    property the snapshot-consistency oracle checks.  Seekable, so a feed
    ``restore()`` replays exactly."""

    def __init__(self, lane: int, lanes: int,
                 make_record: Optional[Callable[[int], Dict[str, Any]]] = None,
                 limit: Optional[int] = None):
        self.lane = int(lane)
        self.lanes = int(lanes)
        self.make_record = make_record or _default_record
        self.limit = limit
        self.cursor = 0

    def next_batch(self, n: int) -> List[Any]:
        if self.limit is not None:
            n = max(0, min(n, self.limit - self.cursor))
        out = [self.make_record((self.cursor + j) * self.lanes + self.lane)
               for j in range(n)]
        self.cursor += len(out)
        return out

    def seek(self, cursor: int) -> None:
        self.cursor = cursor


class BoundedSink:
    """Feed store stage pushing micro-batches onto a bounded queue.  A
    full queue *blocks* the pump (backpressure) instead of dropping —
    the fix for silent feed-side loss under a slow storage stage."""

    def __init__(self, q: "queue.Queue[List[Any]]"):
        self.q = q

    def __call__(self, records: Sequence[Any]) -> None:
        if records:
            self.q.put(list(records), block=True)


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

class Admission:
    """Outcome of one ``admit()`` attempt: truthy iff a slot was
    granted.  ``queue_wait_s`` is how long the request waited for its
    answer — the time-to-rejection for shed requests — and
    ``rejected_deadline`` marks a rejection caused by the per-request
    deadline rather than slot exhaustion."""

    __slots__ = ("ok", "queue_wait_s", "rejected_deadline")

    def __init__(self, ok: bool, queue_wait_s: float,
                 rejected_deadline: bool = False):
        self.ok = ok
        self.queue_wait_s = queue_wait_s
        self.rejected_deadline = rejected_deadline

    def __bool__(self) -> bool:
        return self.ok


_USE_DEFAULT = object()


class AdmissionController:
    """Caps in-flight queries with a semaphore.  ``admit()`` either
    grants a slot or rejects — open-loop clients keep offering load; the
    controller sheds it instead of queueing unboundedly.  Two rejection
    causes, counted separately:

    * *slots* — no slot freed within ``timeout`` seconds
      (``serve.admission.rejected``);
    * *deadline* — the request carries a deadline and its elapsed queue
      wait alone would blow it, so the slot wait is capped at the
      deadline and a too-late grant is returned unused
      (``serve.slo.rejected_deadline``, also counted in the rejected
      total).

    Every attempt's queue wait — including time-to-rejection — lands in
    the ``serve.queue_wait_s`` histogram, so shed load is visible in the
    same distribution as admitted load."""

    def __init__(self, max_inflight: int = 8, timeout: float = 0.2,
                 deadline_s: Optional[float] = None):
        self.max_inflight = int(max_inflight)
        self.timeout = float(timeout)
        self.deadline_s = deadline_s
        self._sem = threading.Semaphore(self.max_inflight)
        self._lock = threading.Lock()
        self.admitted = 0
        self.rejected = 0                  # all rejections
        self.rejected_deadline = 0         # the deadline-caused subset
        self._inflight = _obs.gauge("serve.admission.inflight")
        self._rejected_c = _obs.counter("serve.admission.rejected")
        self._rejected_deadline_c = _obs.counter("serve.slo.rejected_deadline")
        self._queue_wait = _obs.histogram("serve.queue_wait_s")

    @contextmanager
    def admit(self, deadline_s: Any = _USE_DEFAULT) -> Iterator[Admission]:
        dl = self.deadline_s if deadline_s is _USE_DEFAULT else deadline_s
        budget = self.timeout if dl is None else min(self.timeout, dl)
        t0 = time.perf_counter()
        ok = self._sem.acquire(timeout=budget)
        wait = time.perf_counter() - t0
        by_deadline = False
        if ok and dl is not None and wait >= dl:
            # the slot arrived, but too late: queue wait alone blew the
            # deadline — hand the slot back instead of executing a
            # request the client has already given up on
            self._sem.release()
            ok = False
            by_deadline = True
        elif not ok and dl is not None and wait >= dl:
            by_deadline = True
        self._queue_wait.observe(wait)
        if not ok:
            with self._lock:
                self.rejected += 1
                if by_deadline:
                    self.rejected_deadline += 1
            self._rejected_c.inc()
            if by_deadline:
                self._rejected_deadline_c.inc()
            yield Admission(False, wait, by_deadline)
            return
        with self._lock:
            self.admitted += 1
            self._inflight.set(self.max_inflight - self._sem._value)
        try:
            yield Admission(True, wait)
        finally:
            self._sem.release()


# ---------------------------------------------------------------------------
# Per-query request tracing + SLO accounting
# ---------------------------------------------------------------------------

@dataclass
class RequestRecord:
    """One query-worker submission: a monotone trace id, per-phase wall
    times, and — when sampled by the 1-in-N profiler — the real span
    tree, which carries kernel dispatch/transfer attribution and rides
    into the ``/trace`` exporter endpoint."""

    trace_id: int
    kind: str                            # "verify" | "query"
    profiled: bool = False
    t0: float = 0.0
    queue_wait_s: float = 0.0
    phases: Dict[str, float] = field(default_factory=dict)
    outcome: str = "ok"        # ok | error | rejected | rejected_deadline
    total_s: float = 0.0
    attained: Optional[bool] = None      # None: no deadline / rejected
    kernel: Dict[str, int] = field(default_factory=dict)
    spans: List[_tracer.Span] = field(default_factory=list)
    _root: Optional[_tracer.Span] = None

    @contextmanager
    def phase(self, name: str) -> Iterator[None]:
        """Time one request phase; profiled requests additionally open a
        real tracer span (regardless of the global tracing flag) so
        ``obs.record_dispatch`` attributes kernel traffic to it."""
        sp: Optional[_tracer.Span] = None
        if self.profiled:
            sp = _tracer.Span(f"serve.phase.{name}",
                              {"trace_id": self.trace_id, "kind": self.kind})
            sp.__enter__()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.phases[name] = self.phases.get(name, 0.0) + dt
            if sp is not None:
                sp.__exit__(None, None, None)
                self.spans.append(sp)
                for k in ("kernel_dispatches", "h2d_bytes", "d2h_bytes"):
                    if k in sp.attrs:
                        self.kernel[k] = self.kernel.get(k, 0) + sp.attrs[k]


class RequestTracker:
    """Assigns trace ids, settles SLO accounting, and keeps the bounded
    profile ring.

    Counters/histograms go to the global registry (the exporter's view)
    *and* to tracker-local tallies/histograms, so one harness's
    :class:`ServeReport` is never polluted by another harness sharing
    the process (tests run many)."""

    def __init__(self, deadline_s: Optional[float] = None,
                 profile_every: int = 16, profile_ring: int = 64):
        self.deadline_s = deadline_s
        self.profile_every = max(0, int(profile_every))
        self.profiles: Deque[RequestRecord] = deque(maxlen=int(profile_ring))
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self.attained = 0
        self.missed = 0
        self.rejected_slots = 0
        self.rejected_deadline = 0
        self.completed = 0
        # local distributions for per-harness reporting
        self.queue_wait = _LocalHistogram("local.queue_wait_s")
        self.phase_hist: Dict[str, _LocalHistogram] = {
            p: _LocalHistogram(f"local.phase.{p}_s")
            for p in PHASES if p != "queue_wait"}
        # global registry handles (shared with every harness + exporter)
        self._g_attained = _obs.counter("serve.slo.attained")
        self._g_missed = _obs.counter("serve.slo.missed")
        self._g_profiled = _obs.counter("serve.request.profiled")
        self._g_phase = {p: _obs.histogram(f"serve.phase.{p}_s")
                         for p in PHASES if p != "queue_wait"}

    def begin(self, kind: str) -> RequestRecord:
        tid = next(self._ids)
        profiled = self.profile_every > 0 and tid % self.profile_every == 0
        rec = RequestRecord(trace_id=tid, kind=kind, profiled=profiled,
                            t0=time.perf_counter())
        if profiled:
            self._g_profiled.inc()
            rec._root = _tracer.Span("serve.request",
                                     {"trace_id": tid, "kind": kind})
            rec._root.__enter__()
        return rec

    def settle(self, rec: RequestRecord, grant: Optional[Admission] = None
               ) -> None:
        """Close out one request: fold the admission result in, observe
        the phase histograms, settle the SLO verdict, retain the profile."""
        rec.total_s = time.perf_counter() - rec.t0
        if grant is not None:
            rec.queue_wait_s = grant.queue_wait_s
            if not grant:
                rec.outcome = ("rejected_deadline" if grant.rejected_deadline
                               else "rejected")
        self.queue_wait.observe(rec.queue_wait_s)
        for p, dt in rec.phases.items():
            self.phase_hist[p].observe(dt)
            self._g_phase[p].observe(dt)
        rejected = rec.outcome in ("rejected", "rejected_deadline")
        with self._lock:
            if rec.outcome == "rejected":
                self.rejected_slots += 1
            elif rec.outcome == "rejected_deadline":
                self.rejected_deadline += 1
            else:
                self.completed += 1
        if not rejected and self.deadline_s is not None:
            rec.attained = rec.total_s <= self.deadline_s
            with self._lock:
                if rec.attained:
                    self.attained += 1
                else:
                    self.missed += 1
            (self._g_attained if rec.attained else self._g_missed).inc()
        if rec._root is not None:
            root = rec._root
            root.set("outcome", rec.outcome)
            root.set("queue_wait_s", rec.queue_wait_s)
            for p, dt in rec.phases.items():
                root.set(f"{p}_s", dt)
            root.__exit__(None, None, None)
            rec.spans.append(root)
            rec._root = None
            self.profiles.append(rec)

    def offered(self) -> int:
        with self._lock:
            return (self.completed + self.rejected_slots
                    + self.rejected_deadline)

    def profile_spans(self) -> List[_tracer.Span]:
        """Finished spans of every retained profiled request (the serve
        contribution to the exporter's ``/trace`` endpoint)."""
        return [sp for rec in list(self.profiles) for sp in rec.spans]


# ---------------------------------------------------------------------------
# Worker threads
# ---------------------------------------------------------------------------

class IngestPump(threading.Thread):
    """Runs one feed's intake→compute→store cycle until stopped or the
    adaptor is exhausted.  Parks (without consuming) while the harness
    gate is closed, so ``checkpoint()`` can quiesce the pipeline."""

    def __init__(self, feed: Feed, batch: int, gate: threading.Event,
                 stop: threading.Event):
        super().__init__(daemon=True, name=f"pump-{feed.name}")
        self.feed = feed
        self.batch = int(batch)
        self.gate = gate
        self.stop_ev = stop
        self.parked = threading.Event()
        self.exhausted = threading.Event()

    def run(self) -> None:
        while not self.stop_ev.is_set():
            if not self.gate.is_set():
                self.parked.set()
                self.gate.wait(0.02)
                continue
            self.parked.clear()
            self.feed.pump(self.batch)
            if self.feed.last_intake == 0:       # end of stream
                self.exhausted.set()
                self.parked.set()
                self.stop_ev.wait(0.02)
        self.parked.set()


class SinkWorker(threading.Thread):
    """Drains one ingest lane's bounded queue into the dataset and
    acknowledges primary keys *after* ``insert_batch`` returns — the ack
    list is the ground truth the consistency oracle checks against."""

    def __init__(self, harness: "ServeHarness", lane: int,
                 q: "queue.Queue[List[Any]]", stop: threading.Event):
        super().__init__(daemon=True, name=f"sink-{lane}")
        self.h = harness
        self.lane = lane
        self.q = q
        self.stop_ev = stop

    def run(self) -> None:
        ds, pk = self.h.dataset, self.h.dataset.pk
        acked_c = _obs.counter("serve.ingest.acked")
        while True:
            try:
                chunk = self.q.get(timeout=0.02)
            except queue.Empty:
                if self.stop_ev.is_set():
                    return
                continue
            try:
                ds.insert_batch(chunk)
                pks = [r[pk] for r in chunk]
                with self.h._ack_lock:
                    # a set, not a list: at-least-once replay after a
                    # crash re-delivers (and re-acks) records, and the
                    # consistency floor must count *distinct* acks
                    self.h.acked[self.lane].update(pks)
                acked_c.inc(len(pks))
            finally:
                self.q.task_done()


class QueryWorker(threading.Thread):
    """Open-loop query client: every submission is a tracked request —
    trace id, queue-wait/pin/execute/result phases, SLO settlement — and
    on an admitted slot runs either a snapshot verification scan (the
    consistency oracle) or an executor query over a pinned snapshot,
    observing the latency histogram."""

    def __init__(self, harness: "ServeHarness", idx: int,
                 stop: threading.Event):
        super().__init__(daemon=True, name=f"query-{idx}")
        self.h = harness
        self.idx = idx
        self.stop_ev = stop
        self.queries = 0
        self.torn = 0
        self.lost = 0
        self.errors: List[str] = []

    def run(self) -> None:
        lat = _obs.histogram("serve.query.latency_s")
        torn_c = _obs.counter("serve.query.torn_reads")
        lost_c = _obs.counter("serve.query.lost_acks")
        tracker = self.h.tracker
        i = 0
        while not self.stop_ev.is_set():
            kind = "verify" if i % 2 == 0 else "query"
            req = tracker.begin(kind)
            with self.h.admission.admit() as grant:
                if not grant:
                    tracker.settle(req, grant)
                    continue
                req.queue_wait_s = grant.queue_wait_s
                t0 = time.perf_counter()
                try:
                    if kind == "verify":
                        torn, lost = self.h.verify_snapshot(req)
                        if torn:
                            self.torn += 1
                            torn_c.inc()
                        if lost:
                            self.lost += 1
                            lost_c.inc()
                    else:
                        self.h.executor_query(self.idx + i, req)
                except Exception as e:            # noqa: BLE001
                    req.outcome = "error"
                    self.errors.append(f"{type(e).__name__}: {e}")
                lat.observe(time.perf_counter() - t0)
                self.queries += 1
                i += 1
            tracker.settle(req)


# ---------------------------------------------------------------------------
# Report + harness
# ---------------------------------------------------------------------------

@dataclass
class ServeReport:
    """Outcome of one mixed-workload run (see ``as_dict`` for the JSON
    schema serve_bench emits).  Beyond throughput/consistency, carries
    the SLO ledger (attained / missed / rejected-by-deadline on the
    per-request deadline), the admission queue-wait distribution —
    rejections included — and the per-phase p99 attribution table that
    names which phase dominates tail latency."""
    duration_s: float
    ingest_acked: int
    ingest_rate: float            # acked records / wall second
    queries: int
    admission_rejected: int
    query_p50_ms: Optional[float]
    query_p99_ms: Optional[float]
    torn_reads: int
    lost_acks: int                # live-scan floor violations
    lost_acked_final: int         # acked pks missing from the final scan
    recoveries: int
    query_errors: List[str] = field(default_factory=list)
    # --- request tracing / SLO accounting (PR 9) ---
    deadline_ms: Optional[float] = None
    slo_attained: int = 0
    slo_missed: int = 0
    slo_rejected_deadline: int = 0
    rejection_rate: float = 0.0          # all rejections / offered requests
    deadline_miss_rate: float = 0.0      # (missed + rejected_deadline)/offered
    queue_wait_p50_ms: Optional[float] = None
    queue_wait_p99_ms: Optional[float] = None
    phase_p99_ms: Dict[str, Optional[float]] = field(default_factory=dict)
    slowest_phase_p99: Optional[str] = None
    profiled_requests: int = 0

    @property
    def slo_attainment(self) -> Optional[float]:
        """attained / (attained + missed + rejected_deadline); None when
        no deadline was configured."""
        denom = self.slo_attained + self.slo_missed + \
            self.slo_rejected_deadline
        if self.deadline_ms is None or denom == 0:
            return None
        return self.slo_attained / denom

    def as_dict(self) -> Dict[str, Any]:
        return {
            "duration_s": self.duration_s,
            "ingest_acked": self.ingest_acked,
            "ingest_rate": self.ingest_rate,
            "queries": self.queries,
            "admission_rejected": self.admission_rejected,
            "query_p50_ms": self.query_p50_ms,
            "query_p99_ms": self.query_p99_ms,
            "torn_reads": self.torn_reads,
            "lost_acks": self.lost_acks,
            "lost_acked_final": self.lost_acked_final,
            "recoveries": self.recoveries,
            "query_errors": self.query_errors[:8],
            "slo": {
                "deadline_ms": self.deadline_ms,
                "attained": self.slo_attained,
                "missed": self.slo_missed,
                "rejected_deadline": self.slo_rejected_deadline,
                "attainment": self.slo_attainment,
            },
            "rejection_rate": self.rejection_rate,
            "deadline_miss_rate": self.deadline_miss_rate,
            "queue_wait_p50_ms": self.queue_wait_p50_ms,
            "queue_wait_p99_ms": self.queue_wait_p99_ms,
            "phase_p99_ms": dict(self.phase_p99_ms),
            "slowest_phase_p99": self.slowest_phase_p99,
            "profiled_requests": self.profiled_requests,
        }


class ServeHarness:
    """Concurrent serving loop over one ``PartitionedDataset``: N ingest
    lanes + M query workers under admission control.  ``run(duration_s)``
    is the one-call driver; ``start()``/``stop()`` plus ``checkpoint()``
    and ``crash_and_recover()`` compose for fault-injection tests."""

    def __init__(self, dataset: Any, *, n_ingest: int = 2, n_query: int = 2,
                 pump_batch: int = 64, queue_depth: int = 8,
                 max_inflight: int = 8,
                 make_record: Optional[Callable[[int], Dict[str, Any]]] = None,
                 records_per_lane: Optional[int] = None,
                 joint_window: int = 4096,
                 deadline_s: Optional[float] = None,
                 admission_timeout: float = 0.2,
                 profile_every: int = 16, profile_ring: int = 64):
        self.dataset = dataset
        self.n_ingest = int(n_ingest)
        self.n_query = int(n_query)
        self.pump_batch = int(pump_batch)
        self.queue_depth = int(queue_depth)
        self.joint_window = int(joint_window)
        self.deadline_s = deadline_s
        self.admission = AdmissionController(max_inflight,
                                             timeout=admission_timeout,
                                             deadline_s=deadline_s)
        self.tracker = RequestTracker(deadline_s=deadline_s,
                                      profile_every=profile_every,
                                      profile_ring=profile_ring)
        self.acked: List[set] = [set() for _ in range(self.n_ingest)]
        self._ack_lock = threading.Lock()
        self.recoveries = 0
        self.feeds: List[Feed] = []
        self.queues: List["queue.Queue[List[Any]]"] = []
        for lane in range(self.n_ingest):
            q: "queue.Queue[List[Any]]" = queue.Queue(maxsize=queue_depth)
            adaptor = StridedRecordAdaptor(lane, self.n_ingest,
                                           make_record=make_record,
                                           limit=records_per_lane)
            feed = Feed(name=f"{dataset.name}-ingest{lane}",
                        adaptor=adaptor, store=BoundedSink(q),
                        joint=FeedJoint(window=self.joint_window,
                                        name=f"{dataset.name}-ingest{lane}"))
            self.queues.append(q)
            self.feeds.append(feed)
        self._ckpt: Optional[List[Dict[str, Any]]] = None
        self._gate = threading.Event()
        self._stop = threading.Event()
        self._pumps: List[IngestPump] = []
        self._sinks: List[SinkWorker] = []
        self._workers: List[QueryWorker] = []
        self._done_workers: List[QueryWorker] = []
        self._t0: Optional[float] = None
        self._elapsed = 0.0

    # -- query surface ------------------------------------------------------
    def verify_snapshot(self, req: Optional[RequestRecord] = None
                        ) -> "tuple[bool, bool]":
        """Pin a snapshot and check the lane-prefix consistency oracle.
        Returns (torn, lost): ``torn`` — some lane's key set is not a
        prefix of its insertion order; ``lost`` — some lane holds fewer
        keys than were acknowledged before the pin.  ``req`` (a tracked
        request) splits the work into pin / execute / result phases."""
        ph = req.phase if req is not None else _null_phase
        lanes = self.n_ingest
        with ph("pin"):
            with self._ack_lock:
                floors = [len(a) for a in self.acked]
            snap = self.dataset.pin()
        with ph("execute"):
            try:
                parts = [snap.partition_pk_array(i)
                         for i in range(self.dataset.num_partitions)]
            finally:
                snap.release()
        with ph("result"):
            parts = [p for p in parts if p.size]
            pks = (np.concatenate(parts) if parts
                   else np.empty(0, dtype=np.int64)).astype(np.int64)
            torn = lost = False
            for lane in range(lanes):
                lane_pks = pks[pks % lanes == lane]
                k = int(lane_pks.size)
                if k and (int(lane_pks.max()) // lanes != k - 1
                          or np.unique(lane_pks).size != k):
                    torn = True
                if k < floors[lane]:
                    lost = True
        return torn, lost

    def executor_query(self, salt: int,
                       req: Optional[RequestRecord] = None) -> int:
        """One executor query through the optimizer + row/columnar engine
        over a pinned snapshot.  With a tracked request the pin is taken
        explicitly so its cost lands in the pin phase, and the executor
        runs against the snapshot facade (``run_query`` skips re-pinning
        an already-pinned ``DatasetSnapshot``)."""
        ph = req.phase if req is not None else _null_phase
        pk = self.dataset.pk
        r = salt % 7
        plan = A.select(A.scan(self.dataset.name),
                        pred=lambda row: row[pk] % 7 == r,
                        fields=[pk])
        with ph("pin"):
            snap = self.dataset.pin()
        try:
            with ph("execute"):
                rows, _ = run_query(plan, {self.dataset.name: snap})
            with ph("result"):
                return len(rows)
        finally:
            snap.release()

    # -- lifecycle ----------------------------------------------------------
    def _spawn(self) -> None:
        self._stop = threading.Event()
        self._pumps = [IngestPump(f, self.pump_batch, self._gate, self._stop)
                       for f in self.feeds]
        self._sinks = [SinkWorker(self, lane, q, self._stop)
                       for lane, q in enumerate(self.queues)]
        self._workers = [QueryWorker(self, j, self._stop)
                         for j in range(self.n_query)]
        for t in self._pumps + self._sinks + self._workers:
            t.start()

    def start(self) -> None:
        if self._ckpt is None:
            self._ckpt = [f.state() for f in self.feeds]   # initial cursors
        self._gate.set()
        self._t0 = time.perf_counter()
        self._spawn()

    def stop(self) -> None:
        """Quiesce and join every thread (queues drain first, so all
        pumped records are delivered and acked)."""
        self._quiesce()
        self._stop.set()
        for t in self._pumps + self._sinks + self._workers:
            t.join(timeout=10.0)
        self._done_workers.extend(self._workers)
        self._workers = []
        if self._t0 is not None:
            self._elapsed += time.perf_counter() - self._t0
            self._t0 = None

    def _quiesce(self) -> None:
        self._gate.clear()
        for p in self._pumps:
            p.parked.wait(timeout=10.0)
        for q in self.queues:
            q.join()                       # every delivered chunk acked

    def exhausted(self) -> bool:
        return all(p.exhausted.is_set() for p in self._pumps)

    def checkpoint(self) -> List[Dict[str, Any]]:
        """Park the pumps, drain the queues, capture every feed cursor,
        resume.  The captured state is durable: everything at or before
        each cursor has been acked to storage."""
        self._quiesce()
        self._ckpt = [f.state() for f in self.feeds]
        self._gate.set()
        return self._ckpt

    def crash_and_recover(self) -> None:
        """Kill the pipeline mid-flight, rebuild the dataset from (valid
        components + WAL), restore feeds from the last checkpoint and
        resume pumping — at-least-once replay; PK upserts dedupe."""
        self._stop.set()
        self._gate.set()                   # unblock parked pumps to exit
        for t in self._pumps + self._sinks + self._workers:
            t.join(timeout=10.0)
        self._done_workers.extend(self._workers)
        for q in self.queues:              # drop in-flight chunks: the
            while True:                    # replay below re-delivers them
                try:
                    q.get_nowait()
                    q.task_done()
                except queue.Empty:
                    break
        self.dataset.crash_and_recover()
        self.recoveries += 1
        _obs.counter("serve.recoveries").inc()
        if self._ckpt is not None:
            for f, st in zip(self.feeds, self._ckpt):
                f.restore(st)
        self._gate.set()
        self._spawn()

    # -- driver -------------------------------------------------------------
    def run(self, duration_s: float = 2.0,
            checkpoint_after: Optional[int] = None,
            crash_after: Optional[int] = None) -> ServeReport:
        """Drive the mixed workload for ``duration_s`` (or until every
        lane's adaptor is exhausted).  ``checkpoint_after`` /
        ``crash_after`` are total-acked-record thresholds: once acks
        pass ``checkpoint_after`` a checkpoint is taken, and once they
        pass ``crash_after`` the pipeline is crashed and recovered —
        everything acked between the two replays at-least-once."""
        self.start()
        deadline = time.perf_counter() + duration_s
        did_ckpt = checkpoint_after is None
        did_crash = crash_after is None
        while time.perf_counter() < deadline:
            with self._ack_lock:
                total = sum(len(a) for a in self.acked)
            if not did_ckpt and total >= checkpoint_after:
                self.checkpoint()
                did_ckpt = True
            if did_ckpt and not did_crash and total >= crash_after:
                self.crash_and_recover()
                did_crash = True
            if self.exhausted() and did_ckpt and did_crash:
                break
            time.sleep(0.005)
        self.stop()
        return self.report()

    def report(self) -> ServeReport:
        lat = _obs.histogram("serve.query.latency_s")
        with self._ack_lock:
            acked_sets = [set(a) for a in self.acked]   # defensive copies
        n_acked = sum(len(s) for s in acked_sets)
        final = set()
        for i in range(self.dataset.num_partitions):
            final.update(int(x) for x in
                         self.dataset.partition_pk_array(i).tolist())
        lost_final = sum(len(s - final) for s in acked_sets)
        workers = self._done_workers + self._workers
        elapsed = self._elapsed if self._elapsed > 0 else 1e-9
        p50 = lat.percentile(50)
        p99 = lat.percentile(99)
        tr = self.tracker
        offered = tr.offered()
        rejected_all = tr.rejected_slots + tr.rejected_deadline
        missed_all = tr.missed + tr.rejected_deadline
        qw50 = tr.queue_wait.percentile(50)
        qw99 = tr.queue_wait.percentile(99)
        # tail-latency attribution: p99 of each phase across this
        # harness's requests — the table that names what dominates p99
        phase_p99: Dict[str, Optional[float]] = {}
        qw = qw99
        phase_p99["queue_wait"] = None if qw is None else qw * 1e3
        for p, h in tr.phase_hist.items():
            v = h.percentile(99)
            phase_p99[p] = None if v is None else v * 1e3
        known = {p: v for p, v in phase_p99.items() if v is not None}
        slowest = max(known, key=known.get) if known else None
        return ServeReport(
            duration_s=elapsed,
            ingest_acked=n_acked,
            ingest_rate=n_acked / elapsed,
            queries=sum(w.queries for w in workers),
            admission_rejected=self.admission.rejected,
            query_p50_ms=None if p50 is None else p50 * 1e3,
            query_p99_ms=None if p99 is None else p99 * 1e3,
            torn_reads=sum(w.torn for w in workers),
            lost_acks=sum(w.lost for w in workers),
            lost_acked_final=lost_final,
            recoveries=self.recoveries,
            query_errors=[e for w in workers for e in w.errors],
            deadline_ms=(None if self.deadline_s is None
                         else self.deadline_s * 1e3),
            slo_attained=tr.attained,
            slo_missed=tr.missed,
            slo_rejected_deadline=tr.rejected_deadline,
            rejection_rate=rejected_all / offered if offered else 0.0,
            deadline_miss_rate=missed_all / offered if offered else 0.0,
            queue_wait_p50_ms=None if qw50 is None else qw50 * 1e3,
            queue_wait_p99_ms=None if qw99 is None else qw99 * 1e3,
            phase_p99_ms=phase_p99,
            slowest_phase_p99=slowest,
            profiled_requests=len(tr.profiles),
        )
