"""Hash-partitioned datasets with LSM primary + node-local secondary indexes
(paper §2.2, §4.3-4.4).

Faithful structure:
  * a Dataset is hash-partitioned (sharded) on its primary key;
  * each partition's primary index is an LSM "B+-tree" (core/lsm.LSMIndex);
  * secondary indexes are NODE-LOCAL: partition i's secondary index only
    references rows stored in partition i, so secondary lookups fan out to
    all partitions and return primary keys, never rows;
  * records are ADM instances (open/closed types, core/adm) — the encoded
    size difference between Schema and KeyOnly types reproduces Table 2;
  * record-level "transactions": every insert/delete WAL-logs before apply;
    recovery = drop invalid components + replay WAL tail (paper §4.4);
  * ``scan_partition_batch`` serves the columnar engine (columnar/): each
    LSM component shreds into cached per-column arrays on first touch, so
    projected scans skip full-record decode (cf. the columnar-LSM paper in
    PAPERS.md); the dataset tracks observed open fields on insert so
    schemaless records still get columns.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core import adm
from ..core.functions import (cells_covering_circle, spatial_cell,
                              spatial_intersect_circle, word_tokens)
from ..core.lsm import LSMIndex, TOMBSTONE, TieredMergePolicy, WALRecord, \
    recover
from ..columnar.batch import Column, ColumnBatch, MISSING, build_column
from ..columnar.schema import ColumnSchema

__all__ = ["PartitionedDataset", "hash_partition"]


def hash_partition(key: Any, num_partitions: int) -> int:
    """Deterministic hash partitioning (the paper's shard function).  Uses a
    Fibonacci-style integer mix for ints and FNV-1a for strings so partition
    spread does not depend on Python's randomized hash."""
    if isinstance(key, (int, np.integer)):
        return int((int(key) * 11400714819323198485) % (2 ** 64)
                   >> 40) % num_partitions
    if isinstance(key, str):
        h = 14695981039346656037
        for b in key.encode():
            h = ((h ^ b) * 1099511628211) % (2 ** 64)
        return h % num_partitions
    return hash(key) % num_partitions


@dataclass
class _Partition:
    primary: LSMIndex
    secondaries: Dict[str, LSMIndex] = field(default_factory=dict)


class PartitionedDataset:
    """An AsterixDB Dataset: typed, partitioned, LSM-indexed."""

    def __init__(self, name: str, dtype: adm.RecordType, primary_key: str,
                 num_partitions: int = 4, flush_threshold: int = 256,
                 merge_policy: Optional[TieredMergePolicy] = None):
        self.name = name
        self.dtype = dtype
        self.primary_key = (primary_key,)
        self.pk = primary_key
        self.num_partitions = num_partitions
        self.flush_threshold = flush_threshold
        self.merge_policy = merge_policy or TieredMergePolicy()
        self.partitions: List[_Partition] = [
            _Partition(LSMIndex(flush_threshold, self.merge_policy))
            for _ in range(num_partitions)]
        self.index_fields: List[str] = []
        self.index_kinds: Dict[str, str] = {}   # btree | rtree | keyword
        self.spatial_cell_size = 0.05
        self.stats = {"inserts": 0, "deletes": 0, "bytes_encoded": 0}
        # columnar engine: open fields seen so far (name -> column kind)
        self._open_schema = ColumnSchema()
        self._declared = tuple(f.name for f in dtype.fields)
        # per-partition assembled-scan cache, invalidated by any mutation
        # (keyed on component ids + mutation counters)
        self._scan_cache: Dict[int, Dict[str, Any]] = {}

    # -- DDL ---------------------------------------------------------------
    def _sec_keys(self, fld: str, value: Any, pk: Any) -> List[Tuple]:
        """Secondary-index entries for one field value, per index kind
        (paper Data definition 2: btree | rtree | keyword)."""
        kind = self.index_kinds.get(fld, "btree")
        if kind == "btree":
            return [(value, pk)]
        if kind == "rtree":   # grid-bucketed spatial index
            return [(spatial_cell(value, self.spatial_cell_size), pk)]
        if kind == "keyword":  # inverted index: one entry per token
            return [((tok,), pk) for tok in set(word_tokens(value))]
        raise adm.ValidationError(kind)

    def create_index(self, fld: str, kind: str = "btree") -> None:
        """Node-local secondary index; backfills from existing rows."""
        if fld in self.index_fields:
            raise adm.ValidationError(f"index on {fld} already exists")
        self.index_fields.append(fld)
        self.index_kinds[fld] = kind
        for part in self.partitions:
            ix = LSMIndex(self.flush_threshold, self.merge_policy)
            for pk, row in part.primary.items():
                if fld in row:
                    for key in self._sec_keys(fld, row[fld], pk):
                        ix.insert(key, pk)
            part.secondaries[fld] = ix

    # -- DML (record-level transactions) ------------------------------------
    def insert(self, record: Dict[str, Any]) -> None:
        rec = self.dtype.validate(record)
        self.stats["bytes_encoded"] += len(self.dtype.encode(rec))
        self._open_schema.observe_row(rec, self._declared)
        key = rec[self.pk]
        part = self.partitions[hash_partition(key, self.num_partitions)]
        old = part.primary.lookup(key)
        part.primary.insert(key, rec)
        for fld, ix in part.secondaries.items():
            if old is not None and fld in old:
                for k2 in self._sec_keys(fld, old[fld], key):
                    ix.delete(k2)
            if fld in rec:
                for k2 in self._sec_keys(fld, rec[fld], key):
                    ix.insert(k2, key)
        self.stats["inserts"] += 1

    def insert_batch(self, records: Sequence[Dict[str, Any]]) -> None:
        """One-statement batch (paper Table 4: amortizes per-statement
        overhead — here, validation setup + WAL grouping)."""
        for r in records:
            self.insert(r)

    def delete(self, key: Any) -> bool:
        part = self.partitions[hash_partition(key, self.num_partitions)]
        old = part.primary.lookup(key)
        if old is None:
            return False
        part.primary.delete(key)
        for fld, ix in part.secondaries.items():
            if fld in old:
                for k2 in self._sec_keys(fld, old[fld], key):
                    ix.delete(k2)
        self.stats["deletes"] += 1
        return True

    # -- read paths ----------------------------------------------------------
    def lookup(self, key: Any) -> Optional[Dict[str, Any]]:
        """Primary-key point lookup: routed to ONE partition (paper: record
        lookup hits a single node)."""
        part = self.partitions[hash_partition(key, self.num_partitions)]
        return part.primary.lookup(key)

    def scan_partition(self, i: int) -> List[Dict[str, Any]]:
        return [row for _, row in self.partitions[i].primary.items()]

    def scan(self) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        for i in range(self.num_partitions):
            out.extend(self.scan_partition(i))
        return out

    # -- columnar read path --------------------------------------------------
    def columnar_schema(self) -> ColumnSchema:
        """Declared fields (from the RecordType) + open fields observed on
        insert — the schema the columnar engine shreds against."""
        return ColumnSchema.from_record_type(self.dtype) \
            .union(self._open_schema)

    def _component_columns(self, comp, names: Sequence[str],
                           schema: ColumnSchema) -> ColumnBatch:
        """Column-at-a-time shred of one immutable component.  Each column
        is built once and cached on the component (core/lsm Component
        ``col_cache``), so projected scans never decode unrequested
        fields and repeat scans reuse prior work."""
        cache = comp.col_cache
        cols: Dict[str, Column] = {}
        for name in names:
            kind = schema.kind(name)
            col = cache.get(name)
            if col is None or (col.kind != kind and col.kind != "obj"):
                raw = [MISSING if r is TOMBSTONE else r.get(name, MISSING)
                       for r in comp.rows]
                col = build_column(raw, kind)
                cache[name] = col
            cols[name] = col
        return ColumnBatch(cols, comp.size)

    @staticmethod
    def _tomb_array(comp) -> np.ndarray:
        tomb = comp.col_cache.get("__tomb")
        if tomb is None:
            tomb = np.fromiter((r is TOMBSTONE for r in comp.rows),
                               dtype=bool, count=comp.size)
            comp.col_cache["__tomb"] = tomb
        return tomb

    def _partition_version(self, i: int) -> Tuple:
        prim = self.partitions[i].primary
        return (tuple(c.comp_id for c in prim.components if c.valid),
                prim.stats["inserts"], prim.stats["deletes"])

    def _live_selection(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """Newest-wins live-row selection for partition ``i``: positions
        ``idx`` into the memtable+components concat (newest first) and the
        pk array ``keys`` aligned with them, both ordered by ascending pk.
        Cached per storage version; computed from keys + tombstone flags
        only — no record decode, no column shred."""
        ver = self._partition_version(i)
        cache = self._scan_cache.get(i)
        if cache is None or cache["ver"] != ver:
            cache = {"ver": ver, "batches": {}, "idx": None, "keys": None}
            self._scan_cache[i] = cache
        if cache["idx"] is not None:
            return cache["idx"], cache["keys"]
        prim = self.partitions[i].primary
        key_arrays: List[np.ndarray] = []
        tombs: List[np.ndarray] = []
        mem = prim.memtable            # newest version of any key it holds
        if mem:
            key_arrays.append(np.asarray(list(mem), dtype=object))
            tombs.append(np.fromiter((r is TOMBSTONE
                                      for r in mem.values()),
                                     dtype=bool, count=len(mem)))
        for comp in prim.components:   # newest first
            if not comp.valid or comp.size == 0:
                continue
            key_arrays.append(comp.keys)
            tombs.append(self._tomb_array(comp))
        if not key_arrays:
            idx = np.zeros(0, dtype=np.int64)
            keys: np.ndarray = np.zeros(0, dtype=np.int64)
        else:
            all_tomb = np.concatenate(tombs)
            flat_keys = [k for ka in key_arrays for k in ka.tolist()]
            all_keys: Optional[np.ndarray]
            try:
                all_keys = np.asarray(flat_keys)
                if all_keys.dtype == object:
                    raise TypeError("inhomogeneous keys")
                # first occurrence in newest-first concat order == newest
                _, idx = np.unique(all_keys, return_index=True)
            except TypeError:
                all_keys = None
                seen = set()
                first = []
                for pos, k2 in enumerate(flat_keys):
                    if k2 not in seen:
                        seen.add(k2)
                        first.append((k2, pos))
                first.sort(key=lambda t: t[0])
                idx = np.asarray([p for _, p in first], dtype=np.int64)
            idx = idx[~all_tomb[idx]]
            if all_keys is not None:
                keys = all_keys[idx]
            else:
                keys = np.empty(len(idx), dtype=object)
                for j, pos in enumerate(idx.tolist()):
                    keys[j] = flat_keys[pos]
        cache["idx"] = idx
        cache["keys"] = keys
        return idx, keys

    def partition_pk_array(self, i: int) -> np.ndarray:
        """Sorted live primary keys of partition ``i``, aligned row-for-row
        with ``scan_partition_batch(i, ...)``: element j is the pk of the
        scan batch's j-th record.  Sorted candidate-PK arrays from the
        secondary indexes intersect against this array to become position
        bitmaps over the cached ColumnBatches (columnar index access)."""
        return self._live_selection(i)[1]

    def scan_partition_batch(self, i: int,
                             columns: Optional[Sequence[str]] = None
                             ) -> ColumnBatch:
        """Columnar scan of one partition: per-component cached column
        projection + vectorized newest-wins dedup across components and
        the memtable.  Row order (sorted by pk) and contents match
        ``scan_partition`` exactly."""
        schema = self.columnar_schema()
        names = list(schema) if columns is None \
            else [c for c in columns if c in schema]
        idx, _ = self._live_selection(i)
        cache = self._scan_cache[i]
        ckey = tuple(names)
        if ckey in cache["batches"]:
            return cache["batches"][ckey]
        prim = self.partitions[i].primary
        batches: List[ColumnBatch] = []
        mem = prim.memtable
        if mem:
            batches.append(ColumnBatch.from_rows(
                [({} if r is TOMBSTONE else r) for r in mem.values()],
                schema, names))
        for comp in prim.components:   # newest first, as in _live_selection
            if not comp.valid or comp.size == 0:
                continue
            batches.append(self._component_columns(comp, names, schema))
        if not batches:
            out = ColumnBatch.from_rows([], schema, names)
        else:
            out = ColumnBatch.concat(batches).take(idx)
        cache["batches"][ckey] = out
        return out

    def secondary_search_partition(self, i: int, fld: str, lo: Any, hi: Any
                                   ) -> List[Any]:
        """Secondary range search on one partition -> primary keys (paper
        §4.3: 'the result of a secondary key lookup is a set of primary
        keys')."""
        ix = self.partitions[i].secondaries.get(fld)
        if ix is None:
            raise adm.ValidationError(f"no index on {self.name}.{fld}")
        lo_k = (_MIN if lo is None else lo, _MIN)   # None = unbounded side
        hi_k = (_MAX if hi is None else hi, _MAX)
        return [pk for _, pk in ix.range(lo_k, hi_k)]

    def spatial_search_partition(self, i: int, fld: str,
                                 center: Tuple[float, float],
                                 radius: float) -> List[Any]:
        """Grid ('rtree') candidates within the circle's covering cells —
        post-validation (paper Figure 6) filters exact distance later."""
        ix = self.partitions[i].secondaries.get(fld)
        if ix is None or self.index_kinds.get(fld) != "rtree":
            raise adm.ValidationError(f"no rtree index on {self.name}.{fld}")
        out = []
        for cell in cells_covering_circle(center, radius,
                                          self.spatial_cell_size):
            out.extend(pk for _, pk in ix.range((cell, _MIN), (cell, _MAX)))
        return out

    def keyword_search_partition(self, i: int, fld: str, token: str,
                                 fuzzy_ed: int = 0) -> List[Any]:
        """Inverted-index lookup; fuzzy_ed>0 scans the partition's token
        dictionary with edit-distance-check (the ngram(k) index would prune
        this scan; the dictionary here is partition-local and small)."""
        from ..core.functions import edit_distance_check
        ix = self.partitions[i].secondaries.get(fld)
        if ix is None or self.index_kinds.get(fld) != "keyword":
            raise adm.ValidationError(
                f"no keyword index on {self.name}.{fld}")
        token = token.lower()
        if fuzzy_ed == 0:
            return [pk for _, pk in ix.range(((token,), _MIN),
                                             ((token,), _MAX))]
        out = []
        seen_tok = None
        for (tok,), pk in ((k[0], r) for k, r in ix.items()):
            if tok != seen_tok:
                seen_tok = tok
                match = edit_distance_check(tok, token, fuzzy_ed)
            if match:
                out.append(pk)
        return out

    # -- candidate read paths (columnar index access) -------------------------
    @staticmethod
    def _pk_array(pks: Sequence[Any]) -> np.ndarray:
        """Sorted, deduplicated candidate-PK array.  Numeric when the keys
        are homogeneous (so the Pallas/jnp sorted-intersection kernel can
        run on them); object dtype otherwise (string/tuple pks intersect
        via the numpy merge fallback)."""
        pks = pks if isinstance(pks, list) else list(pks)
        if not pks:
            return np.zeros(0, dtype=np.int64)
        try:
            arr = np.asarray(pks)
            if arr.dtype == object or arr.dtype.kind not in "biuf":
                raise TypeError("non-numeric pks")
            return np.unique(arr)
        except (TypeError, ValueError):
            uniq = sorted(set(pks))
            out = np.empty(len(uniq), dtype=object)
            for j, v in enumerate(uniq):
                out[j] = v
            return out

    def secondary_candidate_pks(self, i: int, fld: str, lo: Any, hi: Any
                                ) -> np.ndarray:
        """Secondary B+-tree range search -> sorted PK candidate array for
        one partition.  Unlike ``secondary_search_partition`` this never
        materializes (key, pk) pairs in key order: the LSM read returns
        flat live values and the array sorts once, ready for position-
        bitmap intersection against ``partition_pk_array``."""
        ix = self.partitions[i].secondaries.get(fld)
        if ix is None:
            raise adm.ValidationError(f"no index on {self.name}.{fld}")
        lo_k = (_MIN if lo is None else lo, _MIN)
        hi_k = (_MAX if hi is None else hi, _MAX)
        return self._pk_array(ix.range_values(lo_k, hi_k))

    def spatial_candidate_pks(self, i: int, fld: str,
                              center: Tuple[float, float],
                              radius: float) -> np.ndarray:
        """Grid ('rtree') candidates -> sorted PK array (post-validation
        still required: covering cells over-approximate the circle)."""
        ix = self.partitions[i].secondaries.get(fld)
        if ix is None or self.index_kinds.get(fld) != "rtree":
            raise adm.ValidationError(f"no rtree index on {self.name}.{fld}")
        out: List[Any] = []
        for cell in cells_covering_circle(center, radius,
                                          self.spatial_cell_size):
            out.extend(ix.range_values((cell, _MIN), (cell, _MAX)))
        return self._pk_array(out)

    def keyword_candidate_pks(self, i: int, fld: str, token: str,
                              fuzzy_ed: int = 0) -> np.ndarray:
        """Inverted-index candidates -> sorted PK array.  The fuzzy path
        (ed > 0) reuses the dictionary edit-distance scan, then dedups."""
        ix = self.partitions[i].secondaries.get(fld)
        if ix is None or self.index_kinds.get(fld) != "keyword":
            raise adm.ValidationError(
                f"no keyword index on {self.name}.{fld}")
        if fuzzy_ed == 0:
            token = token.lower()
            return self._pk_array(ix.range_values(((token,), _MIN),
                                                  ((token,), _MAX)))
        return self._pk_array(
            self.keyword_search_partition(i, fld, token, fuzzy_ed))

    def primary_lookup_partition(self, i: int, pks: Sequence[Any]
                                 ) -> List[Dict[str, Any]]:
        """Sorted-PK batched primary lookups (Figure 6's SORT_PK step makes
        this access pattern sequential on a real B+-tree)."""
        prim = self.partitions[i].primary
        out = []
        for pk in sorted(pks):
            row = prim.lookup(pk)
            if row is not None:
                out.append(row)
        return out

    # -- recovery -------------------------------------------------------------
    def crash_and_recover(self) -> "PartitionedDataset":
        """Simulate a crash: rebuild every partition from (valid components +
        WAL), discarding unflushed memtables and invalid components."""
        for part in self.partitions:
            part.primary = recover(part.primary.components, part.primary.wal,
                                   flush_threshold=self.flush_threshold)
            for fld in list(part.secondaries):
                sec = part.secondaries[fld]
                part.secondaries[fld] = recover(
                    sec.components, sec.wal,
                    flush_threshold=self.flush_threshold)
        return self

    def __len__(self) -> int:
        return sum(len(p.primary) for p in self.partitions)


class _Extreme:
    def __init__(self, sign: int):
        self.sign = sign

    def __lt__(self, other):
        return self.sign < 0

    def __gt__(self, other):
        return self.sign > 0

    def __le__(self, other):
        return self.sign < 0

    def __ge__(self, other):
        return self.sign > 0

    def __eq__(self, other):
        return isinstance(other, _Extreme) and other.sign == self.sign


_MIN = _Extreme(-1)
_MAX = _Extreme(+1)
