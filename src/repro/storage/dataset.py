"""Hash-partitioned datasets with LSM primary + node-local secondary indexes
(paper §2.2, §4.3-4.4), columnar-native storage.

Faithful structure:
  * a Dataset is hash-partitioned (sharded) on its primary key;
  * each partition's primary index is an LSM "B+-tree" (core/lsm.LSMIndex);
  * secondary indexes are NODE-LOCAL: partition i's secondary structures
    only reference rows stored in partition i, so secondary lookups fan
    out to all partitions and return primary keys (or position bitmaps),
    never rows.  Secondary indexes are not separate LSM trees of
    (key, pk) rows: every primary component carries per-indexed-field
    **columnar CSR postings** (columnar/postings.FieldPostings — sorted
    key dictionary + offsets + row-position arrays; btree values, rtree
    grid-cell codes, keyword tokens), built at flush/merge beside the
    component batch exactly like the fuzzy ngram postings, adopted as-is
    by recovery and backfilled by late ``create_index``.  The mutable
    memtable tail is indexed at query time (cached per storage version),
    and newest-wins/tombstone semantics come from the live-row selection
    — a stale old-version posting is simply never selected — so inserts
    and deletes need no secondary maintenance at all;
  * records are ADM instances (open/closed types, core/adm) — the encoded
    size difference between Schema and KeyOnly types reproduces Table 2;
  * record-level "transactions": every insert/delete WAL-logs before apply;
    recovery = drop invalid components + replay WAL tail (paper §4.4);
  * storage is **columnar-first** (cf. the columnar-LSM paper in
    PAPERS.md): every immutable primary component carries a sorted-by-PK
    ColumnBatch + tombstone bitmap as its *primary* representation,
    shredded once at flush/merge inside core/lsm (the dataset hands the
    LSM layer its ``columnar_schema`` so open fields observed on insert
    shred correctly).  ``scan_partition_batch`` therefore reads component
    batches zero-copy — concat + newest-wins position selection + the
    tombstone bitmaps — decoding nothing; open-type drift is handled by
    merging per-component ColumnSchemas at read time (mixed physical
    kinds widen to ``obj`` on concat).  Row dicts exist only as the LSM
    components' lazy derived view for the row engine;
  * ``insert_batch`` is the feed ingestion path: records are validated
    and grouped per partition, then applied as one WAL+memtable pass per
    partition chunk (skipping per-record old-version lookups when no
    secondary index needs them), so a feed -> memory component -> flush
    pipeline never runs a per-record code path;
  * fuzzy queries ride ``"ngram"`` indexes (fuzzy/): per-component CSR
    gram postings built at flush/merge, T-occurrence candidate bitmaps
    aligned with the columnar scan (``ngram_candidate_mask``), batched
    similarity verification downstream.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core import adm
from ..core.functions import cells_covering_circle
from ..core.lsm import LSMIndex, LSMView, TOMBSTONE, TieredMergePolicy, \
    WALRecord, key_array, recover
from ..columnar.batch import ColumnBatch, promotes_lossless
from ..columnar.postings import FieldPostings, cell_codes_for_query
from ..columnar.schema import ColumnSchema

__all__ = ["PartitionedDataset", "DatasetSnapshot", "hash_partition",
           "hash_partition_array"]


def hash_partition(key: Any, num_partitions: int) -> int:
    """Deterministic hash partitioning (the paper's shard function).  Uses a
    Fibonacci-style integer mix for ints and FNV-1a for strings so partition
    spread does not depend on Python's randomized hash.  Integral floats
    canonicalize to ints first, so a double-pk record stored under 2.0
    routes to the same partition whether a later delete/lookup probes
    with 2 or 2.0 (ADM casts ints into float fields at validation)."""
    if isinstance(key, (float, np.floating)) and float(key).is_integer():
        key = int(key)
    if isinstance(key, (int, np.integer)):
        return int((int(key) * 11400714819323198485) % (2 ** 64)
                   >> 40) % num_partitions
    if isinstance(key, str):
        h = 14695981039346656037
        for b in key.encode():
            h = ((h ^ b) * 1099511628211) % (2 ** 64)
        return h % num_partitions
    return hash(key) % num_partitions


def hash_partition_array(keys: np.ndarray, num_partitions: int) -> np.ndarray:
    """Vectorized integer branch of ``hash_partition``: bit-identical
    placement for integer key arrays (uint64 two's-complement wrap matches
    python's mod-2**64 arithmetic).  The one copy of the mix constant both
    batch routing and the columnar repartition operator share."""
    h = (keys.astype(np.uint64)
         * np.uint64(11400714819323198485)) >> np.uint64(40)
    return (h % np.uint64(num_partitions)).astype(np.int64)


class _BatchGate:
    """Shared/exclusive gate making snapshots *batch*-consistent cuts.

    Writers (``insert`` / ``insert_batch`` / ``delete``) hold the gate
    in shared mode, so concurrent batches on different partitions still
    proceed in parallel; ``pin()`` takes it exclusive for the brief
    moment it pins every partition's LSM view.  Without it a snapshot
    could land *between* the per-partition sub-inserts of one
    ``insert_batch`` and observe half a micro-batch.  Exclusive waiters
    get priority so a steady write load cannot starve snapshot pins."""

    __slots__ = ("_cv", "_shared", "_excl", "_excl_waiting")

    def __init__(self):
        self._cv = threading.Condition()
        self._shared = 0
        self._excl = False
        self._excl_waiting = 0

    def acquire_shared(self) -> None:
        with self._cv:
            while self._excl or self._excl_waiting:
                self._cv.wait()
            self._shared += 1

    def release_shared(self) -> None:
        with self._cv:
            self._shared -= 1
            if self._shared == 0:
                self._cv.notify_all()

    def acquire_exclusive(self) -> None:
        with self._cv:
            self._excl_waiting += 1
            while self._excl or self._shared:
                self._cv.wait()
            self._excl_waiting -= 1
            self._excl = True

    def release_exclusive(self) -> None:
        with self._cv:
            self._excl = False
            self._cv.notify_all()


@dataclass
class _Partition:
    primary: LSMIndex


class PartitionedDataset:
    """An AsterixDB Dataset: typed, partitioned, LSM-indexed."""

    def __init__(self, name: str, dtype: adm.RecordType, primary_key: str,
                 num_partitions: int = 4, flush_threshold: int = 256,
                 merge_policy: Optional[TieredMergePolicy] = None,
                 columnar: bool = True):
        self.name = name
        self.dtype = dtype
        self.primary_key = (primary_key,)
        self.pk = primary_key
        self.num_partitions = num_partitions
        self.flush_threshold = flush_threshold
        self.merge_policy = merge_policy or TieredMergePolicy()
        self.columnar = columnar            # False: legacy row components
        # ngram(k) indexes: field -> gram length; btree/rtree/keyword
        # indexes: field -> kind.  ALL secondary postings live on the
        # primary components (built at flush/merge), none in a secondary
        # LSM tree
        self._ngram_specs: Dict[str, int] = {}
        self._sec_kinds: Dict[str, str] = {}
        self.partitions: List[_Partition] = [
            _Partition(LSMIndex(flush_threshold, self.merge_policy,
                                schema=self.columnar_schema,
                                columnar=None if columnar else False,
                                ngram_fields=self._ngram_fields,
                                sec_fields=self._sec_fields))
            for _ in range(num_partitions)]
        self.index_fields: List[str] = []
        self.index_kinds: Dict[str, str] = {}   # btree|rtree|keyword|ngram
        self.spatial_cell_size = 0.05
        self.stats = {"inserts": 0, "deletes": 0, "bytes_encoded": 0}
        # columnar engine: open fields seen so far (name -> column kind)
        self._open_schema = ColumnSchema()
        self._declared = tuple(f.name for f in dtype.fields)
        # assembled-scan cache keyed by (partition, recovery epoch, LSM
        # version): the version is the snapshot-isolation key, so a query
        # over a pinned snapshot and a live read at the same version share
        # entries, and concurrent writers simply create entries under new
        # keys instead of invalidating a reader's.  GC keeps, per
        # partition, only the current version plus pinned ones (the epoch
        # keeps pre-crash entries from colliding after recovery replaces
        # the LSMIndex and resets its version counter).
        self._scan_cache: Dict[Tuple[int, int, int], Dict[str, Any]] = {}
        self._cache_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._batch_gate = _BatchGate()
        self._recover_epoch = 0
        self._schema_cache: Optional[Tuple[Any, ColumnSchema]] = None

    # -- DDL ---------------------------------------------------------------
    def _ngram_fields(self) -> Dict[str, int]:
        """Callable handed to the primary LSM indexes so components
        flushed/merged after a late ``create_index(..., "ngram")`` still
        get their postings built."""
        return dict(self._ngram_specs)

    def _sec_spec(self, fld: str) -> Tuple[str, Any]:
        """The (kind, param) postings spec for one secondary field.  The
        rtree spec carries the *current* grid cell size, so a changed
        ``spatial_cell_size`` rebuilds stale per-component postings on
        their next probe instead of serving wrong cells."""
        kind = self._sec_kinds[fld]
        return (kind, self.spatial_cell_size if kind == "rtree" else None)

    def _sec_fields(self) -> Dict[str, Tuple[str, Any]]:
        """Callable handed to the primary LSM indexes: flush/merge build
        btree/rtree/keyword CSR postings for these fields beside the
        component batch (the ngram calculus, generalized)."""
        return {fld: self._sec_spec(fld) for fld in self._sec_kinds}

    def create_index(self, fld: str, kind: str = "btree",
                     gram_length: int = 3) -> None:
        """Node-local secondary index.  Every kind registers *derived
        columnar postings* on the primary components (no secondary LSM
        tree): backfill here builds them for existing components,
        flush/merge keep them current, and the memtable tail is indexed
        at query time."""
        if fld in self.index_fields:
            raise adm.ValidationError(f"index on {fld} already exists")
        if kind not in ("ngram", "btree", "rtree", "keyword"):
            raise adm.ValidationError(kind)
        self.index_fields.append(fld)
        self.index_kinds[fld] = kind
        if kind == "ngram":
            self._ngram_specs[fld] = int(gram_length)
            for part in self.partitions:        # backfill existing comps
                for comp in part.primary.components:
                    if comp.valid:
                        comp.ensure_gram_postings(fld, int(gram_length))
            return
        self._sec_kinds[fld] = kind
        spec = self._sec_spec(fld)
        for part in self.partitions:            # backfill existing comps
            for comp in part.primary.components:
                if comp.valid:
                    comp.ensure_sec_postings(fld, spec)

    # -- DML (record-level transactions) ------------------------------------
    def insert(self, record: Dict[str, Any]) -> None:
        """Secondary postings are derived data on the components, so an
        insert is exactly one primary-index update — no old-version
        lookup, no per-index (key, pk) maintenance."""
        rec = self.dtype.validate(record)
        nbytes = len(self.dtype.encode(rec))
        self._open_schema.observe_row(rec, self._declared)
        key = rec[self.pk]
        part = self.partitions[hash_partition(key, self.num_partitions)]
        self._batch_gate.acquire_shared()
        try:
            part.primary.insert(key, rec)
        finally:
            self._batch_gate.release_shared()
        with self._stats_lock:
            self.stats["bytes_encoded"] += nbytes
            self.stats["inserts"] += 1

    def insert_batch(self, records: Sequence[Dict[str, Any]]) -> None:
        """One-statement batch (paper Table 4: amortizes per-statement
        overhead).  Records are validated and routed once, then applied
        to each partition as a bulk WAL+memtable pass
        (``LSMIndex.insert_batch``).  Secondary postings being derived
        component data, indexed datasets take the same bulk path as
        unindexed ones — no per-record old-version lookups.  This is the
        feed store path: micro-batches flow straight into memory
        components and flush columnar."""
        P = self.num_partitions
        buckets: List[Tuple[List[Any], List[Dict[str, Any]]]] = \
            [([], []) for _ in range(P)]
        validate = self.dtype.validate
        # no per-record ADM encode here: batch-ingested records land as
        # shredded columns at flush, not as encoded row bytes, so the
        # ``bytes_encoded`` (row-format) accounting applies only to the
        # per-record ``insert`` path
        recs: List[Dict[str, Any]] = []
        keys: List[Any] = []
        for record in records:
            rec = validate(record)
            self._open_schema.observe_row(rec, self._declared)
            recs.append(rec)
            keys.append(rec[self.pk])
        ids: Optional[List[int]] = None
        try:        # vectorized routing, placement-identical to the int
            arr = np.asarray(keys)      # branch of ``hash_partition``
            if arr.dtype.kind not in "iu":
                raise TypeError("non-int pks")
            ids = hash_partition_array(arr, P).tolist()
        except (TypeError, ValueError, OverflowError):
            ids = None
        for j, (key, rec) in enumerate(zip(keys, recs)):
            ks, rs = buckets[ids[j] if ids is not None
                             else hash_partition(key, P)]
            ks.append(key)
            rs.append(rec)
        # shared gate: concurrent batches still run in parallel, but a
        # snapshot pin (exclusive) can never observe half of this batch
        self._batch_gate.acquire_shared()
        try:
            for part, (ks, rs) in zip(self.partitions, buckets):
                if ks:
                    part.primary.insert_batch(ks, rs)
        finally:
            self._batch_gate.release_shared()
        with self._stats_lock:
            self.stats["inserts"] += len(records)

    def delete(self, key: Any) -> bool:
        part = self.partitions[hash_partition(key, self.num_partitions)]
        self._batch_gate.acquire_shared()
        try:
            with part.primary._lock:  # lookup+delete is one write step
                if part.primary.lookup(key) is None:
                    return False
                part.primary.delete(key)
        finally:
            self._batch_gate.release_shared()
        with self._stats_lock:
            self.stats["deletes"] += 1
        return True

    # -- read paths ----------------------------------------------------------
    def lookup(self, key: Any) -> Optional[Dict[str, Any]]:
        """Primary-key point lookup: routed to ONE partition (paper: record
        lookup hits a single node)."""
        part = self.partitions[hash_partition(key, self.num_partitions)]
        return part.primary.lookup(key)

    def scan_partition(self, i: int,
                       _view: Optional[LSMView] = None
                       ) -> List[Dict[str, Any]]:
        view = _view if _view is not None else self._view(i)
        return [row for _, row in view.items()]

    def scan(self) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        for i in range(self.num_partitions):
            out.extend(self.scan_partition(i))
        return out

    # -- columnar read path --------------------------------------------------
    def columnar_schema(self) -> ColumnSchema:
        """Declared fields (from the RecordType) + open fields observed on
        insert, widened at read time by the per-component batch schemas —
        open-type drift between flushes (an int field turning string)
        surfaces here and unifies to ``obj``.  This is both the shred
        schema handed to the LSM layer at flush and the scan schema."""
        ver = (tuple(self._partition_version(i)
                     for i in range(self.num_partitions)),
               tuple(sorted(self._open_schema.kinds.items())))
        if self._schema_cache is not None and self._schema_cache[0] == ver:
            return self._schema_cache[1]
        sch = ColumnSchema.from_record_type(self.dtype) \
            .union(self._open_schema)
        for part in self.partitions:
            for comp in part.primary.components:
                if comp.valid and comp.batch is not None:
                    sch = sch.union(comp.batch.schema())
        self._schema_cache = (ver, sch)
        return sch

    def _partition_version(self, i: int) -> Tuple:
        return (self._recover_epoch, self.partitions[i].primary.version)

    def _view(self, i: int) -> LSMView:
        """Unfrozen point-in-time view of partition ``i`` (the default
        for every read path; concurrent readers pass a pinned view from a
        :class:`DatasetSnapshot` instead)."""
        return self.partitions[i].primary.current_view()

    def _cache_entry(self, i: int, view: LSMView) -> Dict[str, Any]:
        """The scan-cache entry for (partition, view-version): idx/keys
        live selection, assembled batches per projection, and memtable
        postings.  Creation GCs stale versions for the partition."""
        key = (i, self._recover_epoch, view.version)
        entry = self._scan_cache.get(key)
        if entry is None:
            with self._cache_lock:
                entry = self._scan_cache.get(key)
                if entry is None:
                    entry = self._scan_cache[key] = {
                        "idx": None, "keys": None, "batches": {},
                        "sec": {}, "ngram": {}}
                    self._cache_gc(i)
        return entry

    def _cache_gc(self, i: int) -> None:
        """Drop partition ``i`` cache entries whose version is neither
        current nor pinned by a live snapshot (called under
        ``_cache_lock``)."""
        prim = self.partitions[i].primary
        keep = set(prim.pinned_versions())
        keep.add(prim.version)
        epoch = self._recover_epoch
        for key in [k for k in self._scan_cache
                    if k[0] == i and (k[1] != epoch or k[2] not in keep)]:
            self._scan_cache.pop(key, None)

    def _cacheable(self, i: int, view: LSMView) -> bool:
        """An entry computed from a frozen (pinned) view is always safe
        to share; one computed from a live view is shared only if no
        writer raced the computation (else it may be torn — return it to
        this caller, never cache it)."""
        return view.frozen \
            or self.partitions[i].primary.version == view.version

    def _live_selection(self, i: int,
                        _view: Optional[LSMView] = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Newest-wins live-row selection for partition ``i``: positions
        ``idx`` into the memtable+components concat (newest first) and the
        pk array ``keys`` aligned with them, both ordered by ascending pk.
        Cached per storage version; computed from keys + tombstone flags
        only — no record decode, no column shred."""
        view = _view if _view is not None else self._view(i)
        cache = self._cache_entry(i, view)
        if cache["idx"] is not None:
            return cache["idx"], cache["keys"]
        key_arrays: List[np.ndarray] = []
        tombs: List[np.ndarray] = []
        mem = view.memtable            # newest version of any key it holds
        if mem:
            key_arrays.append(key_array(list(mem)))
            tombs.append(np.fromiter((r is TOMBSTONE
                                      for r in mem.values()),
                                     dtype=bool, count=len(mem)))
        for comp in view.components:   # newest first
            if comp.size == 0:
                continue
            key_arrays.append(comp.keys)
            tombs.append(comp.tomb)
        if not key_arrays:
            idx = np.zeros(0, dtype=np.int64)
            keys: np.ndarray = np.zeros(0, dtype=np.int64)
        else:
            all_tomb = np.concatenate(tombs)
            all_keys: Optional[np.ndarray]
            # mixed dtypes promote on concat: require a lossless round-
            # trip (the guard the merge kernel shares) or fall back to
            # the exact python-scalar path below
            numeric = all(ka.dtype != object and ka.dtype.kind in "biuf"
                          for ka in key_arrays) \
                and promotes_lossless(key_arrays)
            if numeric:
                # numeric pks: one concat, no per-key python hop — the
                # component key arrays are already dense numeric
                all_keys = np.concatenate(key_arrays)
                _, idx = np.unique(all_keys, return_index=True)
            else:
                flat_keys = [k for ka in key_arrays for k in ka.tolist()]
                all_keys = key_array(flat_keys)   # lossless or object
                if all_keys.dtype != object:
                    # first occurrence in newest-first concat == newest
                    _, idx = np.unique(all_keys, return_index=True)
                else:
                    all_keys = None
                    seen = set()
                    first = []
                    for pos, k2 in enumerate(flat_keys):
                        if k2 not in seen:
                            seen.add(k2)
                            first.append((k2, pos))
                    first.sort(key=lambda t: t[0])
                    idx = np.asarray([p for _, p in first], dtype=np.int64)
            idx = idx[~all_tomb[idx]]
            if all_keys is not None:
                keys = all_keys[idx]
            else:
                keys = np.empty(len(idx), dtype=object)
                for j, pos in enumerate(idx.tolist()):
                    keys[j] = flat_keys[pos]
        if self._cacheable(i, view):
            # keys before idx: concurrent readers test idx for presence
            cache["keys"] = keys
            cache["idx"] = idx
        return idx, keys

    def partition_pk_array(self, i: int,
                           _view: Optional[LSMView] = None) -> np.ndarray:
        """Sorted live primary keys of partition ``i``, aligned row-for-row
        with ``scan_partition_batch(i, ...)``: element j is the pk of the
        scan batch's j-th record.  Sorted candidate-PK arrays from the
        secondary indexes intersect against this array to become position
        bitmaps over the cached ColumnBatches (columnar index access)."""
        return self._live_selection(i, _view)[1]

    def scan_partition_batch(self, i: int,
                             columns: Optional[Sequence[str]] = None,
                             _view: Optional[LSMView] = None
                             ) -> ColumnBatch:
        """Columnar scan of one partition, zero-copy over component
        storage: the immutable components' primary ColumnBatches are
        projected and concatenated as-is (string dictionaries remap onto
        the merged dictionary; mixed open-type kinds widen to ``obj``),
        then the vectorized newest-wins position selection — computed
        from key + tombstone arrays only — gathers live rows.  Nothing
        is shredded except the (mutable) memtable tail.  Row order
        (sorted by pk) and contents match ``scan_partition`` exactly."""
        view = _view if _view is not None else self._view(i)
        schema = self.columnar_schema()
        names = list(schema) if columns is None \
            else [c for c in columns if c in schema]
        idx, _ = self._live_selection(i, view)
        cache = self._cache_entry(i, view)
        ckey = tuple(names)
        cached = cache["batches"].get(ckey)
        if cached is not None:
            return cached
        batches: List[ColumnBatch] = []
        mem = view.memtable
        if mem:
            batches.append(ColumnBatch.from_rows(
                [({} if r is TOMBSTONE else r) for r in mem.values()],
                schema, names))
        for comp in view.components:   # newest first, as in _live_selection
            if comp.size == 0:
                continue
            batches.append(comp.as_batch(schema).project(names))
        if not batches:
            out = ColumnBatch.from_rows([], schema, names)
        else:
            out = ColumnBatch.concat(batches).take(idx)
        if self._cacheable(i, view):
            cache["batches"][ckey] = out
        return out

    # -- secondary postings probes (candidate reads) --------------------------
    def _require_sec(self, fld: str, kind: str) -> Tuple[str, Any]:
        if self._sec_kinds.get(fld) != kind:
            raise adm.ValidationError(
                f"no {kind} index on {self.name}.{fld}")
        return self._sec_spec(fld)

    def _sec_sources(self, i: int, fld: str, view: LSMView
                     ) -> Tuple[List[Tuple[int, Any]], int]:
        """(offset, FieldPostings) per storage tier of the view in
        ``_live_selection`` concat order (memtable first, then components
        newest-first) plus the concat length — the secondary twin of
        ``_ngram_sources``.  Component postings were built at flush/merge
        (``ensure_sec_postings`` is a no-op then); the mutable memtable
        tail is indexed here, cached per storage version."""
        spec = self._sec_spec(fld)
        sources: List[Tuple[int, Any]] = []
        off = 0
        mem = view.memtable
        if mem:
            # cache entries are keyed by storage version, so the
            # per-field memtable postings cached here can never be stale
            cache = self._cache_entry(i, view)["sec"]
            p = cache.get(fld)
            if p is None or p.spec != spec:
                vals = [None if r is TOMBSTONE else r.get(fld)
                        for r in mem.values()]
                p = FieldPostings.from_values(vals, spec)
                if self._cacheable(i, view):
                    cache[fld] = p
            sources.append((0, p))
            off = len(mem)
        for comp in view.components:           # newest first
            if comp.size == 0:
                continue
            sources.append((off, comp.ensure_sec_postings(fld, spec)))
            off += comp.size
        return sources, off

    @staticmethod
    def _positions_mask(parts: List[np.ndarray], total: int,
                        idx: np.ndarray) -> np.ndarray:
        """Candidate bitmap over live scan positions from per-tier posting
        segments: one scatter pass (the ngram T-occurrence kernel at
        threshold 1) over the storage concat, then the newest-wins
        selection — a stale old-version hit is simply never selected."""
        from ..kernels.fuzzy_ops import t_occurrence_mask
        parts = [p for p in parts if len(p)]
        if not parts:
            return np.zeros(len(idx), dtype=bool)
        all_pos = np.concatenate(parts) if len(parts) > 1 else parts[0]
        return t_occurrence_mask(all_pos, total, 1)[idx]

    def secondary_candidate_mask(self, i: int, fld: str, lo: Any, hi: Any,
                                 _view: Optional[LSMView] = None
                                 ) -> np.ndarray:
        """Secondary B+-tree range probe -> candidate bitmap over
        partition ``i``'s scan positions (aligned with
        ``scan_partition_batch`` / ``partition_pk_array``).  Per tier the
        probe is two binary searches over the sorted key dictionary and
        one contiguous positions slice — no (key, pk) pair is ever
        materialized and no python list is walked."""
        self._require_sec(fld, "btree")
        view = _view if _view is not None else self._view(i)
        idx, _ = self._live_selection(i, view)
        if not len(idx):
            return np.zeros(0, dtype=bool)
        sources, total = self._sec_sources(i, fld, view)
        parts = [off + p.range_positions(lo, hi) for off, p in sources]
        return self._positions_mask(parts, total, idx)

    def secondary_fused_inputs(self, i: int, fld: str,
                               _view: Optional[LSMView] = None
                               ) -> Tuple[List[Tuple[int, Any]], int,
                                          np.ndarray]:
        """Raw operands for the fused Figure-6 chain dispatch
        (``columnar/plancache``): the per-tier ``(offset, FieldPostings)``
        sources, the storage concat length, and the live-selection index
        array — the same three inputs ``secondary_candidate_mask`` feeds
        through the per-operator scatter/gather path, returned unbaked so
        the whole probe -> bitmap -> gather can run as one jit dispatch
        over pooled device buffers."""
        self._require_sec(fld, "btree")
        view = _view if _view is not None else self._view(i)
        idx, _ = self._live_selection(i, view)
        sources, total = self._sec_sources(i, fld, view)
        return sources, total, idx

    def spatial_candidate_mask(self, i: int, fld: str,
                               center: Tuple[float, float],
                               radius: float,
                               _view: Optional[LSMView] = None
                               ) -> np.ndarray:
        """Grid ('rtree') probe -> candidate bitmap (post-validation still
        required: covering cells over-approximate the circle).  The
        covering cells are encoded and *deduplicated* once, then probed
        against each tier's sorted cell-code dictionary in one
        searchsorted + segment gather — overlapping cells are never
        scanned twice."""
        self._require_sec(fld, "rtree")
        view = _view if _view is not None else self._view(i)
        idx, _ = self._live_selection(i, view)
        if not len(idx):
            return np.zeros(0, dtype=bool)
        codes = cell_codes_for_query(
            cells_covering_circle(center, radius, self.spatial_cell_size))
        sources, total = self._sec_sources(i, fld, view)
        parts = [off + p.lookup_positions(codes) for off, p in sources]
        return self._positions_mask(parts, total, idx)

    def keyword_candidate_mask(self, i: int, fld: str, token: str,
                               fuzzy_ed: int = 0,
                               _view: Optional[LSMView] = None
                               ) -> np.ndarray:
        """Inverted-index probe -> candidate bitmap; ``fuzzy_ed > 0`` runs
        each tier's (distinct) token dictionary through one batched
        banded-DP call (kernels/fuzzy_ops) instead of a per-token python
        DP."""
        self._require_sec(fld, "keyword")
        view = _view if _view is not None else self._view(i)
        idx, _ = self._live_selection(i, view)
        if not len(idx):
            return np.zeros(0, dtype=bool)
        token = token.lower()
        sources, total = self._sec_sources(i, fld, view)
        parts = [off + p.token_positions(token, fuzzy_ed)
                 for off, p in sources]
        return self._positions_mask(parts, total, idx)

    # sorted-PK candidate surfaces: the bitmap gathered through the live
    # pk array (ascending, so the result is sorted and deduplicated) —
    # one view serves both sides, so mask and pk array can never skew
    def secondary_candidate_pks(self, i: int, fld: str, lo: Any, hi: Any,
                                _view: Optional[LSMView] = None
                                ) -> np.ndarray:
        view = _view if _view is not None else self._view(i)
        return self.partition_pk_array(i, view)[
            self.secondary_candidate_mask(i, fld, lo, hi, view)]

    def spatial_candidate_pks(self, i: int, fld: str,
                              center: Tuple[float, float],
                              radius: float,
                              _view: Optional[LSMView] = None
                              ) -> np.ndarray:
        view = _view if _view is not None else self._view(i)
        return self.partition_pk_array(i, view)[
            self.spatial_candidate_mask(i, fld, center, radius, view)]

    def keyword_candidate_pks(self, i: int, fld: str, token: str,
                              fuzzy_ed: int = 0,
                              _view: Optional[LSMView] = None
                              ) -> np.ndarray:
        view = _view if _view is not None else self._view(i)
        return self.partition_pk_array(i, view)[
            self.keyword_candidate_mask(i, fld, token, fuzzy_ed, view)]

    # row-engine surfaces (paper §4.3: 'the result of a secondary key
    # lookup is a set of primary keys') — same postings probes, scalar
    # list out
    def secondary_search_partition(self, i: int, fld: str, lo: Any, hi: Any,
                                   _view: Optional[LSMView] = None
                                   ) -> List[Any]:
        return self.secondary_candidate_pks(i, fld, lo, hi, _view).tolist()

    def spatial_search_partition(self, i: int, fld: str,
                                 center: Tuple[float, float],
                                 radius: float,
                                 _view: Optional[LSMView] = None
                                 ) -> List[Any]:
        return self.spatial_candidate_pks(i, fld, center, radius,
                                          _view).tolist()

    def keyword_search_partition(self, i: int, fld: str, token: str,
                                 fuzzy_ed: int = 0,
                                 _view: Optional[LSMView] = None
                                 ) -> List[Any]:
        return self.keyword_candidate_pks(i, fld, token, fuzzy_ed,
                                          _view).tolist()

    # -- ngram (fuzzy) candidate generation -----------------------------------
    def _ngram_sources(self, i: int, fld: str, view: LSMView
                       ) -> Tuple[List[Tuple[int, Any]], int]:
        """(offset, GramPostings) per storage tier of the view in
        ``_live_selection`` concat order (memtable first, then components
        newest-first) plus the concat length.  Component postings were
        built at flush/merge (``ensure_gram_postings`` is a no-op then);
        the mutable memtable tail is indexed here, cached per storage
        version."""
        from ..fuzzy.ngram import GramPostings
        k = self._ngram_specs[fld]
        sources: List[Tuple[int, Any]] = []
        off = 0
        mem = view.memtable
        if mem:
            # cache entries are keyed by storage version, so a per-field
            # memtable postings cache in one can never be stale
            cache = self._cache_entry(i, view)["ngram"]
            p = cache.get(fld)
            if p is None:
                vals = [None if r is TOMBSTONE else r.get(fld)
                        for r in mem.values()]
                p = GramPostings.from_values(vals, k)
                if self._cacheable(i, view):
                    cache[fld] = p
            sources.append((0, p))
            off = len(mem)
        for comp in view.components:           # newest first
            if comp.size == 0:
                continue
            sources.append((off, comp.ensure_gram_postings(fld, k)))
            off += comp.size
        return sources, off

    def ngram_candidate_mask(self, i: int, fld: str, spec: Tuple,
                             _view: Optional[LSMView] = None
                             ) -> np.ndarray:
        """T-occurrence candidate bitmap over partition ``i``'s scan
        positions (aligned with ``scan_partition_batch`` /
        ``partition_pk_array``): gram-hit posting segments from every
        storage tier concatenate into one position array and a single
        fused count kernel keeps positions with >= T hits.  T <= 0 means
        the index cannot prune — every row with an indexable value is a
        candidate."""
        from ..fuzzy.ngram import query_grams
        from ..kernels.fuzzy_ops import t_occurrence_mask
        if fld not in self._ngram_specs:
            raise adm.ValidationError(f"no ngram index on {self.name}.{fld}")
        view = _view if _view is not None else self._view(i)
        idx, _ = self._live_selection(i, view)
        if not len(idx):
            return np.zeros(0, dtype=bool)
        qh, threshold = query_grams(spec, self._ngram_specs[fld])
        sources, total = self._ngram_sources(i, fld, view)
        if threshold <= 0:
            has = np.zeros(total, dtype=bool)
            for off, p in sources:
                has[off:off + p.n_rows] = p.has_value
            return has[idx]
        parts = [off + p.hit_positions(qh) for off, p in sources]
        all_pos = np.concatenate(parts) if parts \
            else np.zeros(0, dtype=np.int64)
        return t_occurrence_mask(all_pos, total, threshold)[idx]

    def ngram_search_partition(self, i: int, fld: str, spec: Tuple,
                               _view: Optional[LSMView] = None
                               ) -> List[Tuple[Any, int]]:
        """Row-engine surface: (pk, gram hits) per candidate row — rows
        with any gram hit, plus (when T <= 0, so hits cannot prune) every
        row holding an indexable value.  The T_OCCURRENCE operator
        filters by threshold; counts here are host bincounts, the fused
        kernel belongs to the columnar path."""
        from ..fuzzy.ngram import query_grams
        if fld not in self._ngram_specs:
            raise adm.ValidationError(f"no ngram index on {self.name}.{fld}")
        view = _view if _view is not None else self._view(i)
        idx, keys = self._live_selection(i, view)
        if not len(idx):
            return []
        qh, threshold = query_grams(spec, self._ngram_specs[fld])
        sources, total = self._ngram_sources(i, fld, view)
        counts = np.zeros(total, dtype=np.int64)
        has = np.zeros(total, dtype=bool)
        for off, p in sources:
            has[off:off + p.n_rows] = p.has_value
            hp = p.hit_positions(qh)
            if len(hp):
                counts[off:off + p.n_rows] += np.bincount(
                    hp, minlength=p.n_rows)
        live_counts = counts[idx]
        live_has = has[idx]
        emit = (live_counts > 0) | live_has if threshold <= 0 \
            else live_counts > 0
        return [(pk, int(c)) for pk, c, e in
                zip(keys.tolist(), live_counts.tolist(), emit.tolist())
                if e]

    def primary_lookup_partition(self, i: int, pks: Sequence[Any],
                                 _view: Optional[LSMView] = None
                                 ) -> List[Dict[str, Any]]:
        """Sorted-PK batched primary lookups (Figure 6's SORT_PK step makes
        this access pattern sequential on a real B+-tree).  The plan's
        SORT_PK already ordered the candidates, so an in-order input is
        detected with one linear pass instead of being re-sorted."""
        prim = _view if _view is not None else self.partitions[i].primary
        pks = list(pks)
        try:
            unsorted = any(pks[j] > pks[j + 1] for j in range(len(pks) - 1))
        except TypeError:           # mixed-type pks: let sorted() decide
            unsorted = True
        if unsorted:
            pks = sorted(pks)
        out = []
        for pk in pks:
            row = prim.lookup(pk)
            if row is not None:
                out.append(row)
        return out

    # -- recovery -------------------------------------------------------------
    def crash_and_recover(self) -> "PartitionedDataset":
        """Simulate a crash: rebuild every partition from (valid components +
        WAL), discarding unflushed memtables and invalid components.
        Secondary postings are component data, so they survive (or are
        dropped) with their components — there is no secondary recovery
        pass, and the replayed memtable tail is re-indexed at query
        time."""
        self._recover_epoch += 1     # recovered indexes restart counters
        for part in self.partitions:
            part.primary = recover(part.primary.components, part.primary.wal,
                                   flush_threshold=self.flush_threshold,
                                   schema=self.columnar_schema,
                                   columnar=None if self.columnar else False,
                                   ngram_fields=self._ngram_fields,
                                   sec_fields=self._sec_fields)
        return self

    # -- snapshot isolation ---------------------------------------------------
    def pin(self) -> "DatasetSnapshot":
        """Pin a snapshot-isolated read view of every partition (paper
        §2.4: queries serve against one consistent LSM state while feeds
        keep ingesting).  Use as a context manager, or call
        ``release()`` when done so replaced components can physically
        retire."""
        return DatasetSnapshot(self)

    def __len__(self) -> int:
        return sum(len(p.primary) for p in self.partitions)


class DatasetSnapshot:
    """Snapshot-isolated read facade over a :class:`PartitionedDataset`.

    Pins one refcounted :class:`~repro.core.lsm.LSMView` per partition
    (``LSMIndex.pin()``) and exposes the dataset's entire *read* surface
    — row scans, columnar scans, candidate masks/PKs, ngram probes,
    primary lookups — bound to those frozen views, so a whole query plan
    (row or columnar engine) executes against one consistent LSM state
    end to end while writers proceed.  Duck-types the dataset for the
    executor and the columnar lowering: configuration attributes
    (``name``, ``num_partitions``, index registries, ...) delegate to the
    underlying dataset, mutators raise.  Scan-cache entries are shared
    with the live dataset through the (partition, epoch, version) key,
    so repeated queries over one snapshot — or a snapshot and a live
    read at the same version — reuse the same assembled batches.
    """

    def __init__(self, ds: PartitionedDataset):
        self._ds = ds
        # exclusive gate: waits out in-flight insert/insert_batch/delete
        # calls so the per-partition pins form one batch-consistent cut —
        # never half of a multi-partition micro-batch
        ds._batch_gate.acquire_exclusive()
        try:
            self._views: List[LSMView] = [p.primary.pin()
                                          for p in ds.partitions]
        finally:
            ds._batch_gate.release_exclusive()
        self._released = False

    # -- lifecycle -----------------------------------------------------------
    @property
    def versions(self) -> Tuple[int, ...]:
        """Per-partition pinned LSM versions (the snapshot identity)."""
        return tuple(v.version for v in self._views)

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> None:
        """Unpin every partition view (idempotent): deferred component
        retirements owed to this snapshot happen here."""
        if self._released:
            return
        self._released = True
        for v in self._views:
            v.release()

    def __enter__(self) -> "DatasetSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    # -- config passthrough (executor/lowering/catalog probes) ---------------
    def __getattr__(self, name: str):
        # only called for attributes not defined on the snapshot: config
        # and registry reads delegate; everything stateful is explicit
        if name.startswith("_abc"):
            raise AttributeError(name)
        return getattr(self._ds, name)

    def _blocked(self, *a, **k):
        raise TypeError("DatasetSnapshot is read-only — writes go to the "
                        "live PartitionedDataset")

    insert = insert_batch = delete = create_index = _blocked
    crash_and_recover = _blocked

    def pin(self) -> "DatasetSnapshot":
        raise TypeError("cannot pin a DatasetSnapshot — pin the live "
                        "PartitionedDataset")

    # -- read surface, bound to the pinned views -----------------------------
    def lookup(self, key: Any) -> Optional[Dict[str, Any]]:
        i = hash_partition(key, self._ds.num_partitions)
        return self._views[i].lookup(key)

    def scan_partition(self, i: int) -> List[Dict[str, Any]]:
        return self._ds.scan_partition(i, _view=self._views[i])

    def scan(self) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        for i in range(self._ds.num_partitions):
            out.extend(self.scan_partition(i))
        return out

    def partition_pk_array(self, i: int) -> np.ndarray:
        return self._ds.partition_pk_array(i, _view=self._views[i])

    def scan_partition_batch(self, i: int,
                             columns: Optional[Sequence[str]] = None
                             ) -> ColumnBatch:
        return self._ds.scan_partition_batch(i, columns,
                                             _view=self._views[i])

    def secondary_candidate_mask(self, i: int, fld: str, lo: Any, hi: Any
                                 ) -> np.ndarray:
        return self._ds.secondary_candidate_mask(i, fld, lo, hi,
                                                 _view=self._views[i])

    def secondary_fused_inputs(self, i: int, fld: str):
        # explicit (not __getattr__): the fused chain must see *this*
        # snapshot's pinned view, not a freshly-taken one
        return self._ds.secondary_fused_inputs(i, fld,
                                               _view=self._views[i])

    def spatial_candidate_mask(self, i: int, fld: str,
                               center: Tuple[float, float],
                               radius: float) -> np.ndarray:
        return self._ds.spatial_candidate_mask(i, fld, center, radius,
                                               _view=self._views[i])

    def keyword_candidate_mask(self, i: int, fld: str, token: str,
                               fuzzy_ed: int = 0) -> np.ndarray:
        return self._ds.keyword_candidate_mask(i, fld, token, fuzzy_ed,
                                               _view=self._views[i])

    def secondary_candidate_pks(self, i: int, fld: str, lo: Any, hi: Any
                                ) -> np.ndarray:
        return self._ds.secondary_candidate_pks(i, fld, lo, hi,
                                                _view=self._views[i])

    def spatial_candidate_pks(self, i: int, fld: str,
                              center: Tuple[float, float],
                              radius: float) -> np.ndarray:
        return self._ds.spatial_candidate_pks(i, fld, center, radius,
                                              _view=self._views[i])

    def keyword_candidate_pks(self, i: int, fld: str, token: str,
                              fuzzy_ed: int = 0) -> np.ndarray:
        return self._ds.keyword_candidate_pks(i, fld, token, fuzzy_ed,
                                              _view=self._views[i])

    def secondary_search_partition(self, i: int, fld: str, lo: Any, hi: Any
                                   ) -> List[Any]:
        return self._ds.secondary_search_partition(i, fld, lo, hi,
                                                   _view=self._views[i])

    def spatial_search_partition(self, i: int, fld: str,
                                 center: Tuple[float, float],
                                 radius: float) -> List[Any]:
        return self._ds.spatial_search_partition(i, fld, center, radius,
                                                 _view=self._views[i])

    def keyword_search_partition(self, i: int, fld: str, token: str,
                                 fuzzy_ed: int = 0) -> List[Any]:
        return self._ds.keyword_search_partition(i, fld, token, fuzzy_ed,
                                                 _view=self._views[i])

    def ngram_candidate_mask(self, i: int, fld: str, spec: Tuple
                             ) -> np.ndarray:
        return self._ds.ngram_candidate_mask(i, fld, spec,
                                             _view=self._views[i])

    def ngram_search_partition(self, i: int, fld: str, spec: Tuple
                               ) -> List[Tuple[Any, int]]:
        return self._ds.ngram_search_partition(i, fld, spec,
                                               _view=self._views[i])

    def primary_lookup_partition(self, i: int, pks: Sequence[Any]
                                 ) -> List[Dict[str, Any]]:
        return self._ds.primary_lookup_partition(i, pks,
                                                 _view=self._views[i])

    def __len__(self) -> int:
        return sum(int(self.partition_pk_array(i).shape[0])
                   for i in range(self._ds.num_partitions))
