"""Partitioned query executor — runs the rewriter's PhysicalOp plans over
PartitionedDatasets (the Hyracks role, host-side record engine).

Data between operators is a list of per-partition row lists; Connectors
redistribute it exactly as the paper's connector library does:

  OneToOne                 keep partition alignment
  MToNHashPartition(keys)  re-bucket rows by hash of the key columns
  MToNHashPartitionMerge   re-bucket + merge keeping sort order
  MToNReplicate            every partition receives the concatenation
  ReplicateToOne           fan-in to a single partition (global ops)

The executor also collects per-query counters (rows moved per connector,
operator cardinalities) used by the benchmarks to show e.g. the Figure-6
local/global aggregation split reducing "network" traffic.

``Executor(..., vectorize=True)`` additionally offers every operator to
the columnar engine first (columnar/lower.try_lower): supported subplans
— scans, sargable selects, index access paths (secondary/rtree/keyword
CSR-postings probe -> candidate bitmap -> gather + post-validate),
aggregates,
groups, sorts/top-k, equijoins — execute on ColumnBatches with
Pallas/jnp kernels (kernels/columnar_ops) and convert back to row dicts
only at the boundary; everything else (opaque predicates without
ranges, bare joins at the root) falls back to the row engine below, and
``ExecStats`` records rows_vectorized / rows_index_vectorized vs
rows_fallback.
"""

from __future__ import annotations

import contextlib
import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .. import obs as _obs
from ..core.algebra import Connector, PhysicalOp
from ..core.rewriter import Catalog, RewriteConfig, optimize
from .dataset import DatasetSnapshot, PartitionedDataset, hash_partition

__all__ = ["Executor", "run_query", "explain_analyze"]

Rows = List[Dict[str, Any]]
Parts = List[Rows]


@dataclass
class ExecStats:
    rows_moved: Dict[str, int] = field(default_factory=dict)
    op_rows: Dict[str, int] = field(default_factory=dict)
    rows_vectorized: int = 0    # produced by columnar-lowered operators
    rows_fallback: int = 0      # produced by the row engine while
    #                             vectorize=True (unsupported subplans)
    rows_index_vectorized: int = 0   # subset of rows_vectorized produced
    #                             by vectorized index access paths (index
    #                             search -> bitmap intersect -> gather)
    rows_fuzzy_vectorized: int = 0   # subset of rows_index_vectorized
    #                             produced by the fuzzy ngram chains
    #                             (T-occurrence bitmap -> batched verify)
    kernel_retraces: int = 0    # jit traces of the columnar kernel cores
    #                             this query triggered: repeated queries
    #                             over pow2-padded batches must show 0
    fallback_reasons: Dict[str, int] = field(default_factory=dict)
    #                             "OP_KIND: reason" -> occurrences, one
    #                             entry per subplan the columnar engine
    #                             declined (empty when nothing fell back)
    kernel_dispatches: int = 0  # device-bound kernel calls this query made
    h2d_bytes: int = 0          # operand bytes shipped host -> device
    d2h_bytes: int = 0          # result bytes fetched device -> host
    plan_cache_hits: int = 0    # fused chain dispatches reusing a
    #                             compiled plan shape (columnar/plancache)
    plan_cache_misses: int = 0  # fused chain plan shapes first seen (and
    #                             trace-compiled) during this query
    spmd_dispatches: int = 0    # shard_map'ed all-partition dispatches
    #                             this query made (runtime/spmd; 0 off-mesh)
    spmd_partitions: int = 0    # partitions those dispatches covered (the
    #                             python loop would have paid one dispatch
    #                             + one device_get per partition instead)

    def moved(self, conn: str, n: int) -> None:
        self.rows_moved[conn] = self.rows_moved.get(conn, 0) + n

    def produced(self, op: str, parts: Parts) -> None:
        self.op_rows[op] = self.op_rows.get(op, 0) + sum(map(len, parts))

    def vectorized(self, op: str, n: int) -> None:
        self.op_rows[op] = self.op_rows.get(op, 0) + n
        self.rows_vectorized += n

    def index_vectorized(self, op: str, n: int) -> None:
        self.op_rows[op] = self.op_rows.get(op, 0) + n
        self.rows_vectorized += n
        self.rows_index_vectorized += n

    def fuzzy_vectorized(self, op: str, n: int) -> None:
        self.index_vectorized(op, n)
        self.rows_fuzzy_vectorized += n

    def fell_back(self, op: str, reason: str) -> None:
        key = f"{op}: {reason}"
        self.fallback_reasons[key] = self.fallback_reasons.get(key, 0) + 1


class Executor:
    def __init__(self, datasets: Dict[str, PartitionedDataset],
                 vectorize: bool = False):
        self.datasets = datasets
        self.num_partitions = max(ds.num_partitions
                                  for ds in datasets.values())
        self.stats = ExecStats()
        self.vectorize = vectorize
        # explain_analyze: id(plan node) -> per-operator measurements
        # (None = plain execution, zero instrumentation overhead)
        self.analysis: Optional[Dict[int, Dict[str, Any]]] = None
        self._fallback_reasons: Optional[Dict[int, str]] = None

    # -- connectors ----------------------------------------------------------
    def _apply_connector(self, conn: Connector, parts: Parts) -> Parts:
        P = self.num_partitions
        if conn.name == "OneToOne":
            return parts
        if conn.name in ("MToNHashPartition", "MToNHashPartitionMerge"):
            out: Parts = [[] for _ in range(P)]
            moved = 0
            for i, rows in enumerate(parts):
                for r in rows:
                    j = hash_partition(tuple(r[k] for k in conn.keys)
                                       if len(conn.keys) > 1
                                       else r[conn.keys[0]], P)
                    if j != i:
                        moved += 1
                    out[j].append(r)
            if conn.name == "MToNHashPartitionMerge" and conn.sort_keys:
                for rows in out:
                    rows.sort(key=lambda r: tuple(r[k]
                                                  for k in conn.sort_keys))
            self.stats.moved(conn.name, moved)
            return out
        if conn.name == "MToNReplicate":
            allrows = [r for rows in parts for r in rows]
            self.stats.moved(conn.name, len(allrows) * (P - 1))
            return [list(allrows) for _ in range(P)]
        if conn.name == "ReplicateToOne":
            allrows = [r for rows in parts for r in rows]
            self.stats.moved(conn.name,
                             sum(len(rows) for rows in parts[1:]))
            out = [[] for _ in range(P)]
            out[0] = allrows
            return out
        raise ValueError(conn.name)

    def _input(self, op: PhysicalOp, i: int) -> Parts:
        child = self.execute_op(op.children[i])
        return self._apply_connector(op.connectors[i], child)

    # -- operators -------------------------------------------------------------
    def execute_op(self, op: PhysicalOp) -> Parts:
        # fast path: no explain, no tracing — one attribute check plus one
        # module-flag check on top of the actual operator work
        if self.analysis is None and not _obs.enabled():
            return self._run_op(op)[0]
        kt0 = _obs.kernel_totals()
        moved0 = sum(self.stats.rows_moved.values())
        t0 = time.perf_counter()
        with _obs.span("exec." + op.kind) as sp:
            parts, mode = self._run_op(op)
        wall = time.perf_counter() - t0
        kt1 = _obs.kernel_totals()
        rows_out = sum(map(len, parts))
        sp.set("mode", mode)
        sp.set("rows_out", rows_out)
        if self.analysis is not None:
            # inclusive values (children execute inside _run_op);
            # explain_analyze derives per-operator exclusive ones
            entry = {
                "op": op.kind, "mode": mode, "wall_s": wall,
                "rows_out": rows_out,
                "rows_moved": sum(self.stats.rows_moved.values()) - moved0,
                "kernel_dispatches": kt1[0] - kt0[0],
                "h2d_bytes": kt1[1] - kt0[1],
                "d2h_bytes": kt1[2] - kt0[2],
            }
            reason = (self._fallback_reasons or {}).pop(id(op), None)
            if reason is not None:
                entry["fallback_reason"] = reason
            self.analysis[id(op)] = entry
        return parts

    def _run_op(self, op: PhysicalOp) -> Tuple[Parts, str]:
        """Execute one operator (children recurse through execute_op).
        Returns (parts, mode): "columnar" when the subtree lowered,
        "fallback" when the row engine ran under vectorize=True, "row"
        otherwise."""
        k = op.kind
        P = self.num_partitions

        if self.vectorize:
            from ..columnar.lower import try_lower
            lowered = try_lower(op, self)
            if lowered is not None:
                return lowered(), "columnar"

        if k == "DATASET_SCAN":
            ds = self.datasets[op.attrs["dataset"]]
            parts = [ds.scan_partition(i) for i in range(ds.num_partitions)]
            parts += [[] for _ in range(P - ds.num_partitions)]

        elif k == "SECONDARY_INDEX_SEARCH":
            ds = self.datasets[op.attrs["dataset"]]
            fld, lo, hi = op.attrs["field"], op.attrs["lo"], op.attrs["hi"]
            parts = []
            for i in range(ds.num_partitions):
                pks = ds.secondary_search_partition(i, fld, lo, hi)
                parts.append([{"__pk": pk} for pk in pks])
            parts += [[] for _ in range(P - ds.num_partitions)]

        elif k == "SPATIAL_INDEX_SEARCH":
            ds = self.datasets[op.attrs["dataset"]]
            center, radius = op.attrs["args"]
            parts = []
            for i in range(ds.num_partitions):
                pks = ds.spatial_search_partition(i, op.attrs["field"],
                                                  center, radius)
                parts.append([{"__pk": pk} for pk in pks])
            parts += [[] for _ in range(P - ds.num_partitions)]

        elif k == "KEYWORD_INDEX_SEARCH":
            ds = self.datasets[op.attrs["dataset"]]
            token, fuzzy_ed = op.attrs["args"]
            parts = []
            for i in range(ds.num_partitions):
                pks = ds.keyword_search_partition(i, op.attrs["field"],
                                                  token, fuzzy_ed)
                parts.append([{"__pk": pk} for pk in sorted(set(pks))])
            parts += [[] for _ in range(P - ds.num_partitions)]

        elif k == "NGRAM_INDEX_SEARCH":
            ds = self.datasets[op.attrs["dataset"]]
            parts = []
            for i in range(ds.num_partitions):
                pairs = ds.ngram_search_partition(i, op.attrs["field"],
                                                  op.attrs["spec"])
                parts.append([{"__pk": pk, "__hits": h} for pk, h in pairs])
            parts += [[] for _ in range(P - ds.num_partitions)]

        elif k == "T_OCCURRENCE":
            # keep candidates with >= T gram hits (T <= 0: the ngram
            # search already emitted exactly the indexable rows)
            from ..fuzzy.ngram import query_grams
            _, thr = query_grams(op.attrs["spec"], op.attrs["gram_length"])
            parts = [[{"__pk": r["__pk"]} for r in rows
                      if r["__hits"] >= thr]
                     for rows in self._input(op, 0)]

        elif k == "SORT_PK":
            parts = [sorted(rows, key=lambda r: r["__pk"])
                     for rows in self._input(op, 0)]

        elif k == "PRIMARY_INDEX_LOOKUP":
            ds = self.datasets[op.attrs["dataset"]]
            inp = self._input(op, 0)
            parts = [ds.primary_lookup_partition(i, [r["__pk"] for r in rows])
                     if i < ds.num_partitions else []
                     for i, rows in enumerate(inp)]

        elif k == "POST_VALIDATE_SELECT":
            # §4.4: re-check the search criteria against the primary record
            pred = op.attrs["pred"]
            parts = [[r for r in rows if pred(r)]
                     for rows in self._input(op, 0)]

        elif k == "STREAM_SELECT":
            pred = op.attrs["pred"]
            parts = [[r for r in rows if pred(r)]
                     for rows in self._input(op, 0)]

        elif k == "STREAM_PROJECT":
            cols = op.attrs["cols"]
            parts = [[{c: r[c] for c in cols if c in r} for r in rows]
                     for rows in self._input(op, 0)]

        elif k == "HYBRID_HASH_JOIN":
            lk, rk = op.attrs["lkeys"], op.attrs["rkeys"]
            left, right = self._input(op, 0), self._input(op, 1)
            parts = []
            for lrows, rrows in zip(left, right):
                # build on the right, probe with the left
                table: Dict[Any, List[Dict[str, Any]]] = {}
                for r in rrows:
                    table.setdefault(tuple(r[k2] for k2 in rk), []).append(r)
                out = []
                for l in lrows:
                    for r in table.get(tuple(l[k2] for k2 in lk), ()):
                        out.append({**r, **l})
                parts.append(out)

        elif k == "INDEX_NL_JOIN":
            # paper Query 14: probe the right side's primary index per row
            lk = op.attrs["lkeys"]
            rds = self.datasets[op.attrs["right_dataset"]]
            left = self._input(op, 0)
            parts = []
            for lrows in left:
                out = []
                for l in lrows:
                    r = rds.lookup(l[lk[0]])
                    if r is not None:
                        out.append({**r, **l})
                parts.append(out)

        elif k == "LOCAL_AGG":
            parts = [[_agg_row(rows, op.attrs["aggs"], partial=True)]
                     for rows in self._input(op, 0)]

        elif k == "GLOBAL_AGG":
            inp = self._input(op, 0)
            allrows = [r for rows in inp for r in rows]
            parts = [[] for _ in range(P)]
            parts[0] = [_agg_merge(allrows, op.attrs["aggs"])]

        elif k in ("LOCAL_PREAGG", "HASH_GROUP", "GLOBAL_GROUP"):
            inp = self._input(op, 0)
            keys, aggs = op.attrs["keys"], op.attrs["aggs"]
            partial = (k == "LOCAL_PREAGG")
            merge = (k == "GLOBAL_GROUP")
            parts = []
            for rows in inp:
                groups: Dict[Tuple, Rows] = {}
                for r in rows:
                    groups.setdefault(tuple(r[kk] for kk in keys),
                                      []).append(r)
                out = []
                for gk, grows in groups.items():
                    row = (_agg_merge(grows, aggs) if merge
                           else _agg_row(grows, aggs, partial=partial))
                    row.update(dict(zip(keys, gk)))
                    out.append(row)
                parts.append(out)

        elif k == "LOCAL_SORT":
            keyf = _sort_key(op.attrs["keys"])
            parts = [sorted(rows, key=keyf, reverse=op.attrs.get("desc",
                                                                 False))
                     for rows in self._input(op, 0)]

        elif k == "SORT_MERGE_GATHER":
            inp = self._input(op, 0)
            keyf = _sort_key(op.attrs["keys"])
            allrows = [r for rows in inp for r in rows]
            parts = [[] for _ in range(P)]
            parts[0] = sorted(allrows, key=keyf,
                              reverse=op.attrs.get("desc", False))

        elif k == "LOCAL_TOPK":
            keyf = _sort_key(op.attrs["keys"])
            n = op.attrs["n"]
            parts = [sorted(rows, key=keyf,
                            reverse=op.attrs.get("desc", False))[:n]
                     for rows in self._input(op, 0)]

        elif k == "TOPK_MERGE":
            inp = self._input(op, 0)
            keyf = _sort_key(op.attrs["keys"])
            allrows = [r for rows in inp for r in rows]
            parts = [[] for _ in range(P)]
            parts[0] = sorted(allrows, key=keyf,
                              reverse=op.attrs.get("desc", False))[
                                  :op.attrs["n"]]

        elif k == "STREAM_LIMIT":
            inp = self._input(op, 0)
            parts = [rows[:op.attrs["n"]] for rows in inp]

        else:
            raise ValueError(f"unknown physical operator {k}")

        self.stats.produced(k, parts)
        if self.vectorize:
            self.stats.rows_fallback += sum(map(len, parts))
            return parts, "fallback"
        return parts, "row"


def _sort_key(keys: Sequence[str]) -> Callable:
    return lambda r: tuple(r[k] for k in keys)


def _agg_row(rows: Rows, aggs: Dict[str, Tuple[str, str]],
             partial: bool) -> Dict[str, Any]:
    """Local (partial) aggregation: avg is carried as (sum, count)."""
    out: Dict[str, Any] = {}
    for name, (fn, col) in aggs.items():
        vals = [r[col] for r in rows if col in r and r[col] is not None] \
            if col != "*" else rows
        if fn == "count":
            out[name] = len(vals)
        elif fn == "sum":
            out[name] = sum(vals) if vals else 0
        elif fn == "min":
            out[name] = min(vals) if vals else None
        elif fn == "max":
            out[name] = max(vals) if vals else None
        elif fn == "avg":
            if partial:
                out[name + "__sum"] = sum(vals) if vals else 0
                out[name + "__cnt"] = len(vals)
            else:
                out[name] = (sum(vals) / len(vals)) if vals else None
        else:
            raise ValueError(fn)
    return out


def _agg_merge(rows: Rows, aggs: Dict[str, Tuple[str, str]]
               ) -> Dict[str, Any]:
    """Global aggregation: merge partial rows if present, else aggregate raw
    rows directly (no-split configuration)."""
    out: Dict[str, Any] = {}
    for name, (fn, col) in aggs.items():
        if rows and (name in rows[0] or name + "__sum" in rows[0]):
            # merging partials
            if fn == "count" or fn == "sum":
                out[name] = sum(r[name] for r in rows)
            elif fn == "min":
                vals = [r[name] for r in rows if r[name] is not None]
                out[name] = min(vals) if vals else None
            elif fn == "max":
                vals = [r[name] for r in rows if r[name] is not None]
                out[name] = max(vals) if vals else None
            elif fn == "avg":
                s = sum(r[name + "__sum"] for r in rows)
                c = sum(r[name + "__cnt"] for r in rows)
                out[name] = s / c if c else None
        else:
            out.update(_agg_row(rows, {name: (fn, col)}, partial=False))
    return out


def _default_catalog(datasets: Dict[str, PartitionedDataset]) -> Catalog:
    """Catalog inferred from the datasets' own index declarations."""
    catalog = Catalog(
        primary_keys={n: ds.primary_key
                      for n, ds in datasets.items()},
        indexes=[],
        num_partitions=max(ds.num_partitions
                           for ds in datasets.values()))
    from ..core.rewriter import IndexInfo
    for n, ds in datasets.items():
        for fld in ds.index_fields:
            catalog.indexes.append(IndexInfo(
                f"{n}_{fld}_idx", n, fld,
                kind=getattr(ds, "index_kinds", {}).get(fld, "btree"),
                gram_length=getattr(ds, "_ngram_specs",
                                    {}).get(fld, 3)))
    return catalog


def _finish_stats(ex: "Executor", traces0: int,
                  kt0: Tuple[int, int, int],
                  pc0: Tuple[int, int],
                  sp0: Tuple[int, int] = (0, 0)) -> None:
    from ..columnar import plancache as _pc
    from ..kernels import columnar_ops as K
    from ..runtime import spmd as _sp
    kt1 = _obs.kernel_totals()
    pc1 = _pc.totals()
    sp1 = _sp.dispatch_totals()
    ex.stats.kernel_retraces = K.trace_count() - traces0
    ex.stats.kernel_dispatches = kt1[0] - kt0[0]
    ex.stats.h2d_bytes = kt1[1] - kt0[1]
    ex.stats.d2h_bytes = kt1[2] - kt0[2]
    ex.stats.plan_cache_hits = pc1[0] - pc0[0]
    ex.stats.plan_cache_misses = pc1[1] - pc0[1]
    ex.stats.spmd_dispatches = sp1[0] - sp0[0]
    ex.stats.spmd_partitions = sp1[1] - sp0[1]


def run_query(plan, datasets: Dict[str, PartitionedDataset],
              catalog: Optional[Catalog] = None,
              config: RewriteConfig = RewriteConfig(),
              vectorize: bool = False,
              snapshot: bool = False,
              mesh: Optional[Any] = None
              ) -> Tuple[Rows, "Executor"]:
    """Optimize a LogicalOp plan and execute it.  Returns (rows, executor)
    — the executor carries connector/operator statistics.  With
    ``vectorize=True`` supported subplans run on the columnar engine.
    With ``snapshot=True`` every dataset that supports ``pin()`` is
    pinned for the duration of the query, so the whole plan executes
    against one consistent LSM state even while concurrent writers are
    ingesting (snapshot isolation; pins are released on return).  With
    ``mesh`` (a jax Mesh with a ``"part"`` axis, or an int device count)
    the columnar engine's per-partition loops run as single shard_map'ed
    SPMD dispatches over the partition mesh (``runtime/spmd``); results
    are bit-identical to the loop, per the differential harness."""
    if catalog is None:
        catalog = _default_catalog(datasets)
    phys = optimize(plan, catalog, config)
    pinned = []
    exec_datasets = datasets
    if snapshot:
        exec_datasets = {}
        for n, ds in datasets.items():
            if hasattr(ds, "pin") and not isinstance(ds, DatasetSnapshot):
                snap = ds.pin()
                pinned.append(snap)
                exec_datasets[n] = snap
            else:
                exec_datasets[n] = ds
    try:
        from ..columnar import plancache as _pc
        from ..kernels import columnar_ops as K
        from ..runtime import spmd as _sp
        ctx = contextlib.nullcontext() if mesh is None else (
            _sp.use_partition_mesh(mesh) if isinstance(mesh, int)
            else _sp.use_partition_mesh(mesh=mesh))
        with ctx:
            ex = Executor(exec_datasets, vectorize=vectorize)
            traces0 = K.trace_count()
            kt0 = _obs.kernel_totals()
            pc0 = _pc.totals()
            sp0 = _sp.dispatch_totals()
            parts = ex.execute_op(phys)
            _finish_stats(ex, traces0, kt0, pc0, sp0)
            rows = [r for p in parts for r in p]
            return rows, ex
    finally:
        for snap in pinned:
            snap.release()


def _annotate(op: PhysicalOp, analysis: Dict[int, Dict[str, Any]]
              ) -> Dict[str, Any]:
    """Physical plan tree -> annotated dict tree.  Measured nodes carry
    inclusive values plus ``self_*`` exclusives (inclusive minus measured
    direct children); nodes executed inside a fused columnar closure
    carry whatever per-stage numbers the closure recorded."""
    children = [_annotate(c, analysis) for c in op.children]
    node: Dict[str, Any] = {"op": op.kind,
                            "connectors": [c.name for c in op.connectors]}
    e = analysis.get(id(op))
    if e is None:
        node["mode"] = "fused"      # ran inside an ancestor's closure
    else:
        node.update({kk: v for kk, v in e.items() if kk != "op"})
        if "wall_s" in e:           # measured (not a fused-stage entry)
            for key in ("wall_s", "rows_moved", "kernel_dispatches",
                        "h2d_bytes", "d2h_bytes"):
                node["self_" + key] = e[key] - sum(
                    c.get(key, 0) for c in children)
            node["rows_in"] = sum(c.get("rows_out", 0) for c in children)
    node["children"] = children
    return node


def explain_analyze(plan, datasets: Dict[str, PartitionedDataset],
                    catalog: Optional[Catalog] = None,
                    config: RewriteConfig = RewriteConfig(),
                    vectorize: bool = True,
                    mesh: Optional[Any] = None) -> Dict[str, Any]:
    """EXPLAIN ANALYZE: optimize, execute, and return the physical plan
    annotated per operator with wall time, rows in/out, connector rows
    moved, lowering outcome (columnar / fused / fallback+reason / row),
    kernel dispatches, and host<->device transfer bytes.

    Returns ``{"rows", "plan", "totals", "stats"}``: ``rows`` is the
    query result, ``plan`` the annotated operator tree (``self_*`` keys
    are per-operator exclusive values; plain keys are subtree-inclusive),
    ``totals`` the whole-query wall time and kernel traffic, ``stats``
    the executor's ExecStats.  Combine with ``obs.enable()`` +
    ``obs.dump_trace(path)`` for the same run on a Chrome-trace timeline.
    """
    if catalog is None:
        catalog = _default_catalog(datasets)
    phys = optimize(plan, catalog, config)
    ex = Executor(datasets, vectorize=vectorize)
    ex.analysis = {}
    ex._fallback_reasons = {}
    from ..columnar import plancache as _pc
    from ..kernels import columnar_ops as K
    from ..runtime import spmd as _sp
    ctx = contextlib.nullcontext() if mesh is None else (
        _sp.use_partition_mesh(mesh) if isinstance(mesh, int)
        else _sp.use_partition_mesh(mesh=mesh))
    traces0 = K.trace_count()
    kt0 = _obs.kernel_totals()
    pc0 = _pc.totals()
    sp0 = _sp.dispatch_totals()
    t0 = time.perf_counter()
    with ctx:
        parts = ex.execute_op(phys)
    wall = time.perf_counter() - t0
    _finish_stats(ex, traces0, kt0, pc0, sp0)
    rows = [r for p in parts for r in p]
    return {
        "rows": rows,
        "plan": _annotate(phys, ex.analysis),
        "totals": {
            "wall_s": wall,
            "rows": len(rows),
            "kernel_dispatches": ex.stats.kernel_dispatches,
            "h2d_bytes": ex.stats.h2d_bytes,
            "d2h_bytes": ex.stats.d2h_bytes,
            "kernel_retraces": ex.stats.kernel_retraces,
            "plan_cache_hits": ex.stats.plan_cache_hits,
            "plan_cache_misses": ex.stats.plan_cache_misses,
            "spmd_dispatches": ex.stats.spmd_dispatches,
            "spmd_partitions": ex.stats.spmd_partitions,
        },
        "stats": ex.stats,
    }
