"""Straggler detection + elastic-degradation policy (1000+-node deliverable).

Two mechanisms (both simulated deterministically on CPU, designed for the
fleet):

1. **Data-layer racing** — data/feeds.RedundantIntake already races N intake
   replicas first-wins (exactly-once by deterministic cursors).

2. **Step-time watchdog** (this module) — per-step wall times feed a robust
   outlier detector (median + MAD); a persistent straggler triggers the
   elastic policy: checkpoint (validity-bit component), drop the slow hosts,
   and resume on a smaller mesh (checkpoint/manager's elastic restore
   re-resolves every PartitionSpec against the new mesh).

On a real fleet the wall-times come per-host from the coordinator's
heartbeats; here the Trainer feeds its local step times (tests inject
synthetic slow hosts).
"""

from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["StragglerWatchdog", "ElasticPolicy"]


@dataclass
class StragglerWatchdog:
    """Flags hosts whose step times are persistent robust outliers.

    ``threshold``: multiple of the median absolute deviation above the
    median that counts as slow.  ``patience``: consecutive slow steps before
    a host is reported (transient jitter is not a straggler).
    """

    threshold: float = 4.0
    patience: int = 3
    window: int = 32
    history: Dict[str, List[float]] = field(default_factory=dict)
    strikes: Dict[str, int] = field(default_factory=dict)

    def observe(self, step_times: Dict[str, float]) -> List[str]:
        """Feed one step's per-host wall times; returns hosts to evict."""
        times = list(step_times.values())
        med = statistics.median(times)
        mad = statistics.median([abs(t - med) for t in times]) or \
            max(med * 0.01, 1e-9)
        flagged = []
        for host, t in step_times.items():
            self.history.setdefault(host, []).append(t)
            del self.history[host][:-self.window]
            if t > med + self.threshold * mad:
                self.strikes[host] = self.strikes.get(host, 0) + 1
            else:
                self.strikes[host] = 0
            if self.strikes[host] >= self.patience:
                flagged.append(host)
        return flagged

    def slowdown(self, host: str) -> float:
        """Estimated slowdown factor vs the fleet median (for logs)."""
        all_times = [t for ts in self.history.values() for t in ts]
        if not all_times or host not in self.history:
            return 1.0
        return (statistics.median(self.history[host])
                / statistics.median(all_times))


@dataclass
class ElasticPolicy:
    """Decides the degraded mesh after evictions.

    The production mesh axes must keep their divisibility contract, so the
    policy shrinks the `data` axis to the largest power-of-two of surviving
    hosts and reports the new (data, model) shape; the caller checkpoints,
    re-creates the mesh, and restores (elastic restore is exercised in
    tests/test_system.py::test_elastic_checkpoint_restore_across_meshes).
    """

    model_axis: int = 16
    min_data_axis: int = 1

    def degraded_mesh(self, surviving_hosts: int,
                      chips_per_host: int = 4) -> Tuple[int, int]:
        chips = surviving_hosts * chips_per_host
        data = max(self.min_data_axis, 1)
        while data * 2 * self.model_axis <= chips:
            data *= 2
        return (data, self.model_axis)


def run_with_watchdog(step_fn: Callable[[], float], hosts: Sequence[str],
                      host_latency: Callable[[str, int], float],
                      steps: int,
                      watchdog: Optional[StragglerWatchdog] = None,
                      on_evict: Optional[Callable[[List[str]], None]] = None,
                      ) -> Dict[str, object]:
    """Simulation driver: run ``steps`` steps, synthesizing per-host wall
    times as base_step_time x host_latency(host, step); evictions fire the
    callback once and stop the run (the caller restarts elastically)."""
    wd = watchdog or StragglerWatchdog()
    evicted: List[str] = []
    for s in range(steps):
        base = step_fn()
        times = {h: base * host_latency(h, s) for h in hosts}
        bad = wd.observe(times)
        if bad:
            evicted = bad
            if on_evict is not None:
                on_evict(bad)
            break
    return {"evicted": evicted, "steps_run": s + 1,
            "slowdowns": {h: wd.slowdown(h) for h in evicted}}
