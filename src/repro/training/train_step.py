"""Train/serve step builders — the compiled "queries" of the framework.

The paper's unit of optimization is a query plan; ours is a step.  Like an
AQL query, a step is built from a logical program (the model), partitioned by
the rule table (runtime/sharding.py), and lowered to a distributed executable
whose exchanges (collectives) appear exactly where partitioning changes.

Features:
  * gradient accumulation (scan over microbatches)
  * optional error-feedback int8 gradient compression (optim/grad_compress)
  * MoE aux-loss handling lives in the model's loss_fn
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..models.layers import param_logical_axes
from ..models.model import make_loss_fn, make_decode_fn, make_prefill_fn, \
    model_specs
from ..optim import adamw
from ..optim.grad_compress import ef_quantize, ef_state
from ..runtime.sharding import ShardingRules, DEFAULT_RULES, constrain

__all__ = ["make_train_step", "make_serve_steps", "init_train_state"]


def init_train_state(params: Any, opt_cfg: adamw.OptimizerConfig,
                     compress: bool = False) -> Dict[str, Any]:
    state = adamw.init(params)
    if compress:
        state["ef_err"] = ef_state(params)
    return state


def make_train_step(cfg: ModelConfig,
                    opt_cfg: adamw.OptimizerConfig = adamw.OptimizerConfig(),
                    rules: ShardingRules = DEFAULT_RULES,
                    grad_accum: int = 1,
                    compress: bool = False) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``grad_accum`` > 1 the batch's leading dim is split into microbatches
    and gradients are accumulated in f32 before the optimizer update.
    """
    loss_fn = make_loss_fn(cfg, rules)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    _axes = param_logical_axes(model_specs(cfg))

    def _shard_grads(grads):
        """Pin gradients to the parameters' storage (FSDP x TP) layout so
        the cross-shard reduction lowers as reduce-scatter, not a full
        all-reduce + slice (§Perf iteration 5)."""
        flat_g, treedef = jax.tree.flatten(grads)
        is_axes = lambda x: (isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x))
        flat_ax = jax.tree.flatten(_axes, is_leaf=is_axes)[0]
        return jax.tree.unflatten(
            treedef, [constrain(g, ax, rules)
                      for g, ax in zip(flat_g, flat_ax)])

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape((grad_accum, -1) + x.shape[1:]), b)

        def body(carry, mb):
            acc, msum = carry
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                acc, grads)
            msum = {k: msum[k] + metrics[k] / grad_accum for k in msum}
            return (acc, msum), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        m0 = {k: jnp.zeros((), jnp.float32)
              for k in ("loss", "nll", "accuracy")}
        (grads, metrics), _ = jax.lax.scan(body, (zeros, m0), micro(batch))
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        grads = _shard_grads(grads)
        if compress:
            grads, new_err = ef_quantize(grads, opt_state["ef_err"])
        new_params, new_opt, opt_metrics = adamw.update(
            grads, {k: opt_state[k] for k in ("m", "v", "step")},
            params, opt_cfg)
        if compress:
            new_opt["ef_err"] = new_err
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


def make_serve_steps(cfg: ModelConfig,
                     rules: ShardingRules = DEFAULT_RULES
                     ) -> Tuple[Callable, Callable]:
    """(prefill_step, decode_step) for the serving path."""
    return make_prefill_fn(cfg, rules), make_decode_fn(cfg, rules)
