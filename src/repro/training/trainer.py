"""Fault-tolerant training driver.

Wires together: feed pipeline (data/feeds) -> train step (training/train_step)
-> LSM checkpointing (checkpoint/manager) with a step-metadata WAL, plus:

  * deterministic resume: the feed cursor is checkpointed with the model, so
    a restarted run consumes exactly the records the crashed run would have;
  * failure injection for tests (``fail_at_step``) — the restarted Trainer
    recovers from the newest VALID component and replays;
  * elastic restart: ``restore`` re-resolves shardings against the current
    mesh, so the same checkpoint restores onto a different device count.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ModelConfig
from ..data.feeds import BatchAssembler, Feed, SyntheticTokenAdaptor
from ..models.layers import init_params, param_shardings
from ..models.model import model_specs
from ..optim import adamw
from ..runtime.sharding import DEFAULT_RULES, ShardingRules
from .train_step import init_train_state, make_train_step

__all__ = ["Trainer", "InjectedFailure"]


class InjectedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: ModelConfig, *, global_batch: int, seq_len: int,
                 ckpt_dir: str,
                 opt_cfg: adamw.OptimizerConfig = adamw.OptimizerConfig(),
                 rules: ShardingRules = DEFAULT_RULES,
                 mesh=None, compress: bool = False, keep: int = 3,
                 param_dtype=jnp.float32, seed: int = 0):
        self.cfg = cfg
        self.rules = rules
        self.mesh = mesh
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.opt_cfg = opt_cfg
        self.compress = compress
        self.param_dtype = param_dtype
        self.seed = seed
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep)
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, rules,
                                               compress=compress),
                               donate_argnums=(0, 1))
        # -- data pipeline: primary feed -> batch assembler ------------------
        self.assembler = BatchAssembler(global_batch)
        self.feed = Feed(
            name="train_feed",
            adaptor=SyntheticTokenAdaptor(seq_len, cfg.vocab_size, seed),
            store=self.assembler)
        self.params = None
        self.opt_state = None
        self.step = 0
        self.history: list = []

    # -- state init / restore -------------------------------------------------
    def init_state(self) -> None:
        specs = model_specs(self.cfg)
        self.params = init_params(specs, jax.random.key(self.seed),
                                  self.param_dtype)
        if self.mesh is not None:
            sh = param_shardings(specs, self.mesh, self.rules)
            self.params = jax.tree.map(jax.device_put, self.params, sh)
        self.opt_state = init_train_state(self.params, self.opt_cfg,
                                          self.compress)
        self.step = 0

    def restore(self) -> bool:
        """Resume from the newest VALID checkpoint (elastic: uses the
        CURRENT mesh's shardings).  Returns True if restored."""
        sh = None
        if self.mesh is not None:
            sh = {"params": param_shardings(model_specs(self.cfg),
                                            self.mesh, self.rules)}
        got = self.ckpt.load_latest()
        if got is None:
            return False
        step, state, extra = got
        self.params = state["params"]
        self.opt_state = state["opt"]
        if self.mesh is not None:
            shp = param_shardings(model_specs(self.cfg), self.mesh,
                                  self.rules)
            self.params = jax.tree.map(jax.device_put, self.params, shp)
        self.step = step
        self.feed.restore(extra["feed"])
        self.assembler.backlog = []
        return True

    def init_or_restore(self) -> None:
        if not self.restore():
            self.init_state()

    # -- training loop --------------------------------------------------------
    def _next_batch(self) -> Dict[str, jnp.ndarray]:
        while True:
            b = self.assembler.take()
            if b is not None:
                return {k: jnp.asarray(v) for k, v in b.items()}
            self.feed.pump(self.global_batch)

    def run(self, num_steps: int, checkpoint_every: int = 0,
            fail_at_step: Optional[int] = None,
            log_every: int = 10) -> Dict[str, Any]:
        assert self.params is not None, "call init_or_restore() first"
        t0 = time.time()
        last = {}
        for _ in range(num_steps):
            if fail_at_step is not None and self.step == fail_at_step:
                raise InjectedFailure(f"injected failure at step {self.step}")
            batch = self._next_batch()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            last = {k: float(v) for k, v in metrics.items()}
            self.history.append({"step": self.step, **last})
            self.ckpt.log_step({"step": self.step,
                                "feed_cursor": self.feed.cursor,
                                "loss": last.get("loss")})
            if checkpoint_every and self.step % checkpoint_every == 0:
                self.save_checkpoint()
        last["wall_s"] = time.time() - t0
        return last

    def save_checkpoint(self, crash_before_validity: bool = False) -> None:
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"feed": self.feed.state(),
                   "config": {"arch": self.cfg.name,
                              "global_batch": self.global_batch}},
            crash_before_validity=crash_before_validity)
