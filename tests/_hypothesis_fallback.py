"""Seeded stand-in for the tiny slice of hypothesis these tests use.

The pinned container has no ``hypothesis``; rather than skip every
property test, modules fall back to this shim:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

``given`` then runs ``max_examples`` deterministic seeded examples per
test.  Only the strategies this repo's tests use are implemented.
"""

from __future__ import annotations

import random
import string
from typing import Any, Callable, List, Optional, Sequence


class Strategy:
    def __init__(self, sample: Callable[[random.Random], Any]):
        self._sample = sample

    def example(self, rng: random.Random) -> Any:
        return self._sample(rng)

    def filter(self, pred: Callable[[Any], bool]) -> "Strategy":
        def sample(rng: random.Random) -> Any:
            for _ in range(1000):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too strict for fallback shim")
        return Strategy(sample)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._sample(rng)))


class _Strategies:
    @staticmethod
    def integers(min_value: Optional[int] = None,
                 max_value: Optional[int] = None) -> Strategy:
        lo = -(2 ** 40) if min_value is None else min_value
        hi = 2 ** 40 if max_value is None else max_value
        return Strategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(allow_nan: bool = True, allow_infinity: bool = True,
               min_value: Optional[float] = None,
               max_value: Optional[float] = None) -> Strategy:
        lo = -1e9 if min_value is None else min_value
        hi = 1e9 if max_value is None else max_value

        def sample(rng: random.Random) -> float:
            specials = []
            if allow_nan:
                specials.append(float("nan"))
            if allow_infinity:
                specials += [float("inf"), float("-inf")]
            if specials and rng.random() < 0.05:
                return rng.choice(specials)
            if rng.random() < 0.2:
                return float(rng.choice([0.0, -0.0, 1.0, -1.0]))
            return rng.uniform(lo, hi)
        return Strategy(sample)

    @staticmethod
    def text(min_size: int = 0, max_size: int = 16,
             alphabet: Optional[str] = None) -> Strategy:
        chars = alphabet or (string.ascii_letters + string.digits
                             + " -_.éλß")
        return Strategy(lambda rng: "".join(
            rng.choice(chars)
            for _ in range(rng.randint(min_size, max_size))))

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int = 16) -> Strategy:
        return Strategy(lambda rng: [
            elements.example(rng)
            for _ in range(rng.randint(min_size, max_size))])

    @staticmethod
    def tuples(*elements: Strategy) -> Strategy:
        return Strategy(lambda rng: tuple(e.example(rng) for e in elements))

    @staticmethod
    def dictionaries(keys: Strategy, values: Strategy, min_size: int = 0,
                     max_size: int = 8) -> Strategy:
        def sample(rng: random.Random) -> dict:
            n = rng.randint(min_size, max_size)
            out = {}
            for _ in range(n * 3):
                if len(out) >= n:
                    break
                out[keys.example(rng)] = values.example(rng)
            return out
        return Strategy(sample)

    @staticmethod
    def one_of(*options: Strategy) -> Strategy:
        return Strategy(lambda rng: rng.choice(options).example(rng))

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> Strategy:
        elements = list(elements)
        return Strategy(lambda rng: rng.choice(elements))


strategies = _Strategies()


def settings(max_examples: int = 30, deadline: Any = None, **_: Any):
    def deco(fn: Callable) -> Callable:
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: Strategy, **kwstrats: Strategy):
    """Run the test body over seeded examples (deterministic per test name)."""
    def deco(fn: Callable) -> Callable:
        n = getattr(fn, "_fallback_max_examples", 30)

        def runner():
            rng = random.Random(f"shim:{fn.__name__}")
            for _ in range(n):
                args = [s.example(rng) for s in strats]
                kwargs = {k: s.example(rng) for k, s in kwstrats.items()}
                fn(*args, **kwargs)

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
