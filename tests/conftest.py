"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design (the 512-device override belongs to launch/dryrun.py ONLY)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
