"""ADM open/closed record types (paper §2.1) — unit + property tests."""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests degrade to the seeded fallback below
    HAVE_HYPOTHESIS = False

from repro.core import adm


def _person(open_=True):
    return adm.RecordType("Person", (
        adm.Field("id", adm.INT32),
        adm.Field("name", adm.STRING),
        adm.Field("zip", adm.STRING, optional=True),
    ), open=open_)


def test_closed_type_rejects_extras():
    rt = _person(open_=False)
    with pytest.raises(adm.ValidationError):
        rt.validate({"id": 1, "name": "a", "hobby": "chess"})


def test_open_type_keeps_extras():
    rt = _person(open_=True)
    rec = rt.validate({"id": 1, "name": "a", "hobby": "chess"})
    assert rec["hobby"] == "chess"


def test_missing_required_field():
    rt = _person()
    with pytest.raises(adm.ValidationError):
        rt.validate({"id": 1})


def test_optional_field_roundtrip():
    rt = _person()
    enc = rt.encode(rt.validate({"id": 1, "name": "a"}))
    dec, _ = rt.decode(enc)
    assert dec == {"id": 1, "name": "a"}


def test_key_only_encoding_is_larger():
    """Table 2: KeyOnly (open) instances carry field names inline."""
    rt = _person(open_=True)
    ko = rt.key_only("id")
    rec = {"id": 7, "name": "NameNameName", "zip": "92617"}
    assert ko.encoded_size(rec) > rt.encoded_size(rec)


def test_int32_range():
    with pytest.raises(adm.ValidationError):
        adm.INT32.validate(2 ** 40)


def test_nested_record_and_bag():
    addr = adm.RecordType("Addr", (adm.Field("city", adm.STRING),),
                          open=False)
    rt = adm.RecordType("U", (
        adm.Field("id", adm.INT32),
        adm.Field("address", addr),
        adm.Field("friend-ids", adm.BagType(adm.INT32)),
        adm.Field("employment", adm.OrderedListType(addr)),
    ))
    rec = rt.validate({"id": 1, "address": {"city": "irvine"},
                       "friend-ids": [3, 1, 2],
                       "employment": [{"city": "x"}]})
    assert rec["friend-ids"] == [1, 2, 3]  # bags canonicalize
    enc = rt.encode(rec)
    dec, _ = rt.decode(enc)
    assert dec == rec


if HAVE_HYPOTHESIS:
    @given(st.dictionaries(
        st.text(min_size=1, max_size=8).filter(lambda s: s not in ("id",)),
        st.one_of(st.integers(min_value=-2**40, max_value=2**40),
                  st.text(max_size=12), st.booleans(),
                  st.floats(allow_nan=False, allow_infinity=False),
                  st.lists(st.integers(min_value=0, max_value=100),
                           max_size=4)),
        max_size=6))
    @settings(max_examples=60, deadline=None)
    def test_open_fields_roundtrip_property(extras):
        """Any JSON-ish open payload encodes/decodes losslessly."""
        rt = adm.RecordType("T", (adm.Field("id", adm.INT32),), open=True)
        rec = rt.validate({"id": 1, **extras})
        dec, _ = rt.decode(rt.encode(rec))
        assert dec == rec
else:
    def test_open_fields_roundtrip_property():
        pytest.importorskip("hypothesis")


def _random_open_value(rng: random.Random, depth: int = 0):
    kinds = ["int", "str", "bool", "float", "none"]
    if depth < 2:
        kinds += ["list", "dict"]
    k = rng.choice(kinds)
    if k == "int":
        return rng.randrange(-2**40, 2**40)
    if k == "str":
        return "".join(rng.choice("abcxyz-0189 é") for _ in range(rng.randrange(12)))
    if k == "bool":
        return rng.random() < 0.5
    if k == "float":
        return rng.uniform(-1e6, 1e6)
    if k == "none":
        return None
    if k == "list":
        return [_random_open_value(rng, depth + 1)
                for _ in range(rng.randrange(4))]
    return {f"k{i}": _random_open_value(rng, depth + 1)
            for i in range(rng.randrange(4))}


def test_open_fields_roundtrip_seeded():
    """Seeded, hypothesis-free analogue of the property test above."""
    rng = random.Random(1234)
    rt = adm.RecordType("T", (adm.Field("id", adm.INT32),), open=True)
    for _ in range(60):
        extras = {f"f{i}": _random_open_value(rng)
                  for i in range(rng.randrange(7))}
        rec = rt.validate({"id": 1, **extras})
        dec, _ = rt.decode(rt.encode(rec))
        assert dec == rec


def test_dataverse_catalog_metadata_as_data():
    dv = adm.Dataverse("TinyTest")
    dv.create_type(_person())
    with pytest.raises(adm.ValidationError):
        dv.create_type(_person())

    class DS:  # minimal dataset stub
        dtype = _person()
        primary_key = ("id",)
        num_partitions = 4

    dv.create_dataset("People", DS())
    cat = dv.catalog_records()
    assert cat[0]["dataset"] == "People"
    assert cat[0]["primary_key"] == ["id"]


def test_float_fields_cast_ints_at_validation():
    """ADM casts ints into declared float/double fields at ingest, so the
    value a lookup returns does not depend on whether the record still
    sits in the memtable or was already shredded into a component
    (regression for the columnar-native storage)."""
    import pytest
    from repro.core import adm
    rt = adm.RecordType("P", (adm.Field("id", adm.INT64),
                              adm.Field("price", adm.DOUBLE)), open=True)
    rec = rt.validate({"id": 1, "price": 10})
    assert rec["price"] == 10.0 and isinstance(rec["price"], float)
    with pytest.raises(adm.ValidationError):
        adm.DOUBLE.validate("not a number")


def test_point_coords_validated_not_just_encoded():
    """POINT coordinate typing must be gated at validation (shared by
    insert and insert_batch), not only at encode time, since batch
    ingestion stores columns without encoding (regression)."""
    import pytest
    from repro.core import adm
    assert adm.POINT.validate((1.5, -2)) == (1.5, -2)
    for bad in (("x", "y"), (1.0,), (1.0, 2.0, 3.0), (True, 1.0)):
        with pytest.raises(adm.ValidationError):
            adm.POINT.validate(bad)
