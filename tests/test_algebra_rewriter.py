"""Algebricks-analogue plan rewriter (paper §4.2, §5.1): rule behavior."""

import pytest

from repro.core import algebra as A
from repro.core.rewriter import Catalog, IndexInfo, RewriteConfig, optimize


def _catalog():
    return Catalog(
        primary_keys={"Users": ("id",), "Msgs": ("message-id",)},
        indexes=[IndexInfo("ix_since", "Users", "user-since"),
                 IndexInfo("ix_author", "Msgs", "author-id")],
        num_partitions=4)


def _ops(phys):
    return [op.kind for op in phys.all_ops()]


def test_index_access_path_with_post_validate():
    """R2: SELECT(sargable) over SCAN becomes Figure 6's plan: secondary
    search -> SORT_PK -> primary lookup -> POST-VALIDATE."""
    plan = A.select(A.scan("Users"), pred=lambda r: True,
                    fields=["user-since"],
                    ranges={"user-since": (1, 2)})
    phys = optimize(plan, _catalog())
    kinds = _ops(phys)
    assert kinds == ["POST_VALIDATE_SELECT", "PRIMARY_INDEX_LOOKUP",
                     "SORT_PK", "SECONDARY_INDEX_SEARCH"]


def test_no_index_falls_back_to_scan():
    plan = A.select(A.scan("Users"), pred=lambda r: True,
                    fields=["name"], ranges={"name": ("a", "b")})
    phys = optimize(plan, _catalog())
    assert "SECONDARY_INDEX_SEARCH" not in _ops(phys)
    assert "DATASET_SCAN" in _ops(phys)


def test_skip_index_hint():
    plan = A.select(A.scan("Users"), pred=lambda r: True,
                    fields=["user-since"], ranges={"user-since": (1, 2)},
                    hints=["skip-index"])
    phys = optimize(plan, _catalog())
    assert "SECONDARY_INDEX_SEARCH" not in _ops(phys)


def test_equijoin_is_hash_join_with_minimal_exchange():
    """R3+R6: both sides hash-partitioned only if they aren't already."""
    plan = A.join(A.scan("Msgs"), A.scan("Users"), ["author-id"], ["id"])
    phys = optimize(plan, _catalog())
    assert phys.kind == "HYBRID_HASH_JOIN"
    lconn, rconn = phys.connectors
    # left: scan is partitioned by message-id, join needs author-id -> move
    assert lconn.name == "MToNHashPartition"
    # right: Users is ALREADY hash-partitioned by id == join key -> no move
    assert rconn.name == "OneToOne"


def test_indexnl_hint():
    plan = A.join(A.scan("Msgs"), A.scan("Users"), ["author-id"], ["id"],
                  hints=["indexnl"])
    phys = optimize(plan, _catalog())
    assert phys.kind == "INDEX_NL_JOIN"
    assert phys.attrs["right_dataset"] == "Users"


def test_agg_split_local_global():
    """R4 (Figure 6): LOCAL_AGG per partition -> one GLOBAL_AGG."""
    plan = A.aggregate(A.scan("Msgs"), {"c": ("count", "*")})
    phys = optimize(plan, _catalog())
    assert _ops(phys) == ["GLOBAL_AGG", "LOCAL_AGG", "DATASET_SCAN"]
    assert phys.connectors[0].name == "ReplicateToOne"
    # disabling the split: single global agg
    phys2 = optimize(plan, _catalog(),
                     RewriteConfig(split_aggregation=False))
    assert "LOCAL_AGG" not in _ops(phys2)


def test_groupby_split_preagg():
    plan = A.group_by(A.scan("Msgs"), ["author-id"], {"c": ("count", "*")})
    phys = optimize(plan, _catalog())
    assert _ops(phys) == ["GLOBAL_GROUP", "LOCAL_PREAGG", "DATASET_SCAN"]
    assert phys.connectors[0].name == "MToNHashPartition"


def test_limit_pushed_into_sort():
    """R5 (beyond paper §5.3.2): ORDERBY+LIMIT -> per-partition TopK."""
    plan = A.limit(A.order_by(A.scan("Msgs"), ["timestamp"]), 3)
    phys = optimize(plan, _catalog())
    assert _ops(phys) == ["TOPK_MERGE", "LOCAL_TOPK", "DATASET_SCAN"]
    off = optimize(plan, _catalog(),
                   RewriteConfig(push_limit_into_sort=False))
    assert _ops(off)[0] == "STREAM_LIMIT"


def test_select_pushdown_below_join():
    plan = A.select(
        A.join(A.scan("Msgs", columns=("message-id", "author-id")),
               A.scan("Users", columns=("id", "name")),
               ["author-id"], ["id"]),
        pred=lambda r: True, fields=["name"])
    phys = optimize(plan, _catalog())
    # the select must sit below the join on the Users side
    assert phys.kind == "HYBRID_HASH_JOIN"
    right = phys.children[1]
    assert right.kind == "STREAM_SELECT"


def test_partitioning_satisfies():
    h = A.hash_partitioned("id")
    assert h.satisfies(A.RANDOM)
    assert h.satisfies(A.hash_partitioned("id"))
    assert not h.satisfies(A.hash_partitioned("other"))
    assert not A.RANDOM.satisfies(h)
