"""Columnar engine: ADM <-> ColumnBatch round-trips, kernel oracles, the
columnar LSM scan, and row-vs-columnar executor equality on every
tinysocial query shape."""

import datetime as dt
import random

import numpy as np
import pytest

from repro.columnar.batch import ColumnBatch
from repro.columnar.schema import ColumnSchema
from repro.configs.tinysocial import build_dataverse, message_type, user_type
from repro.core import algebra as A
from repro.core.rewriter import RewriteConfig
from repro.kernels import columnar_ops as K
from repro.storage.query import run_query

LO, HI = dt.datetime(2010, 1, 1), dt.datetime(2011, 6, 30)
MLO = dt.datetime(2014, 3, 1)


@pytest.fixture(scope="module")
def tiny():
    _, ds = build_dataverse(num_users=120, num_messages=600,
                            num_partitions=4, flush_threshold=64)
    return ds


def _canon(rows):
    return sorted(repr(sorted(r.items(), key=lambda kv: kv[0]))
                  for r in rows)


# ---------------------------------------------------------------------------
# round-trips
# ---------------------------------------------------------------------------

def test_closed_type_roundtrip():
    mt = message_type()
    rows = [mt.validate({
        "message-id": i, "author-id": i % 7,
        "timestamp": dt.datetime(2014, 1, 1 + i, 2, 3, 4, 500000 + i),
        "sender-location": (33.5, -117.5),
        "tags": ["a", "b"], "message": f"msg {i}",
        **({"in-response-to": i - 1} if i % 2 else {}),
    }) for i in range(1, 20)]
    back = ColumnBatch.from_rows(rows).to_rows()
    assert back == rows


def test_open_type_roundtrip_missing_null_and_dict():
    ut = user_type()
    rows = [
        ut.validate({"id": 1, "alias": "a", "name": "A", "user-since": LO,
                     "address": {"street": "1", "city": "i", "state": "CA",
                                 "zip": "1", "country": "USA"},
                     "friend-ids": [2], "employment": [],
                     "job-kind": "part-time"}),       # open string field
        ut.validate({"id": 2, "alias": "b", "name": "B", "user-since": HI,
                     "address": {"street": "2", "city": "i", "state": "WA",
                                 "zip": "2", "country": "USA"},
                     "friend-ids": [], "employment": [],
                     "nerd-score": 11}),              # open int field
        ut.validate({"id": 3, "alias": "c", "name": "C", "user-since": LO,
                     "address": {"street": "3", "city": "i", "state": "OR",
                                 "zip": "3", "country": "USA"},
                     "friend-ids": [], "employment": [],
                     "nickname": None}),              # present-but-null
    ]
    batch = ColumnBatch.from_rows(rows)
    back = batch.to_rows()
    assert back == rows
    # missing open fields stay missing, null stays null
    assert "job-kind" not in back[1] and back[2]["nickname"] is None
    # string dictionary is sorted => code order == lexicographic order
    col = batch.columns["alias"]
    assert col.values == ["a", "b", "c"]
    assert col.data.tolist() == [0, 1, 2]


def test_seeded_random_open_roundtrip():
    rng = random.Random(7)
    pool = {
        "i": lambda: rng.randrange(-2**40, 2**40),
        "f": lambda: rng.uniform(-1e6, 1e6),
        "s": lambda: "".join(rng.choice("abcé-19 ")
                             for _ in range(rng.randrange(9))),
        "b": lambda: rng.random() < 0.5,
        "t": lambda: dt.datetime(2000 + rng.randrange(30), 1 + rng.randrange(12),
                                 1 + rng.randrange(28), rng.randrange(24),
                                 rng.randrange(60), rng.randrange(60),
                                 rng.randrange(10**6)),
        "d": lambda: dt.date(1960 + rng.randrange(100), 1 + rng.randrange(12),
                             1 + rng.randrange(28)),
        "l": lambda: [rng.randrange(10) for _ in range(rng.randrange(4))],
        "n": lambda: None,
    }
    for _ in range(30):
        rows = []
        fields = rng.sample(sorted(pool), rng.randrange(2, 6))
        for i in range(rng.randrange(1, 30)):
            r = {"id": i}
            for f in fields:
                if rng.random() < 0.8:
                    r[f] = pool[f]()
            rows.append(r)
        assert ColumnBatch.from_rows(rows).to_rows() == rows


def test_all_missing_str_column_decodes():
    """Regression (found by the differential harness): a str column that
    is entirely missing has an empty dictionary but zero-filled codes;
    decode must not index the empty dictionary."""
    s = ColumnSchema({"id": "i64", "txt": "str"})
    rows = [{"id": 1}, {"id": 2}]
    batch = ColumnBatch.from_rows(rows, s)
    assert batch.to_rows() == rows
    assert batch.columns["txt"].values == []


def test_concat_unions_schemas_and_dictionaries():
    b1 = ColumnBatch.from_rows([{"id": 1, "s": "zz"}, {"id": 2, "s": "aa"}])
    b2 = ColumnBatch.from_rows([{"id": 3, "x": 1.5}, {"id": 4, "s": "mm"}])
    cat = ColumnBatch.concat([b1, b2])
    assert cat.to_rows() == [{"id": 1, "s": "zz"}, {"id": 2, "s": "aa"},
                             {"id": 3, "x": 1.5}, {"id": 4, "s": "mm"}]
    assert cat.columns["s"].values == ["aa", "mm", "zz"]


# ---------------------------------------------------------------------------
# kernels: jnp fallback vs pallas (interpret) vs numpy oracle
# ---------------------------------------------------------------------------

def test_kernel_range_mask_and_fused_aggregate(rng):
    n = 777
    x = rng.integers(-10**6, 10**6, n)
    xv = rng.random(n) < 0.9
    y = rng.normal(size=n)
    yv = rng.random(n) < 0.8
    preds = [(x, xv, -500000, 400000)]
    oracle = xv & (x >= -500000) & (x <= 400000)
    assert np.array_equal(K.range_mask(preds, n), oracle)
    assert np.array_equal(
        K.range_mask(preds, n, force_pallas=True, interpret=True), oracle)

    res = K.fused_filter_aggregate(preds, [(x, xv), (y, yv)], n)
    assert res["count"] == int(oracle.sum())
    assert res["sums"][0] == int(x[oracle].sum())
    assert res["mins"][0] == int(x[oracle].min())
    assert res["maxs"][0] == int(x[oracle].max())
    ok_y = oracle & yv
    assert res["cnts"][1] == int(ok_y.sum())
    assert res["sums"][1] == pytest.approx(float(y[ok_y].sum()))

    # the Pallas kernel (interpret mode off-TPU) agrees to f32 tolerance
    rp = K.fused_filter_aggregate(preds, [(x, xv), (y, yv)], n,
                                  force_pallas=True, interpret=True)
    assert rp["count"] == res["count"] and rp["cnts"] == res["cnts"]
    assert rp["sums"][0] == pytest.approx(res["sums"][0], rel=1e-5)
    assert rp["mins"][0] == pytest.approx(res["mins"][0], rel=1e-5)

    # unbounded sides and empty results
    assert K.range_mask([(x, xv, None, None)], n).sum() == xv.sum()
    empty = K.fused_filter_aggregate([(x, xv, 10**7, None)], [(x, xv)], n)
    assert empty["count"] == 0 and empty["mins"] == [None]


# ---------------------------------------------------------------------------
# columnar LSM scan
# ---------------------------------------------------------------------------

def test_scan_partition_batch_matches_row_scan(tiny):
    users = tiny["MugshotUsers"]
    for i in range(users.num_partitions):
        rows = users.scan_partition(i)
        crows = users.scan_partition_batch(i).to_rows()
        assert crows == rows


def test_scan_batch_sees_updates_deletes_and_tombstones():
    _, ds = build_dataverse(num_users=50, num_messages=10,
                            num_partitions=2, flush_threshold=8)
    users = ds["MugshotUsers"]
    base = {"alias": "x", "name": "X", "user-since": LO,
            "address": {"street": "1", "city": "i", "state": "CA",
                        "zip": "1", "country": "USA"},
            "friend-ids": [], "employment": []}
    users.delete(7)
    users.insert({"id": 11, **base, "name": "Updated"})   # overwrite
    users.insert({"id": 1007, **base, "extra-open": 42})  # new open field
    got = []
    for i in range(users.num_partitions):
        got.extend(users.scan_partition_batch(i).to_rows())
    want = users.scan()
    assert _canon(got) == _canon(want)
    ids = {r["id"] for r in got}
    assert 7 not in ids and 1007 in ids
    assert next(r for r in got if r["id"] == 11)["name"] == "Updated"


def test_scan_projection_and_component_storage(tiny):
    msgs = tiny["MugshotMessages"]
    b = msgs.scan_partition_batch(0, ["message-id", "timestamp"])
    assert set(b.columns) == {"message-id", "timestamp"}
    comp = next(c for c in msgs.partitions[0].primary.components if c.valid)
    # columnar-native storage: the flush shredded the component's batch
    # as primary data (tombstone bitmap included) and no row-dict view
    # was ever forced — projected scans are zero-copy dict subsets
    assert comp.batch is not None and "timestamp" in comp.batch.columns
    assert comp.tomb is not None and comp._rows is None
    again = msgs.scan_partition_batch(0, ["message-id", "timestamp"])
    assert again.to_rows() == b.to_rows()


# ---------------------------------------------------------------------------
# executor equality: every tinysocial query shape, row vs columnar
# ---------------------------------------------------------------------------

def _plans():
    return {
        "range_select": A.select(
            A.scan("MugshotUsers"),
            pred=lambda r: LO <= r["user-since"] <= HI,
            fields=["user-since"], ranges={"user-since": (LO, HI)}),
        "equijoin": A.join(A.scan("MugshotMessages"),
                           A.scan("MugshotUsers"),
                           ["author-id"], ["id"]),
        "double_select_join": A.join(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r: r["timestamp"] >= MLO,
                     fields=["timestamp"],
                     ranges={"timestamp": (MLO, dt.datetime(2015, 1, 1))}),
            A.select(A.scan("MugshotUsers"),
                     pred=lambda r: LO <= r["user-since"] <= HI,
                     fields=["user-since"],
                     ranges={"user-since": (LO, HI)}),
            ["author-id"], ["id"]),
        "grouped_agg_topk": A.limit(A.order_by(
            A.group_by(A.scan("MugshotMessages"), ["author-id"],
                       {"cnt": ("count", "*")}), ["cnt"], desc=True), 5),
        "avg_agg": A.aggregate(A.scan("MugshotMessages"),
                               {"alen": ("avg", "message-id")}),
        "sum_min_max": A.aggregate(
            A.scan("MugshotMessages"),
            {"s": ("sum", "author-id"), "mn": ("min", "timestamp"),
             "mx": ("max", "timestamp"), "c": ("count", "timestamp")}),
        "fused_exact_agg": A.aggregate(
            A.select(A.scan("MugshotMessages"),
                     pred=lambda r: r["timestamp"] >= MLO,
                     fields=["timestamp"],
                     ranges={"timestamp": (MLO, dt.datetime(2030, 1, 1))},
                     ranges_exact=True, hints=["skip-index"]),
            {"c": ("count", "*"), "am": ("avg", "author-id")}),
        "group_over_join": A.group_by(
            A.join(A.scan("MugshotMessages"), A.scan("MugshotUsers"),
                   ["author-id"], ["id"]),
            ["author-id"],
            {"mn": ("min", "timestamp"), "c": ("count", "*")}),
        "project_orderby_limit": A.limit(A.order_by(
            A.project(A.scan("MugshotUsers"), ["id", "name"]),
            ["id"], desc=True), 7),
    }


@pytest.mark.parametrize("shape", sorted(_plans()))
@pytest.mark.parametrize("cfg", ["default", "noidx", "nosplit", "nopush"])
def test_vectorize_identical_to_row_engine(tiny, shape, cfg):
    config = {
        "default": RewriteConfig(),
        "noidx": RewriteConfig(use_indexes=False),
        "nosplit": RewriteConfig(split_aggregation=False),
        "nopush": RewriteConfig(push_limit_into_sort=False),
    }[cfg]
    plan = _plans()[shape]
    rows_r, _ = run_query(plan, tiny, config=config)
    rows_c, _ = run_query(plan, tiny, config=config, vectorize=True)
    assert _canon(rows_r) == _canon(rows_c)


def test_vectorized_stats_recorded(tiny):
    plan = A.aggregate(
        A.select(A.scan("MugshotMessages"),
                 pred=lambda r: r["timestamp"] >= MLO,
                 fields=["timestamp"],
                 ranges={"timestamp": (MLO, dt.datetime(2030, 1, 1))},
                 ranges_exact=True, hints=["skip-index"]),
        {"c": ("count", "*")})
    rows, ex = run_query(plan, tiny, vectorize=True)
    assert ex.stats.rows_vectorized > 0
    assert ex.stats.rows_fallback == 0
    # op cardinalities keep the row engine's accounting
    assert ex.stats.op_rows["DATASET_SCAN"] == 600
    assert ex.stats.op_rows["STREAM_SELECT"] == rows[0]["c"]

    # index access paths vectorize too: candidate PKs -> position bitmaps
    plan_ix = A.select(A.scan("MugshotUsers"),
                       pred=lambda r: LO <= r["user-since"] <= HI,
                       fields=["user-since"],
                       ranges={"user-since": (LO, HI)})
    rows_ix, ex2 = run_query(plan_ix, tiny, vectorize=True)
    assert ex2.stats.rows_fallback == 0
    assert ex2.stats.rows_index_vectorized > 0
    assert ex2.stats.op_rows["POST_VALIDATE_SELECT"] == len(rows_ix)
    # every index-path op keeps the row engine's accounting keys
    assert ex2.stats.op_rows["SECONDARY_INDEX_SEARCH"] >= len(rows_ix)
    assert ex2.stats.op_rows["SORT_PK"] == \
        ex2.stats.op_rows["SECONDARY_INDEX_SEARCH"]


def test_min_on_object_column_matches_row_engine(tiny):
    """min/max over a non-summable obj column (lists) must not touch
    sum()."""
    plan = A.aggregate(A.scan("MugshotMessages"), {"mn": ("min", "tags")})
    rows_r, _ = run_query(plan, tiny)
    rows_c, _ = run_query(plan, tiny, vectorize=True)
    assert rows_r == rows_c


def test_explicit_null_survives_downstream_operators():
    """Empty-group aggregates surface as explicit None through project
    and at the row boundary, like the row engine."""
    _, ds = build_dataverse(num_users=60, num_messages=10,
                            num_partitions=2, flush_threshold=16)
    users = ds["MugshotUsers"]
    users.insert({"id": 1060, "alias": "n", "name": "N", "user-since": LO,
                  "address": {"street": "1", "city": "i", "state": "CA",
                              "zip": "1", "country": "USA"},
                  "friend-ids": [], "employment": [], "nerd-score": 9})
    plan = A.project(
        A.group_by(A.scan("MugshotUsers"), ["id"],
                   {"m": ("min", "nerd-score")}), ["id", "m"])
    rows_r, _ = run_query(plan, ds)
    rows_c, _ = run_query(plan, ds, vectorize=True)
    assert _canon(rows_r) == _canon(rows_c)
    assert {"id": 0, "m": None} in rows_c     # None, not a missing key


# ---------------------------------------------------------------------------
# index access path: intersection kernel + short-circuits
# ---------------------------------------------------------------------------

def test_sorted_intersect_mask_matches_oracle(rng):
    keys = np.unique(rng.integers(0, 2 ** 20, 4000))
    cands = np.unique(np.concatenate([
        rng.choice(keys, min(300, len(keys)), replace=False),
        rng.integers(0, 2 ** 20, 100)]))
    oracle = np.isin(keys, cands)
    assert np.array_equal(K.sorted_intersect_mask(keys, cands), oracle)
    # the Pallas membership kernel (interpret off-TPU) agrees exactly on
    # f32-exact int domains
    assert np.array_equal(
        K.sorted_intersect_mask(keys, cands, force_pallas=True,
                                interpret=True), oracle)
    # zero-length guards: no kernel launch on either empty side
    assert K.sorted_intersect_mask(keys[:0], cands).shape == (0,)
    assert not K.sorted_intersect_mask(keys, cands[:0]).any()
    # pks beyond f32-exact range stay on the exact x64 oracle
    big = np.asarray([2 ** 40, 2 ** 40 + 1, 2 ** 40 + 2], dtype=np.int64)
    got = K.sorted_intersect_mask(big, big[1:2])
    assert got.tolist() == [False, True, False]


def test_partition_pk_array_aligned_with_scan(tiny):
    users = tiny["MugshotUsers"]
    for i in range(users.num_partitions):
        keys = users.partition_pk_array(i).tolist()
        rows = users.scan_partition_batch(i).to_rows()
        assert keys == [r["id"] for r in rows]
        assert keys == sorted(keys)


def test_empty_candidate_set_short_circuits(tiny):
    """Index range matching nothing -> empty batches end-to-end: count 0,
    avg/min as explicit None (no NaN), nothing on the row engine."""
    future = (dt.datetime(2031, 1, 1), dt.datetime(2032, 1, 1))
    plan = A.aggregate(
        A.select(A.scan("MugshotUsers"),
                 pred=lambda r: future[0] <= r["user-since"] <= future[1],
                 fields=["user-since"], ranges={"user-since": future}),
        {"c": ("count", "*"), "m": ("avg", "id"), "mn": ("min", "id")})
    rows_r, _ = run_query(plan, tiny)
    rows_c, ex = run_query(plan, tiny, vectorize=True)
    assert rows_r == rows_c == [{"c": 0, "m": None, "mn": None}]
    assert ex.stats.rows_fallback == 0
    assert ex.stats.op_rows["POST_VALIDATE_SELECT"] == 0


def test_all_deleted_partitions_short_circuit():
    """Every row tombstoned: the index path yields empty ColumnBatches
    (no NaN aggregates, no zero-length kernel launches)."""
    _, ds = build_dataverse(num_users=40, num_messages=10,
                            num_partitions=2, flush_threshold=8)
    users = ds["MugshotUsers"]
    for r in users.scan():
        users.delete(r["id"])
    assert users.scan() == []
    sel = A.select(A.scan("MugshotUsers"),
                   pred=lambda r: r["user-since"] >= LO,
                   fields=["user-since"], ranges={"user-since": (LO, None)})
    rows_r, _ = run_query(sel, ds)
    rows_c, ex = run_query(sel, ds, vectorize=True)
    assert rows_r == rows_c == []
    assert ex.stats.rows_fallback == 0
    agg = A.aggregate(
        A.select(A.scan("MugshotUsers"),
                 pred=lambda r: r["user-since"] >= LO,
                 fields=["user-since"],
                 ranges={"user-since": (LO, None)}),
        {"s": ("sum", "id"), "m": ("avg", "id")})
    rows_ra, _ = run_query(agg, ds)
    rows_ca, _ = run_query(agg, ds, vectorize=True)
    assert rows_ra == rows_ca == [{"s": 0, "m": None}]
    for i in range(users.num_partitions):
        assert len(users.partition_pk_array(i)) == 0
        assert len(users.scan_partition_batch(i)) == 0


def test_schema_inference_unifies_open_fields():
    s = ColumnSchema()
    s.observe_value("x", 1)
    assert s.kind("x") == "i64"
    s.observe_value("x", 2.5)
    assert s.kind("x") == "f64"
    s.observe_value("x", "oops")
    assert s.kind("x") == "obj"


# ---------------------------------------------------------------------------
# shape-stable kernels: pow2-padded batches never retrace on repeats
# ---------------------------------------------------------------------------

def test_repeated_queries_zero_kernel_retraces(tiny):
    """Component batches and post-index-gather aggregate batches go
    through the shared pow2-padding path, so a repeated query — scan or
    index access -> aggregate — triggers zero new jit traces
    (``ExecStats.kernel_retraces``)."""
    scan_agg = A.aggregate(
        A.select(A.scan("MugshotMessages"),
                 pred=lambda r: r["timestamp"] >= MLO,
                 fields=["timestamp"], ranges={"timestamp": (MLO, None)},
                 ranges_exact=True, hints=["skip-index"]),
        {"c": ("count", "*"), "av": ("avg", "author-id")})
    index_agg = A.aggregate(
        A.select(A.scan("MugshotMessages"),
                 pred=lambda r: r["timestamp"] >= MLO,
                 fields=["timestamp"], ranges={"timestamp": (MLO, None)}),
        {"c": ("count", "*"), "mx": ("max", "message-id")})
    for plan in (scan_agg, index_agg):
        run_query(plan, tiny, vectorize=True)          # warm traces
        _, ex = run_query(plan, tiny, vectorize=True)
        assert ex.stats.kernel_retraces == 0
        _, ex = run_query(plan, tiny, vectorize=True)
        assert ex.stats.kernel_retraces == 0
    assert ex.stats.rows_index_vectorized > 0          # index path ran


def test_column_padded_view_cached_and_invalid():
    b = ColumnBatch.from_rows([{"a": i} for i in range(13)])
    col = b.columns["a"]
    data, valid = col.padded()
    assert data.shape == (16,) and valid.shape == (16,)
    assert not valid[13:].any() and valid[:13].all()
    assert col.padded()[0] is data               # cached, one allocation
    # pow2 lengths pass through untouched
    b2 = ColumnBatch.from_rows([{"a": i} for i in range(8)])
    d2, _ = b2.columns["a"].padded()
    assert d2 is b2.columns["a"].data


# ---------------------------------------------------------------------------
# ColumnBatch as LSM primary storage: sort_by / merge_sorted
# ---------------------------------------------------------------------------

def test_batch_sort_by_and_merge_sorted():
    rows_new = [{"id": 5, "v": "n5"}, {"id": 1, "v": "n1"}]
    rows_old = [{"id": 1, "v": "o1"}, {"id": 2, "v": "o2"},
                {"id": 9, "v": "o9"}]
    bn = ColumnBatch.from_rows(rows_new).sort_by(["id"])
    bo = ColumnBatch.from_rows(rows_old).sort_by(["id"])
    assert [r["id"] for r in bn.to_rows()] == [1, 5]
    merged, keys, tomb = ColumnBatch.merge_sorted(
        [bn, bo], [np.asarray([1, 5]), np.asarray([1, 2, 9])],
        [np.zeros(2, bool), np.zeros(3, bool)])
    assert keys.tolist() == [1, 2, 5, 9] and not tomb.any()
    got = merged.to_rows()
    assert [r["v"] for r in got] == ["n1", "o2", "n5", "o9"]  # newest wins
    # tombstone drop (merge includes the oldest component)
    merged2, keys2, tomb2 = ColumnBatch.merge_sorted(
        [bn, bo], [np.asarray([1, 5]), np.asarray([1, 2, 9])],
        [np.asarray([True, False]), np.zeros(3, bool)],
        drop_tombstones=True)
    assert keys2.tolist() == [2, 5, 9] and not tomb2.any()
    assert [r["v"] for r in merged2.to_rows()] == ["o2", "n5", "o9"]


def test_batch_sort_by_absent_values_sort_first():
    bm = ColumnBatch.from_rows([{"id": 1, "a": 3}, {"id": 2}])
    assert [r["id"] for r in bm.sort_by(["a"]).to_rows()] == [2, 1]
    assert [r["id"] for r in bm.sort_by(["a"], desc=True).to_rows()] == [1, 2]
