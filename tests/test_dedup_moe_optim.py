"""Fuzzy join (paper Q13), MoE dispatch equivalence, optimizer, and gradient
compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: seeded shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.data.dedup import FuzzyJoin, jaccard, minhash_signature
from repro.models import moe as moe_mod
from repro.models.layers import init_params
from repro.optim import adamw
from repro.optim.grad_compress import ef_quantize, ef_state


# ---------------------------------------------------------------------------
# fuzzy join
# ---------------------------------------------------------------------------

def _docs(n, seed=0):
    rng = np.random.default_rng(seed)
    vocab = [f"w{i}" for i in range(50)]
    docs = []
    for i in range(n):
        base = set(rng.choice(vocab, size=10, replace=False))
        docs.append((f"d{i}", base))
        if rng.random() < 0.3:  # planted near-duplicate
            dup = set(base)
            dup.discard(next(iter(dup)))
            dup.add(f"w{rng.integers(50, 60)}")
            docs.append((f"d{i}_dup", dup))
    return docs


def test_minhash_estimates_jaccard():
    rng = np.random.default_rng(1)
    a = set(f"t{i}" for i in range(40))
    b = set(f"t{i}" for i in range(20, 60))
    s1 = minhash_signature(a, k=256)
    s2 = minhash_signature(b, k=256)
    est = float(np.mean(s1 == s2))
    assert abs(est - jaccard(a, b)) < 0.12


def test_fuzzy_join_recall_vs_bruteforce():
    fj = FuzzyJoin(threshold=0.5, num_hashes=64, bands=16)
    docs = _docs(40)
    pairs, stats = fj.run(docs)
    oracle = fj.brute_force(docs)
    got = {(a, b) for a, b, _ in pairs}
    want = {(a, b) for a, b, _ in oracle}
    assert got <= want or not want        # no false positives (verified)
    if want:
        recall = len(got & want) / len(want)
        assert recall >= 0.9, (recall, stats)
    # LSH pruned the candidate space vs n^2
    n = len(docs)
    assert stats["candidates"] < n * (n - 1) / 2


# ---------------------------------------------------------------------------
# MoE: sort-dispatch ("hash partition") == einsum dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "dbrx-132b"])
def test_moe_sort_dispatch_matches_einsum(arch):
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              capacity_factor=64.0)  # no drops
    specs = moe_mod.moe_specs(cfg)
    params = init_params(specs, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y1, _ = moe_mod.moe_ffn(params, x, cfg, dispatch="einsum")
    y2, _ = moe_mod.moe_ffn(params, x, cfg, dispatch="sort")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("olmoe-1b-7b")),
                              capacity_factor=0.05)
    specs = moe_mod.moe_specs(cfg)
    params = init_params(specs, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model))
    y, _ = moe_mod.moe_ffn(params, x, cfg, dispatch="einsum")
    # with tiny capacity most tokens drop -> many zero rows
    zero_rows = np.mean(np.all(np.asarray(y[0]) == 0.0, axis=-1))
    assert zero_rows > 0.3


def test_router_aux_losses_balanced_vs_skewed():
    cfg = reduced(get_config("olmoe-1b-7b"))
    E = cfg.num_experts
    B, S = 4, 64
    probs_bal = jnp.full((B, S, E), 1.0 / E)
    idx_bal = jnp.tile(jnp.arange(cfg.experts_per_token), (B, S, 1))
    idx_bal = (idx_bal + jnp.arange(S)[None, :, None]) % E
    logits = jnp.log(probs_bal)
    aux_bal = moe_mod.router_aux_losses(logits, probs_bal, idx_bal, cfg)
    probs_skew = jnp.zeros((B, S, E)).at[..., 0].set(1.0)
    idx_skew = jnp.zeros((B, S, cfg.experts_per_token), jnp.int32)
    aux_skew = moe_mod.router_aux_losses(
        jnp.log(probs_skew + 1e-9), probs_skew, idx_skew, cfg)
    assert float(aux_skew["moe_balance"]) > float(aux_bal["moe_balance"])


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = adamw.OptimizerConfig(peak_lr=0.1, warmup_steps=5,
                                decay_steps=300, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)
    target = jnp.array([1.0, 1.0])

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw.update(g, state, params, cfg)

    for _ in range(300):
        params, state, m = step(params, state)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_schedule_shape():
    cfg = adamw.OptimizerConfig(peak_lr=1.0, warmup_steps=10,
                                decay_steps=100, min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(jnp.int32(s), cfg)) for s in
           (0, 5, 10, 55, 100, 1000)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=1e-2)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, abs=1e-2)
    assert lrs[5] == pytest.approx(0.1, abs=1e-2)


def test_grad_clipping():
    cfg = adamw.OptimizerConfig(max_grad_norm=1.0, peak_lr=1e-3)
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw.update(huge, state, params, cfg)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


def test_error_feedback_quantization_unbiased_over_steps():
    """EF property: accumulated quantized updates converge to the
    accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(512,)), jnp.float32) * 0.01
    err = ef_state({"g": g_true})["g"] * 0  # zeros
    err = {"g": err}
    total_q = jnp.zeros_like(g_true)
    for _ in range(30):
        q, err = ef_quantize({"g": g_true}, err)
        total_q = total_q + q["g"]
    np.testing.assert_allclose(total_q / 30, g_true, atol=1e-4)


@given(st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_bounded_error(seed):
    from repro.runtime.collectives import int8_decode, int8_encode
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(300,)), jnp.float32)
    q, s = int8_encode(x, block=64)
    y = int8_decode(q, s, x.shape)
    scale_bound = np.repeat(np.asarray(s).ravel(),
                            64)[:300] * 0.5 + 1e-6
    assert np.all(np.abs(np.asarray(x - y)) <= scale_bound)
